"""Quickstart: pretrain a tiny llama3-family model on the synthetic
wikipedia corpus with the Data plan, save a checkpoint, generate text.

    PYTHONPATH=src python examples/quickstart.py --steps 50
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.plans import get_plan
from repro.data import Loader, Tokenizer, build_dataset, synthetic_wikipedia
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import Engine
from repro.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    print("== corpus + tokenizer ==")
    texts = list(synthetic_wikipedia(400, seed=1))
    tok = Tokenizer.train(texts, vocab_size=1024)
    ds = build_dataset(texts, tok, seq_len=128)
    print(f"{len(texts)} docs -> {len(ds)} packed examples, "
          f"vocab {tok.vocab_size}")

    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              n_layers=4, vocab_size=tok.vocab_size)
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    loader = Loader(ds, global_batch=8, seed=0)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=args.steps)

    print("== pretraining (Data plan) ==")
    res = train(model, get_plan("data"), mesh, tcfg, loader,
                steps=args.steps, log_every=10, ckpt_dir=args.ckpt_dir)
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"avg step {res.avg_step_time * 1e3:.0f} ms")

    print("== generation ==")
    from repro.train import latest_checkpoint, restore_checkpoint
    params = model.init(jax.random.key(0))
    params, _, _ = restore_checkpoint(latest_checkpoint(args.ckpt_dir),
                                      params)
    eng = Engine(model, get_plan("data"), mesh, batch_size=1, max_len=256,
                 temperature=0.8, top_k=40)
    prompt = tok.encode(texts[0][:80], eos=False)
    out = eng.generate(params, {"tokens": np.asarray([prompt], np.int32)},
                       n_tokens=40)
    print("prompt:", texts[0][:80])
    print("continuation:", tok.decode(out["tokens"][0].tolist()))
    print(f"decode: {out['stats'].tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
