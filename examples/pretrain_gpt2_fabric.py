"""End-to-end driver reproducing the paper's experiment shape: pretrain a
GPT-2-family model for a few hundred steps under a selectable technique
(Data / ZeRO2 / Shard / Pipeshard), reporting the paper's metrics — total
wall-clock and average training TFLOP/s.

Scaled to this container: a ~100M-param GPT-2 variant (the paper's gpt2m is
354M), seq 256, CPU host devices standing in for the two-VM FABRIC slice:

    PYTHONPATH=src python examples/pretrain_gpt2_fabric.py \
        --plan pipeshard --devices 8 --steps 200

Use Algorithm 1 offline first (examples/select_technique.py) to pick the
plan, exactly as the paper prescribes (§IV-H).
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--plan", default="data",
                choices=["data", "zero2", "shard", "shard_zero",
                         "pipeshard", "fsdp"])
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--d-model", type=int, default=512)
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices} "
    + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs.gpt2 import GPT2_MEDIUM
from repro.configs.base import TrainConfig
from repro.core.pipeline import pipeline_mesh
from repro.core.plans import get_plan
from repro.data import Loader, Tokenizer, build_dataset, synthetic_wikipedia
from repro.models import Model
from repro.train import model_flops_per_step, train


def main():
    texts = list(synthetic_wikipedia(1500, seed=0))
    tok = Tokenizer.train(texts, vocab_size=8192)
    # ~100M-param GPT-2 variant (gpt2m scaled to the container)
    cfg = dataclasses.replace(
        GPT2_MEDIUM, n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=8, d_ff=4 * args.d_model,
        vocab_size=tok.vocab_size, max_seq_len=args.seq)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params, plan={args.plan}")

    ds = build_dataset(texts, tok, seq_len=args.seq)
    loader = Loader(ds, global_batch=args.batch, seed=0)
    plan = get_plan(args.plan)
    n = args.devices
    base = jax.make_mesh((max(n // 4, 1), min(n, 2), 2),
                         ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    mesh = pipeline_mesh(base, 2) if plan.pipeline else base
    tcfg = TrainConfig(learning_rate=6e-4, warmup_steps=20,
                       total_steps=args.steps, microbatches=4)

    res = train(Model(cfg), plan, mesh, tcfg, loader, steps=args.steps,
                log_every=20)
    flops = model_flops_per_step(cfg, args.batch * args.seq)
    print(f"\n== paper metrics ==")
    print(f"total wall-clock: {sum(res.step_times) / 60:.2f} min "
          f"({args.steps} steps)")
    print(f"avg training performance: {res.tflops(flops):.4f} TFLOP/s "
          f"(host-CPU devices; the paper's Fig 3-7 y-axis)")
    print(f"final loss: {res.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
