"""Algorithm 1 (paper §IV-H) in action: pick the pretraining technique for
a model + cluster, two ways:

  1. analytically, over the paper's five FABRIC slices (cost model),
  2. live, probing epsilon-epochs of real training on host devices.

    PYTHONPATH=src python examples/select_technique.py --model gpt2m
    PYTHONPATH=src python examples/select_technique.py --live
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--model", default="gpt2m")
ap.add_argument("--live", action="store_true",
                help="probe with real epsilon-epoch training runs")
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--delta", type=float, default=0.1)
args = ap.parse_args()

if args.live:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.costmodel import PAPER_CLUSTERS, paper_workload
from repro.core.selector import (CostModelProber, LiveProber,
                                 select_technique)


def analytic():
    wl = paper_workload(get_config(args.model))
    print(f"Algorithm 1 over the paper's clusters ({args.model}):")
    for name, cluster in PAPER_CLUSTERS.items():
        sel = select_technique(CostModelProber(wl, cluster),
                               delta=args.delta)
        probes = {k: (f"{v:.2f}" if v else "OOM")
                  for k, v in sel.probes.items()}
        print(f"  {name:11s} -> {sel.technique}@VMs{sel.vms}   "
              f"probes(TFLOP/s): {probes}")


def live():
    """epsilon-epoch probes with real training on host devices: VM1 = first
    half of the mesh, VM2 = second half (the paper's two-VM shape)."""
    import dataclasses
    import jax
    from repro.configs.base import TrainConfig
    from repro.core.plans import get_plan
    from repro.core.pipeline import pipeline_mesh
    from repro.data import (Loader, Tokenizer, build_dataset,
                            synthetic_wikipedia)
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train import model_flops_per_step, train

    texts = list(synthetic_wikipedia(300, seed=0))
    tok = Tokenizer.train(texts, 1024)
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              n_layers=4, vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=64)

    def probe(technique, vms):
        plan = get_plan("shard_zero" if technique == "shard" else technique)
        n = args.devices if vms is None else args.devices // 2
        base = make_host_mesh((max(n // 4, 1), 2, 2),
                              ("pod", "data", "model"))
        mesh = pipeline_mesh(base, 2) if plan.pipeline else base
        loader = Loader(ds, global_batch=8, seed=0)
        res = train(Model(cfg), plan, mesh,
                    TrainConfig(warmup_steps=2, total_steps=10,
                                microbatches=4),
                    loader, steps=6, log_every=0)
        flops = model_flops_per_step(cfg, 8 * 64)
        tf = res.tflops(flops)
        print(f"  probe {technique}@{vms or 'both'}: {tf:.4f} TFLOP/s")
        return tf

    sel = select_technique(LiveProber(probe), delta=args.delta)
    print(f"live selection: {sel.technique}@VMs{sel.vms}")


if __name__ == "__main__":
    (live if args.live else analytic)()
