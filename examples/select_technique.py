"""Algorithm 1 (paper §IV-H) in action: pick the pretraining technique for
a model + cluster, three ways:

  1. analytically, over the paper's five FABRIC slices (cost model),
  2. live, probing epsilon-epochs of real training on host devices,
  3. beyond the paper: full PlanSearch over an N-site topology — site
     subsets and pipeline stage orders the two-VM algorithm can't express.

    PYTHONPATH=src python examples/select_technique.py --model gpt2m
    PYTHONPATH=src python examples/select_technique.py --live
    PYTHONPATH=src python examples/select_technique.py --topology edge3
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--model", default="gpt2m")
ap.add_argument("--live", action="store_true",
                help="probe with real epsilon-epoch training runs")
ap.add_argument("--topology", choices=["edge3", "ring3", "hub4"],
                help="full PlanSearch over an example N-site topology")
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--delta", type=float, default=0.1)
ap.add_argument("--balance", choices=["even", "tflops"], default="even",
                help="[--topology only] pipeline stage sizing: even "
                     "(paper-faithful) or TFLOP-weighted "
                     "(docs/topology-and-search.md)")
ap.add_argument("--exact", action="store_true",
                help="[--topology only] exhaustive PlanSearch "
                     "(no pruning)")
args = ap.parse_args()
if (args.balance != "even" or args.exact) and not args.topology:
    ap.error("--balance/--exact only apply to the --topology PlanSearch "
             "modes (Algorithm 1 probes the paper's fixed plan set)")

if args.live:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.costmodel import PAPER_CLUSTERS, paper_workload
from repro.core.search import PlanSearch
from repro.core.selector import (CostModelProber, LiveProber,
                                 select_technique)
from repro.core.topology import Link, Site, hub, make_topology, ring


def analytic():
    wl = paper_workload(get_config(args.model))
    print(f"Algorithm 1 over the paper's clusters ({args.model}):")
    for name, cluster in PAPER_CLUSTERS.items():
        sel = select_technique(CostModelProber(wl, cluster),
                               delta=args.delta)
        probes = {k: (f"{v:.2f}" if v else "OOM")
                  for k, v in sel.probes.items()}
        print(f"  {name:11s} -> {sel.technique}@VMs{sel.vms}   "
              f"probes(TFLOP/s): {probes}")


EXAMPLE_TOPOLOGIES = {
    # two metro-adjacent sites + one transatlantic: the search spans the
    # cheap pair with Data — a subset the two-VM algorithm never probes.
    "edge3": lambda: make_topology(
        "edge3",
        [Site(("A30", "A30"), name="A"), Site(("A30", "A30"), name="B"),
         Site(("A30", "A30"), name="C")],
        {(0, 1): Link(0.5e-3, 3.0), (1, 2): Link(60e-3, 3.0),
         (0, 2): Link(100e-3, 3.0)}),
    # asymmetric ring: the best pipeline stage order crosses the two cheap
    # links and leaves the 120 ms edge as the un-crossed return path.
    "ring3": lambda: ring(
        "ring3", [Site(("A30", "A30"), name=n) for n in "ABC"],
        [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)]),
    # hub-and-spoke: leaf↔leaf traffic relays through the hub (2 hops).
    "hub4": lambda: hub(
        "hub4", Site(("A30", "A30"), name="HUB"),
        [Site(("RTX", "RTX"), name=f"L{k}") for k in range(3)],
        Link(25e-3, 3.0)),
}


def topology_search():
    from repro.core.plans import get_plan
    from repro.launch.analytic import placement_degrees

    topo = EXAMPLE_TOPOLOGIES[args.topology]()
    wl = paper_workload(get_config(args.model))
    print(topo.describe())
    search = PlanSearch(wl, topo, stage_balance=args.balance,
                        prune=not args.exact)
    ranked = search.search()
    print(f"\nPlanSearch over {len(ranked)} candidates ({args.model}):")
    for s in ranked[:8]:
        perf = f"{s.tflops:.2f}" if s.feasible else "OOM"
        print(f"  {s.candidate.key:30s} {perf:>8s} TFLOP/s")
    best = search.best()
    alg1 = search.select(delta=args.delta)
    if best is None:
        print("\nbest overall : none — every candidate OOMs on this "
              "topology (need more GPU memory)")
        print(f"Algorithm 1  : {alg1.technique}@VMs{alg1.vms}")
        return
    print(f"\nbest overall : {best.candidate.key} "
          f"({best.tflops:.2f} TFLOP/s)")
    print(f"Algorithm 1  : {alg1.technique}@VMs{alg1.vms} "
          f"(probe set restricted to the paper's)")
    plan_name = "shard_zero" if best.candidate.technique == "shard" \
        else best.candidate.technique
    placement = search.placement(best.candidate)
    dp, tp, zdeg = placement_degrees(
        get_plan(plan_name), topo, placement, wl.global_batch)
    print(f"mesh degrees : dp={dp} tp={tp} zero={zdeg} over sites "
          f"{best.candidate.sites}")
    if placement.stage_layers is not None:
        print(f"stage layers : {placement.stage_layers} "
              f"(TFLOP-weighted; even would be "
              f"{wl.cfg.n_layers // placement.n_stages} per stage)")


def live():
    """epsilon-epoch probes with real training on host devices: VM1 = first
    half of the mesh, VM2 = second half (the paper's two-VM shape)."""
    import dataclasses
    import jax
    from repro.configs.base import TrainConfig
    from repro.core.plans import get_plan
    from repro.core.pipeline import pipeline_mesh
    from repro.data import (Loader, Tokenizer, build_dataset,
                            synthetic_wikipedia)
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train import model_flops_per_step, train

    texts = list(synthetic_wikipedia(300, seed=0))
    tok = Tokenizer.train(texts, 1024)
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              n_layers=4, vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=64)

    def probe(technique, vms):
        plan = get_plan("shard_zero" if technique == "shard" else technique)
        n = args.devices if vms is None else args.devices // 2
        base = make_host_mesh((max(n // 4, 1), 2, 2),
                              ("pod", "data", "model"))
        mesh = pipeline_mesh(base, 2) if plan.pipeline else base
        loader = Loader(ds, global_batch=8, seed=0)
        res = train(Model(cfg), plan, mesh,
                    TrainConfig(warmup_steps=2, total_steps=10,
                                microbatches=4),
                    loader, steps=6, log_every=0)
        flops = model_flops_per_step(cfg, 8 * 64)
        tf = res.tflops(flops)
        print(f"  probe {technique}@{vms or 'both'}: {tf:.4f} TFLOP/s")
        return tf

    sel = select_technique(LiveProber(probe), delta=args.delta)
    print(f"live selection: {sel.technique}@VMs{sel.vms}")


if __name__ == "__main__":
    if args.topology:
        topology_search()
    elif args.live:
        live()
    else:
        analytic()
