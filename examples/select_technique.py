"""Algorithm 1 (paper §IV-H) in action: pick the pretraining technique for
a model + cluster, three ways:

  1. analytically, over the paper's five FABRIC slices (cost model),
  2. live, probing epsilon-epochs of real training on host devices,
  3. beyond the paper: full PlanSearch over an N-site topology — site
     subsets and pipeline stage orders the two-VM algorithm can't express,
  4. live + topology: a searched heterogeneous Placement (uneven
     TFLOP-weighted stage split) probed end-to-end by a LiveProber on
     forced host devices — the probe realizes the exact staged mesh.

    PYTHONPATH=src python examples/select_technique.py --model gpt2m
    PYTHONPATH=src python examples/select_technique.py --live
    PYTHONPATH=src python examples/select_technique.py --topology edge3
    PYTHONPATH=src python examples/select_technique.py --live \\
        --topology line3 --devices 3 --balance tflops
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--model", default="gpt2m")
ap.add_argument("--live", action="store_true",
                help="probe with real epsilon-epoch training runs")
ap.add_argument("--topology",
                choices=["edge3", "ring3", "hub4", "line3", "lan3"],
                help="full PlanSearch over an example N-site topology "
                     "(with --live: probe the searched placement live)")
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--delta", type=float, default=0.1)
ap.add_argument("--balance", choices=["even", "tflops"], default="even",
                help="[--topology only] pipeline stage sizing: even "
                     "(paper-faithful) or TFLOP-weighted "
                     "(docs/topology-and-search.md)")
ap.add_argument("--exact", action="store_true",
                help="[--topology only] exhaustive PlanSearch "
                     "(no pruning)")
ap.add_argument("--techniques", choices=["paper", "all"], default="paper",
                help="technique pool: the paper's four, or 'all' to add "
                     "the shard_zero/fsdp specs (docs/cost-model.md) — "
                     "with --topology lan3 --model gpt2L the extended "
                     "pool finds a shard_zero winner the paper's "
                     "selector misses")
args = ap.parse_args()
if (args.balance != "even" or args.exact) and not args.topology:
    ap.error("--balance/--exact only apply to the --topology PlanSearch "
             "modes (Algorithm 1 probes the paper's fixed plan set)")
if args.techniques != "paper" and args.live:
    ap.error("--techniques all is analytic-only here (live probes of the "
             "extended pool go through launch.mesh.placement_mesh)")
if args.live and args.topology and args.topology != "line3":
    ap.error("--live --topology currently supports line3 (single-GPU "
             "sites, so the staged mesh fits forced host devices)")

if args.live:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.costmodel import PAPER_CLUSTERS, paper_workload
from repro.core.search import PlanSearch
from repro.core.selector import (CostModelProber, LiveProber,
                                 select_technique)
from repro.core.topology import Link, Site, hub, line, make_topology, ring


def analytic():
    wl = paper_workload(get_config(args.model))
    print(f"Algorithm 1 over the paper's clusters ({args.model}):")
    for name, cluster in PAPER_CLUSTERS.items():
        sel = select_technique(CostModelProber(wl, cluster),
                               delta=args.delta)
        probes = {k: (f"{v:.2f}" if v else "OOM")
                  for k, v in sel.probes.items()}
        print(f"  {name:11s} -> {sel.technique}@VMs{sel.vms}   "
              f"probes(TFLOP/s): {probes}")


EXAMPLE_TOPOLOGIES = {
    # two metro-adjacent sites + one transatlantic: the search spans the
    # cheap pair with Data — a subset the two-VM algorithm never probes.
    "edge3": lambda: make_topology(
        "edge3",
        [Site(("A30", "A30"), name="A"), Site(("A30", "A30"), name="B"),
         Site(("A30", "A30"), name="C")],
        {(0, 1): Link(0.5e-3, 3.0), (1, 2): Link(60e-3, 3.0),
         (0, 2): Link(100e-3, 3.0)}),
    # asymmetric ring: the best pipeline stage order crosses the two cheap
    # links and leaves the 120 ms edge as the un-crossed return path.
    "ring3": lambda: ring(
        "ring3", [Site(("A30", "A30"), name=n) for n in "ABC"],
        [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)]),
    # hub-and-spoke: leaf↔leaf traffic relays through the hub (2 hops).
    "hub4": lambda: hub(
        "hub4", Site(("A30", "A30"), name="HUB"),
        [Site(("RTX", "RTX"), name=f"L{k}") for k in range(3)],
        Link(25e-3, 3.0)),
    # heterogeneous A30+T4 line with single-GPU sites: the TFLOP-weighted
    # balancer gives the T4 sites strictly fewer layers, and one host
    # device per site realizes the staged mesh under --live.
    "line3": lambda: line(
        "line3",
        [Site(("A30",), name="A"), Site(("T4",), name="B"),
         Site(("T4",), name="C")],
        [Link(20e-3, 3.0), Link(20e-3, 3.0)]),
    # memory-tight metro LAN: three 16GB T4 sites a campus apart.  With
    # --model gpt2L the replicated-state plans OOM and the extended pool
    # (--techniques all) finds the shard_zero hybrid the paper's
    # four-technique selector cannot even price (docs/cost-model.md).
    "lan3": lambda: line(
        "lan3", [Site(("T4", "T4"), name=n) for n in "ABC"],
        [Link(0.1e-3, 3.0), Link(0.1e-3, 3.0)]),
}


def topology_search():
    from repro.core.costmodel import ALL_TECHNIQUES, TECHNIQUES
    from repro.core.plans import get_plan
    from repro.launch.analytic import placement_degrees

    topo = EXAMPLE_TOPOLOGIES[args.topology]()
    wl = paper_workload(get_config(args.model))
    print(topo.describe())
    pool = ALL_TECHNIQUES if args.techniques == "all" else TECHNIQUES
    search = PlanSearch(wl, topo, stage_balance=args.balance,
                        prune=not args.exact, techniques=pool)
    ranked = search.search()
    print(f"\nPlanSearch over {len(ranked)} candidates ({args.model}, "
          f"{args.techniques} pool):")
    for s in ranked[:8]:
        perf = f"{s.tflops:.2f}" if s.feasible else "OOM"
        print(f"  {s.candidate.key:30s} {perf:>8s} TFLOP/s")
    best = search.best()
    alg1 = search.select(delta=args.delta, extended=False)
    if best is None:
        print("\nbest overall : none — every candidate OOMs on this "
              "topology (need more GPU memory)")
        print(f"Algorithm 1  : {alg1.technique}@VMs{alg1.vms}")
        return
    print(f"\nbest overall : {best.candidate.key} "
          f"({best.tflops:.2f} TFLOP/s)")
    print(f"Algorithm 1  : {alg1.technique}@VMs{alg1.vms} "
          f"(probe set restricted to the paper's)")
    if args.techniques == "all":
        ext = search.select(delta=args.delta, extended=True)
        print(f"Algorithm 1+ : {ext.technique}@VMs{ext.vms} "
              f"(extended probe set: +shard_zero/fsdp)")
        paper_best = PlanSearch(wl, topo, stage_balance=args.balance,
                                prune=not args.exact).best()
        if paper_best is not None and \
                best.tflops > (paper_best.tflops or 0):
            print(f"paper pool   : {paper_best.candidate.key} "
                  f"({paper_best.tflops:.2f} TFLOP/s) — the extended "
                  f"pool wins by "
                  f"{best.tflops / paper_best.tflops - 1:+.1%}")
    plan_name = "shard_zero" if best.candidate.technique == "shard" \
        else best.candidate.technique
    placement = search.placement(best.candidate)
    dp, tp, zdeg = placement_degrees(
        get_plan(plan_name), topo, placement, wl.global_batch)
    print(f"mesh degrees : dp={dp} tp={tp} zero={zdeg} over sites "
          f"{best.candidate.sites}")
    if placement.stage_layers is not None:
        print(f"stage layers : {placement.stage_layers} "
              f"(TFLOP-weighted; even would be "
              f"{wl.cfg.n_layers // placement.n_stages} per stage)")
    if placement.schedule != "gpipe":
        print(f"schedule     : {placement.schedule} "
              f"(docs/schedules.md)")


def live():
    """epsilon-epoch probes with real training on host devices: VM1 = first
    half of the mesh, VM2 = second half (the paper's two-VM shape)."""
    import dataclasses
    from repro.configs.base import TrainConfig
    from repro.core.plans import get_plan
    from repro.core.pipeline import pipeline_mesh
    from repro.data import (Loader, Tokenizer, build_dataset,
                            synthetic_wikipedia)
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train import model_flops_per_step, train

    texts = list(synthetic_wikipedia(300, seed=0))
    tok = Tokenizer.train(texts, 1024)
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              n_layers=4, vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=64)

    def probe(technique, placement):
        plan = get_plan("shard_zero" if technique == "shard" else technique)
        both = placement is None or len(placement.sites) > 1
        n = args.devices if both else args.devices // 2
        base = make_host_mesh((max(n // 4, 1), 2, 2),
                              ("pod", "data", "model"))
        mesh = pipeline_mesh(base, 2) if plan.pipeline else base
        loader = Loader(ds, global_batch=8, seed=0)
        res = train(Model(cfg), plan, mesh,
                    TrainConfig(warmup_steps=2, total_steps=10,
                                microbatches=4),
                    loader, steps=6, log_every=0)
        flops = model_flops_per_step(cfg, 8 * 64)
        tf = res.tflops(flops)
        where = "both" if both else f"V{placement.sites[0] + 1}"
        print(f"  probe {technique}@{where}: {tf:.4f} TFLOP/s")
        return tf

    sel = select_technique(LiveProber(probe), delta=args.delta)
    print(f"live selection: {sel.technique}@VMs{sel.vms}")


def live_topology():
    """A LiveProber-driven placement probe: search the heterogeneous
    line3 topology with TFLOP-weighted balancing, then *execute* the
    winning Pipeshard placement — pod blocks in stage order, uneven
    stage_layers pad-and-masked — on forced host devices (one device per
    single-GPU site)."""
    import dataclasses
    import jax
    from repro.configs.base import TrainConfig
    from repro.core.costmodel import Workload
    from repro.core.plans import get_plan
    from repro.data import (Loader, Tokenizer, build_dataset,
                            synthetic_wikipedia)
    from repro.launch.mesh import placement_pipeline_mesh
    from repro.models import Model
    from repro.train import model_flops_per_step, train

    topo = EXAMPLE_TOPOLOGIES[args.topology]()
    n_gpus = sum(len(s.gpus) for s in topo.sites)
    assert args.devices >= n_gpus, \
        f"--devices {args.devices} < {n_gpus} GPUs in {topo.name}"
    print(topo.describe())

    texts = list(synthetic_wikipedia(200, seed=0))
    tok = Tokenizer.train(texts, 1024)
    cfg = dataclasses.replace(get_config("gpt2m").reduced(),
                              n_layers=6, vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=64)
    wl = Workload(cfg, 64, 8, steps_per_epoch=1, microbatches=4)

    # analytic search proposes; the live probe disposes.  Probe the best
    # *all-site* pipeline — the placement that exercises every topology
    # link; under --balance tflops each site gets a weighted (uneven)
    # layer share.
    search = PlanSearch(wl, topo, stage_balance=args.balance,
                        techniques=("pipeshard",))
    best = next((s for s in search.search()
                 if len(s.candidate.sites) == topo.n_sites and s.feasible),
                None)
    if best is None:
        print(f"no feasible all-site pipeline on {topo.name} — "
              f"need more GPU memory")
        sys.exit(1)
    placement = search.placement(best.candidate)
    print(f"searched placement: {best.candidate.key} "
          f"stage_layers={placement.stage_layers} "
          f"schedule={placement.schedule}")

    def run_probe(technique, placement):
        mesh = placement_pipeline_mesh(topo, placement,
                                       devices=jax.devices()[:n_gpus])
        loader = Loader(ds, global_batch=8, seed=0)
        res = train(Model(cfg), get_plan(technique), mesh,
                    TrainConfig(warmup_steps=2, total_steps=10,
                                microbatches=4),
                    loader, steps=4, log_every=0,
                    stage_layers=placement.stage_layers,
                    schedule=placement.schedule)
        return res.tflops(model_flops_per_step(cfg, 8 * 64))

    prober = LiveProber(run_probe, n_sites=topo.n_sites)
    tf = prober.probe("pipeshard", placement)
    print(f"live probe {best.candidate.key}: "
          f"{'infeasible' if tf is None else f'{tf:.4f} TFLOP/s'}")
    if tf is None:
        sys.exit(1)


if __name__ == "__main__":
    if args.topology and args.live:
        live_topology()
    elif args.topology:
        topology_search()
    elif args.live:
        live()
    else:
        analytic()
