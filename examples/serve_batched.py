"""Batched serving example: prefill + autoregressive decode with the
sharded KV cache, across architecture families (dense / MLA / SSM).

    PYTHONPATH=src python examples/serve_batched.py --arch falcon-mamba-7b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.plans import get_plan
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    help="any assigned arch id (reduced variant is served)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(
        rng.integers(4, min(cfg.vocab_size, 400),
                     (args.batch, args.prompt_len)), np.int32)}
    if cfg.family == "vlm":   # stub frontend: precomputed patch embeddings
        batch["patch_embeds"] = np.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim))
            * 0.02, np.float32)
    if cfg.family == "encdec":  # stub frontend: precomputed frames
        batch["frames"] = np.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq_len, cfg.d_model))
            * 0.02, np.float32)

    eng = Engine(model, get_plan("data"), mesh, batch_size=args.batch,
                 max_len=args.prompt_len + args.gen + 8,
                 temperature=args.temperature, top_k=40)
    out = eng.generate(params, batch, n_tokens=args.gen, seed=0)
    s = out["stats"]
    print(f"arch {cfg.name} [{cfg.family}] batch={args.batch}")
    print(f"prefill: {s.prefill_s * 1e3:.0f} ms for "
          f"{args.batch * args.prompt_len} tokens")
    print(f"decode:  {s.tokens_per_s:.1f} steps/s "
          f"({s.tokens_per_s * args.batch:.1f} tok/s aggregate)")
    print("generated ids [0]:", out["tokens"][0].tolist())


if __name__ == "__main__":
    main()
