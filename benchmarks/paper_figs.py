"""Benchmark: paper Figures 3–7 — per-cluster pretraining time + TFLOP/s
for every technique, 4-GPU (two VM) and single-VM configurations, with OOM
marks, plus the machine-checkable claims each figure supports."""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core.costmodel import (PAPER_CLUSTERS, avg_tflops, epoch_minutes,
                                  paper_workload)

TECHNIQUES = ("data", "zero2", "shard", "pipeshard")


def figure_rows(cluster_name: str) -> List[Dict]:
    cluster = PAPER_CLUSTERS[cluster_name]
    rows = []
    for model_name in ("gpt2m", "gpt2L", "gpt2l"):
        wl = paper_workload(get_config(model_name))
        for scope, vms in (("4gpu", None), ("1vm", [0])):
            for tech in TECHNIQUES:
                mins = epoch_minutes(tech, wl, cluster, vms)
                tf = avg_tflops(tech, wl, cluster, vms)
                rows.append({
                    "cluster": cluster_name, "model": model_name,
                    "scope": scope, "technique": tech,
                    "minutes": mins, "tflops": tf,
                })
    return rows


def check_figure_claims(cluster_name: str) -> List[str]:
    """The per-figure claims from §IV-A..E, evaluated on the model."""
    failures = []
    cluster = PAPER_CLUSTERS[cluster_name]
    wl_m = paper_workload(get_config("gpt2m"))
    t = {tech: epoch_minutes(tech, wl_m, cluster) for tech in TECHNIQUES}

    if cluster_name != "TACC-TACC":
        # C1: Pipeshard fastest on every geo-distributed 4-GPU cluster
        others = [v for k, v in t.items() if k != "pipeshard" and v]
        if t["pipeshard"] and others and t["pipeshard"] > min(others):
            failures.append(f"{cluster_name}: pipeshard not fastest (gpt2m)")
        # C2: Shard slowest among techniques that ran
        ran = {k: v for k, v in t.items() if v}
        if "shard" in ran and ran["shard"] != max(ran.values()):
            failures.append(f"{cluster_name}: shard not slowest")
    # C3: single-VM Data beats 4-GPU Pipeshard when it fits (all clusters)
    one = epoch_minutes("data", wl_m, cluster, vms=[0])
    if one is not None and t["pipeshard"] is not None \
            and one > t["pipeshard"]:
        failures.append(f"{cluster_name}: 1-VM data slower than pipeshard")
    # C4: gpt2L memory: zero2 fits whenever anything fits
    wl_L = paper_workload(get_config("gpt2L"))
    fits = {tech: epoch_minutes(tech, wl_L, cluster) is not None
            for tech in TECHNIQUES}
    if any(fits.values()) and not (fits["zero2"] or fits["pipeshard"]):
        failures.append(f"{cluster_name}: nothing low-memory fits gpt2L")
    return failures


def run(print_fn=print) -> int:
    n_fail = 0
    for cname in PAPER_CLUSTERS:
        rows = figure_rows(cname)
        print_fn(f"# Figure ({cname})")
        print_fn("cluster,model,scope,technique,minutes,tflops")
        for r in rows:
            m = "OOM" if r["minutes"] is None else f"{r['minutes']:.0f}"
            f = "-" if r["tflops"] is None else f"{r['tflops']:.2f}"
            print_fn(f"{r['cluster']},{r['model']},{r['scope']},"
                     f"{r['technique']},{m},{f}")
        fails = check_figure_claims(cname)
        for f in fails:
            print_fn(f"CLAIM-FAIL: {f}")
        n_fail += len(fails)
    return n_fail


if __name__ == "__main__":
    raise SystemExit(run())
