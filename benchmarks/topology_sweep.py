"""Benchmark: multi-site winner maps — the N-site analogue of paper
Table II / Algorithm 1.

Runs the pruned ``core.search.PlanSearch`` over N∈{2..6} ring/hub/line
topologies × the paper's GPU mixes (A30/T4/RTX) × GPT-2 medium/large ×
Table-I latency regimes, and emits per-regime winner maps
(technique × site-subset × stage-order) as JSON + markdown tables:

    PYTHONPATH=src python benchmarks/topology_sweep.py --smoke
    PYTHONPATH=src python benchmarks/topology_sweep.py            # full
    PYTHONPATH=src python benchmarks/topology_sweep.py --exact    # no pruning
    PYTHONPATH=src python benchmarks/topology_sweep.py --smoke --techniques all
    PYTHONPATH=src python benchmarks/topology_sweep.py --smoke --wire

``--smoke`` covers N∈{2,3} ring+hub in seconds (the CI gate) and
cross-checks every pruned winner against the exhaustive search; the
full grid covers N∈{2..6} × 3 kinds × 4 mixes × 2 models × 4 regimes.
Pipeshard stages are TFLOP-weighted by default (``--balance even``
restores the paper's equal splits).  ``--techniques all`` widens the
pool to the shard_zero/fsdp specs (docs/cost-model.md): winner cells a
beyond-paper technique takes are tagged †, and the run fails loudly
when no extended cell ever wins (a mispriced-spec guard, wired into
CI).  ``--wire`` opens the fp32/bf16/int8 wire-dtype axis
(docs/quantization.md): winners carry a ``~int8``/``~bf16`` tag, the
smoke grid swaps in the regional regime + all-A30 mix where the
documented data→pipeshard flip lives, and the run fails loudly when
int8 never wins a cell.  See docs/benchmarks.md.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.sweep_common import (LATENCY_REGIMES, TOPOLOGY_KINDS,
                                     build_topology, md_table,
                                     write_outputs)
from repro.configs import get_config
from repro.core.costmodel import (ALL_TECHNIQUES, TECHNIQUES,
                                  paper_workload)
from repro.core.search import PlanSearch, Scored

SMOKE_GRID = dict(ns=(2, 3), kinds=("ring", "hub"),
                  mixes=("a30+t4", "rtx+t4"),
                  models=("gpt2m", "gpt2L"),
                  regimes=("metro", "transatlantic"))
FULL_GRID = dict(ns=(2, 3, 4, 5, 6), kinds=TOPOLOGY_KINDS,
                 mixes=("a30", "a30+t4", "rtx+t4", "a30+rtx"),
                 models=("gpt2m", "gpt2L"),
                 regimes=tuple(LATENCY_REGIMES))

TECHNIQUE_POOLS = {"paper": TECHNIQUES, "all": ALL_TECHNIQUES}

WIRE_POOL = ("fp32", "bf16", "int8")
# --wire --smoke: the int8 flip needs WAN-dominated, compute-balanced
# cells — swap in the regional regime and the all-A30 mix (the pinned
# gate in tests/test_search.py lives at regional/a30/n=2).
WIRE_SMOKE_GRID = dict(ns=(2, 3), kinds=("ring", "hub"),
                       mixes=("a30", "a30+t4"),
                       models=("gpt2m", "gpt2L"),
                       regimes=("regional", "transatlantic"))


def _scored_record(search: PlanSearch, s: Optional[Scored]) -> Optional[dict]:
    if s is None:
        return None
    placement = search.placement(s.candidate)
    return {
        "key": s.candidate.key,
        "technique": s.candidate.technique,
        "sites": list(s.candidate.sites),
        "stage_order": (None if s.candidate.stage_order is None
                        else list(s.candidate.stage_order)),
        "stage_layers": (None if placement.stage_layers is None
                         else list(placement.stage_layers)),
        "schedule": s.candidate.schedule,
        "extended": s.candidate.technique not in TECHNIQUES,
        "wire_dtype": s.candidate.wire_dtype,
        "tflops": round(s.tflops, 4),
    }


def sweep_entry(kind: str, n: int, mix: str, model: str, regime: str, *,
                balance: str, exact: bool, check: bool,
                techniques: str = "paper", wire: bool = False) -> dict:
    """Search one grid point; returns the winner-map entry."""
    topo = build_topology(kind, n, mix, LATENCY_REGIMES[regime])
    wl = paper_workload(get_config(model))
    search = PlanSearch(wl, topo, stage_balance=balance, prune=not exact,
                        techniques=TECHNIQUE_POOLS[techniques],
                        wire_dtypes=WIRE_POOL if wire else None)
    t0 = time.perf_counter()
    ranked = search.search()
    elapsed_ms = (time.perf_counter() - t0) * 1e3
    best = ranked[0] if ranked and ranked[0].feasible else None
    alg1 = search.select()
    entry = {
        "kind": kind, "n": n, "mix": mix, "model": model, "regime": regime,
        "latency_ms": LATENCY_REGIMES[regime],
        "winner": _scored_record(search, best),
        "runner_up": _scored_record(
            search, ranked[1] if len(ranked) > 1 and ranked[1].feasible
            else None),
        "algorithm1": {"technique": alg1.technique, "sites": alg1.vms},
        "n_candidates": len(ranked),
        "elapsed_ms": round(elapsed_ms, 2),
    }
    if check:   # pruned result must equal the exhaustive search's
        exb = search.best(prune=False)
        ok = (best is None) == (exb is None) and (
            best is None or abs(best.tflops - exb.tflops) < 1e-9)
        entry["matches_exhaustive"] = ok
    return entry


def _cell(entry: dict) -> str:
    w = entry["winner"]
    if w is None:
        return "OOM"
    sites = "+".join(str(i) for i in w["sites"])
    tag = " †" if w.get("extended") else ""
    if w.get("wire_dtype", "fp32") != "fp32":
        tag += f" ~{w['wire_dtype']}"
    return f"{w['technique']}@{sites} ({w['tflops']:.0f}){tag}"


def to_markdown(entries: List[dict], grid: dict, *, balance: str,
                techniques: str = "paper", wire: bool = False) -> str:
    """Winner-map tables: one per (model, regime), rows = topology,
    cols = GPU mix, cell = winning technique@sites (TFLOP/s)."""
    by_key: Dict[tuple, dict] = {
        (e["model"], e["regime"], e["kind"], e["n"], e["mix"]): e
        for e in entries}
    out = ["# Multi-site winner maps",
           "",
           f"Winning plan per (topology × GPU mix), from the pruned "
           f"`PlanSearch` with `stage_balance={balance!r}` over the "
           f"{techniques!r} technique pool.  Cells are "
           f"`technique@sites (TFLOP/s)`; site GPUs cycle through the mix "
           f"(two cards per site).  N=2 ring/hub degenerate to the paper's "
           f"two-VM single-edge shape.", ""]
    if techniques == "all":
        out += ["Cells tagged † are won by a beyond-paper technique "
                "(`shard_zero` / `fsdp`, docs/cost-model.md) the "
                "paper's four-technique pool cannot price.", ""]
    if wire:
        out += ["The fp32/bf16/int8 wire-dtype axis is open "
                "(docs/quantization.md): cells tagged `~int8`/`~bf16` "
                "are won by a quantized-wire plan; untagged cells stay "
                "fp32 even with the cheaper wires on offer.", ""]
    for model in grid["models"]:
        out.append(f"## {model}")
        for regime in grid["regimes"]:
            out.append(f"\n### {regime} "
                       f"({LATENCY_REGIMES[regime]:g} ms inter-site)\n")
            headers = ["topology"] + list(grid["mixes"])
            rows = []
            for kind in grid["kinds"]:
                for n in grid["ns"]:
                    cells = [f"{kind}{n}"]
                    for mix in grid["mixes"]:
                        e = by_key.get((model, regime, kind, n, mix))
                        cells.append("-" if e is None else _cell(e))
                    rows.append(cells)
            out.append(md_table(headers, rows))
    return "\n".join(out)


def run(*, smoke: bool = False, out: Optional[str] = None,
        balance: str = "tflops", exact: bool = False,
        techniques: str = "paper", wire: bool = False,
        print_fn=print) -> int:
    """Run the sweep; returns the number of failures (pruned/exhaustive
    winner mismatches in smoke mode, grid points that errored, or — over
    the "all" pool — an extended pool in which no beyond-paper technique
    ever wins a cell, the loud guard against silently mispriced specs;
    the --wire analogue fails when int8 never wins a cell)."""
    if smoke:
        grid = WIRE_SMOKE_GRID if wire else SMOKE_GRID
    else:
        grid = FULL_GRID
    entries, n_fail = [], 0
    t0 = time.perf_counter()
    for model in grid["models"]:
        for regime in grid["regimes"]:
            for kind in grid["kinds"]:
                for n in grid["ns"]:
                    for mix in grid["mixes"]:
                        e = sweep_entry(kind, n, mix, model, regime,
                                        balance=balance, exact=exact,
                                        check=smoke and not exact,
                                        techniques=techniques,
                                        wire=wire)
                        entries.append(e)
                        if e.get("matches_exhaustive") is False:
                            n_fail += 1
                            print_fn(f"CLAIM-FAIL: pruned winner != "
                                     f"exhaustive at {e['kind']}{e['n']} "
                                     f"{e['mix']} {e['model']} "
                                     f"{e['regime']}")
    elapsed = time.perf_counter() - t0
    mode = "smoke" if smoke else "full"
    if techniques == "all":
        n_ext = sum(1 for e in entries
                    if (e["winner"] or {}).get("extended"))
        print_fn(f"# extended-technique winners: {n_ext}/{len(entries)} "
                 f"cells")
        if n_ext == 0:
            n_fail += 1
            print_fn("CLAIM-FAIL: the extended pool never beat the "
                     "paper's four techniques in any cell — shard_zero/"
                     "fsdp pricing is suspect (docs/cost-model.md)")
    if wire:
        n_i8 = sum(1 for e in entries
                   if (e["winner"] or {}).get("wire_dtype") == "int8")
        print_fn(f"# int8-wire winners: {n_i8}/{len(entries)} cells")
        if n_i8 == 0:
            n_fail += 1
            print_fn("CLAIM-FAIL: int8 wire never won a cell with the "
                     "fp32/bf16/int8 pool open — wire_dtype pricing is "
                     "suspect (docs/quantization.md)")
    mode_stem = f"topology_sweep_{mode}" if techniques == "paper" \
        else f"topology_sweep_all_{mode}"
    if wire:
        mode_stem = f"topology_sweep_wire_{mode}"
    print_fn(f"# topology sweep ({mode}): {len(entries)} grid points, "
             f"{elapsed:.1f}s, balance={balance}, pool={techniques}, "
             f"wire={'fp32/bf16/int8' if wire else 'fp32'}, "
             f"{'exhaustive' if exact else 'pruned'}")
    md = to_markdown(entries, grid, balance=balance, techniques=techniques,
                     wire=wire)
    record = {"mode": mode, "balance": balance, "exact": exact,
              "techniques": techniques, "wire": wire,
              "wire_dtypes": list(WIRE_POOL) if wire else ["fp32"],
              "elapsed_s": round(elapsed, 2), "entries": entries}
    if out is None:
        out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "out")
    write_outputs(out, mode_stem, record, md, print_fn=print_fn)
    for line_ in md.splitlines():
        print_fn(line_)
    return n_fail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (N∈{2,3} ring+hub), seconds, with "
                         "pruned==exhaustive cross-check")
    ap.add_argument("--out", default=None,
                    help="output dir (default: benchmarks/out)")
    ap.add_argument("--balance", choices=("even", "tflops"),
                    default="tflops", help="pipeline stage-size policy")
    ap.add_argument("--exact", action="store_true",
                    help="exactness escape hatch: exhaustive search, "
                         "no pruning")
    ap.add_argument("--techniques", choices=tuple(TECHNIQUE_POOLS),
                    default="paper",
                    help="technique pool: the paper's four, or 'all' to "
                         "add the shard_zero/fsdp specs; 'all' fails "
                         "loudly when no extended cell ever wins")
    ap.add_argument("--wire", action="store_true",
                    help="open the fp32/bf16/int8 wire-dtype axis; "
                         "fails loudly when int8 never wins a cell")
    args = ap.parse_args(argv)
    return run(smoke=args.smoke, out=args.out, balance=args.balance,
               exact=args.exact, techniques=args.techniques,
               wire=args.wire)


if __name__ == "__main__":
    raise SystemExit(main())
