"""Shared vocabulary for the multi-site sweep benchmarks
(topology_sweep.py / latency_sweep.py): the paper's GPU cards arranged
into N-site ring/hub/line topologies at Table-I latency regimes, plus
JSON/markdown emitters.  See docs/benchmarks.md."""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.topology import (Link, Site, Topology, hub, line, ring,
                                 two_site)

# Two GPUs per site of one card type, cycling through the mix — the
# paper's VM shape (Table I) generalized to N sites.
GPU_MIXES: Dict[str, Sequence[str]] = {
    "a30": ("A30",),
    "rtx": ("RTX",),
    "t4": ("T4",),
    "a30+t4": ("A30", "T4"),
    "rtx+t4": ("RTX", "T4"),
    "a30+rtx": ("A30", "RTX"),
}

# Inter-site RTTs measured by the paper (Table I), in ms.
LATENCY_REGIMES: Dict[str, float] = {
    "metro": 0.1,            # TACC-TACC
    "regional": 20.2,        # UTAH-GPN
    "continental": 57.4,     # UTAH-MASS
    "transatlantic": 103.0,  # GAT-AMST
}

# NCCL-over-TCP achievable bandwidth on FABRIC's 100 Gbps links (§II-C).
WAN_GBPS = 3.0

TOPOLOGY_KINDS = ("ring", "hub", "line")


def mix_sites(n: int, mix: Sequence[str]) -> List[Site]:
    """N two-GPU sites cycling through the mix's card types."""
    return [Site((mix[i % len(mix)],) * 2, name=f"S{i}") for i in range(n)]


def build_topology(kind: str, n: int, mix_name: str, lat_ms: float, *,
                   wan_gbps: float = WAN_GBPS) -> Topology:
    """An N-site `kind` topology with every inter-site edge at `lat_ms`.

    ring needs >= 3 sites and hub >= 3 (hub + 2 leaves); at N=2 both
    degenerate to the paper's single-edge two-site shape, which is what
    this returns so winner maps can cover N=2 uniformly.
    """
    mix = GPU_MIXES[mix_name]
    sites = mix_sites(n, mix)
    name = f"{kind}{n}-{mix_name}"
    if n < 2:
        raise ValueError("need at least 2 sites")
    if n == 2:
        return two_site(name, sites[0].gpus, sites[1].gpus, lat_ms,
                        wan_gbps=wan_gbps)
    edge = Link(lat_ms * 1e-3, wan_gbps)
    if kind == "ring":
        return ring(name, sites, [edge] * n)
    if kind == "hub":
        return hub(name, sites[0], sites[1:], edge)
    if kind == "line":
        return line(name, sites, [edge] * (n - 1))
    raise ValueError(f"unknown topology kind {kind!r}; "
                     f"expected one of {TOPOLOGY_KINDS}")


def write_outputs(out_dir: str, stem: str, record: dict, markdown: str,
                  print_fn=print) -> None:
    """Write `<stem>.json` + `<stem>.md` under `out_dir`."""
    os.makedirs(out_dir, exist_ok=True)
    jpath = os.path.join(out_dir, f"{stem}.json")
    mpath = os.path.join(out_dir, f"{stem}.md")
    with open(jpath, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")
    with open(mpath, "w") as f:
        f.write(markdown)
    print_fn(f"wrote {jpath} and {mpath}")


def md_table(headers: Sequence[str], rows: Sequence[Sequence[str]]) -> str:
    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    lines += ["| " + " | ".join(r) + " |" for r in rows]
    return "\n".join(lines) + "\n"
