"""Benchmark/deliverable: the 40-combo (10 arch × 4 shape) baseline dry-run
sweep on the 16x16 production mesh, plus the 2x16x16 multi-pod pass.

Runs in ONE process (XLA re-uses its compilation threads; subprocess
startup costs ~15 s each on this 1-core container) and is resumable:
results land in results/dryrun/<arch>__<shape>__<mesh>.json and existing
files are skipped.

Usage:  python -m benchmarks.dryrun_sweep [--multi-pod] [--arch A] [--shape S]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_shape
    from repro.launch.dryrun import run_one

    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_name = "multi" if args.multi_pod else "single"
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    t_start = time.time()
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            out = os.path.join(
                RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")
            if os.path.exists(out) and not args.force:
                print(f"[cached] {arch} x {shape} x {mesh_name}", flush=True)
                continue
            plan = "shard_zero" if get_shape(shape).kind == "train" \
                else "shard"
            t0 = time.time()
            try:
                rec = run_one(arch, shape, plan, multi_pod=args.multi_pod,
                              verbose=False)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "plan": plan,
                       "mesh": mesh_name, "status": "fail",
                       "error": f"{type(e).__name__}: {e}"}
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skip"
            n_fail += rec["status"] == "fail"
            msg = rec.get("dominant") or rec.get("reason") \
                or rec.get("error", "")
            print(f"[{rec['status']:4s}] {arch} x {shape} x {mesh_name} "
                  f"({time.time() - t0:.0f}s) {str(msg)[:90]}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail} "
          f"({(time.time() - t_start) / 60:.1f} min)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
