"""Benchmark: paper Table II — gpt2m pretraining time for the four
techniques across the five clusters ordered by site-to-site latency, plus
the latency-sensitivity claims (C1/C2)."""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core.costmodel import (PAPER_CLUSTERS, epoch_minutes,
                                  paper_workload)

PAPER_TABLE2 = {  # minutes, from the paper
    "data": [41, 136, 272, 199, 1375],
    "zero2": [52, 295, 641, 363, 3519],
    "shard": [82, 840, 1808, 1125, 5400],
    "pipeshard": [29, 57, 86, 96, 100],
}
CLUSTER_ORDER = ["TACC-TACC", "UTAH-GPN", "UTAH-MASS", "BRIS-STAR",
                 "GAT-AMST"]


def model_table() -> Dict[str, List[Optional[float]]]:
    wl = paper_workload(get_config("gpt2m"))
    return {tech: [epoch_minutes(tech, wl, PAPER_CLUSTERS[c])
                   for c in CLUSTER_ORDER]
            for tech in PAPER_TABLE2}


def check_claims(table: Dict[str, List[Optional[float]]]) -> List[str]:
    failures = []
    lat0, lat4 = table["pipeshard"][0], table["pipeshard"][-1]
    for tech in ("data", "zero2", "shard"):
        # C1: Pipeshard tolerates latency better: its degradation ratio is
        # far below every other technique's
        deg_t = table[tech][-1] / table[tech][0]
        deg_p = lat4 / lat0
        if deg_p >= deg_t:
            failures.append(f"pipeshard degradation {deg_p:.1f}x not better "
                            f"than {tech} {deg_t:.1f}x")
        # monotone-ish degradation with latency (paper rows rise with
        # latency except the A30-powered BRIS-STAR dip)
        if not table[tech][-1] > table[tech][0]:
            failures.append(f"{tech}: no degradation across latency range")
    # C2: shard is the most latency-affected
    shard_deg = table["shard"][-1] / table["shard"][0]
    for tech in ("data", "zero2"):
        if shard_deg < table[tech][-1] / table[tech][0]:
            failures.append(f"shard degradation not worst vs {tech}")
    # pipeshard fastest on every multi-site cluster
    for i, c in enumerate(CLUSTER_ORDER[1:], start=1):
        best = min(v[i] for v in table.values() if v[i] is not None)
        if table["pipeshard"][i] != best:
            failures.append(f"pipeshard not fastest on {c}")
    return failures


def run(print_fn=print) -> int:
    table = model_table()
    print_fn("# Table II (gpt2m, 4 GPUs, minutes for 20 epochs)")
    print_fn("technique," + ",".join(CLUSTER_ORDER) + ",source")
    for tech in PAPER_TABLE2:
        ours = ",".join("OOM" if v is None else f"{v:.0f}"
                        for v in table[tech])
        ref = ",".join(str(v) for v in PAPER_TABLE2[tech])
        print_fn(f"{tech},{ours},model")
        print_fn(f"{tech},{ref},paper")
    fails = check_claims(table)
    for f in fails:
        print_fn(f"CLAIM-FAIL: {f}")
    return len(fails)


if __name__ == "__main__":
    raise SystemExit(run())
