"""Benchmark: continuous-batching serving (ISSUE 10) — a million-request
trace through the analytic serving model, a live bit-exactness smoke on
this host, and the topology-aware replica-placement winner map,
aggregated into the repo-root ``BENCH_10.json`` (the BENCH_6..9
perf-trajectory family).

Three sections:

  1. trace — Philox-seeded Poisson arrivals (deterministic by ``SEED``,
     independent of platform) drive 10^6 simulated requests with a
     mixed generation-length distribution (90% short / 10% long) through
     two queueing models priced by the cost model's decode/prefill
     times: *continuous* (every slot is an independent server — freed
     the step its request finishes) vs *fixed-batch* (the whole batch
     holds until its longest member finishes, the PR-5 ``Engine``
     discipline).  Two arrival regimes: an overloaded one measures
     goodput (the ISSUE gate: continuous >= 2x fixed on the mixed
     trace), a moderate one measures TTFT p50/p99.
  2. live — the tiny-model smoke: ``ContinuousEngine`` on this host's
     CPU backend, per-request greedy tokens checked bit-identical to
     per-length-group fixed ``Engine`` runs, plus measured tokens/s and
     slot occupancy.
  3. placement — the pinned ``lan2+far`` scenario (two A30 sites at
     0.2 ms LAN + one 80 ms away, full llama3.2-3b pricing, load at 50%
     of a single site's capacity): the winner map must give the
     high-latency site its own local replica while the LAN pair shares
     one.

Approximation, stated once: the continuous trace model treats each slot
as an independent server, ignoring the lockstep decode step (a freed
slot is re-filled on the next step boundary, at most one step late —
<2% of a short request's service time here).

Exit code = number of failed claim checks.
"""
from __future__ import annotations

import argparse
import heapq
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.sweep_common import write_outputs

SEED = 10
N_REQUESTS = 1_000_000
SMOKE_REQUESTS = 20_000
SLOTS = 8
PROMPT_LEN = 256
SHORT_GEN, LONG_GEN = 16, 512
LONG_FRAC = 0.1
#: arrival-rate multiples of continuous capacity for the two regimes
OVERLOAD, MODERATE = 1.4, 0.6


# --------------------------------------------------------------- #
# section 3 first: the pinned placement scenario also prices the
# decode/prefill seconds the trace simulation runs on
def pinned_scenario():
    """The ``lan2+far`` serving scenario: 4xA30 sites, two of them
    0.2 ms apart on a 10 Gb/s LAN, the third 80 ms away on 1 Gb/s."""
    from repro.configs import get_config
    from repro.core.search import PlanSearch
    from repro.core.topology import Link, Site, line
    from repro.serve.placement import decode_workload

    cfg = get_config("llama3.2-3b")
    topo = line("lan2+far",
                [Site(("A30",) * 4, name="S0"),
                 Site(("A30",) * 4, name="S1"),
                 Site(("A30",) * 4, name="S2")],
                [Link(0.2e-3, 10.0), Link(80e-3, 1.0)])
    return PlanSearch(decode_workload(cfg, slots=SLOTS), topo)


def placement_section(print_fn=print) -> dict:
    """Run the replica-placement pass on the pinned scenario and check
    the winner map: far site local, LAN pair pooled."""
    from repro.serve.placement import _price_group, place_replicas

    search = pinned_scenario()
    topo = search.topology
    single, _ = _price_group(search, topo, [0], [0.0, 0.0, 0.0],
                             slots=SLOTS, prompt_len=PROMPT_LEN,
                             gen_len=SHORT_GEN * 4)
    service_s = single.prefill_s + SHORT_GEN * 4 * single.decode_step_s
    capacity_rps = SLOTS / service_s
    rates = [0.5 * capacity_rps] * topo.n_sites
    plan = place_replicas(search, rates, slots=SLOTS,
                          prompt_len=PROMPT_LEN, gen_len=SHORT_GEN * 4)
    far_local = (2,) in plan.groups
    pair_shared = any(0 in g and 1 in g for g in plan.groups)
    print_fn(f"placement: groups {plan.groups}, "
             f"mean latency {plan.mean_latency_s * 1e3:.1f} ms")
    for r in plan.replicas:
        print_fn(f"  serves={r.serves} plan={r.plan_key} "
                 f"x{r.n_instances} rho={r.rho:.3f} "
                 f"wait={r.wait_s * 1e3:.2f}ms")
    return {
        "scenario": topo.name,
        "rates_rps": [round(x, 3) for x in rates],
        "groups": [list(g) for g in plan.groups],
        "replicas": [{
            "serves": list(r.serves),
            "plan": r.plan_key,
            "n_instances": r.n_instances,
            "rho": round(r.rho, 4),
            "wait_ms": round(r.wait_s * 1e3, 3),
        } for r in plan.replicas],
        "mean_latency_ms": round(plan.mean_latency_s * 1e3, 3),
        "single_site": {
            "plan": single.plan_key,
            "decode_step_ms": round(single.decode_step_s * 1e3, 4),
            "prefill_ms": round(single.prefill_s * 1e3, 2),
            "capacity_rps": round(capacity_rps, 3),
        },
        "far_site_local": far_local,
        "lan_pair_shared": pair_shared,
    }


# --------------------------------------------------------------- #
def make_trace(n: int, lam_rps: float) -> tuple:
    """Deterministic Poisson arrivals + mixed generation lengths.

    Philox is counter-based, so the same ``SEED`` reproduces the same
    million-request trace on any platform, in two independent streams
    (arrivals / lengths).
    """
    arr_rng = np.random.Generator(np.random.Philox(key=SEED))
    len_rng = np.random.Generator(np.random.Philox(key=SEED + 1))
    arrivals_s = np.cumsum(arr_rng.exponential(1.0 / lam_rps, n))
    gen_len = np.where(len_rng.random(n) < LONG_FRAC, LONG_GEN, SHORT_GEN)
    return arrivals_s, gen_len.astype(np.int64)


def sim_continuous(arrivals_s, gen_len, *, step_s: float,
                   prefill_s: float, slots: int = SLOTS) -> dict:
    """c-server FCFS queue: each slot serves one request and frees the
    moment it finishes (heap of slot-free times)."""
    free = [0.0] * slots
    heapq.heapify(free)
    ttft_s = np.empty(len(arrivals_s))
    busy_s = 0.0
    finish_s = 0.0
    for i in range(len(arrivals_s)):
        start = max(arrivals_s[i], heapq.heappop(free))
        service_s = prefill_s + gen_len[i] * step_s
        busy_s += service_s
        done = start + service_s
        ttft_s[i] = start + prefill_s - arrivals_s[i]
        finish_s = max(finish_s, done)
        heapq.heappush(free, done)
    makespan_s = finish_s - arrivals_s[0]
    return {
        "goodput_tok_s": float(gen_len.sum() / makespan_s),
        "ttft_s": ttft_s,
        "occupancy": float(busy_s / (slots * makespan_s)),
        "makespan_s": float(makespan_s),
    }


def sim_fixed(arrivals_s, gen_len, *, step_s: float, prefill_s: float,
              batch: int = SLOTS) -> dict:
    """Fixed-batch engine: consecutive arrivals form batches of ``batch``;
    the engine is one server and every batch holds all its slots for
    ``max(gen_len)`` steps (the pre-continuous ``Engine`` discipline)."""
    n = (len(arrivals_s) // batch) * batch
    arr = arrivals_s[:n].reshape(-1, batch)
    gl = gen_len[:n].reshape(-1, batch)
    batch_ready_s = arr[:, -1]                  # last member's arrival
    service_s = prefill_s + gl.max(axis=1) * step_s
    start_s = np.empty(len(arr))
    engine_free_s = 0.0
    for b in range(len(arr)):                   # engine-free recurrence
        start_s[b] = max(engine_free_s, batch_ready_s[b])
        engine_free_s = start_s[b] + service_s[b]
    ttft_s = (start_s[:, None] + prefill_s - arr).ravel()
    makespan_s = engine_free_s - arrivals_s[0]
    return {
        "goodput_tok_s": float(gl.sum() / makespan_s),
        "ttft_s": ttft_s,
        "makespan_s": float(makespan_s),
    }


def trace_section(n_requests: int, *, step_s: float, prefill_s: float,
                  print_fn=print) -> dict:
    """Both regimes, both engines, over the same deterministic trace."""
    service_mean_s = prefill_s + \
        (LONG_FRAC * LONG_GEN + (1 - LONG_FRAC) * SHORT_GEN) * step_s
    capacity_rps = SLOTS / service_mean_s
    out = {"n_requests": n_requests,
           "mix": {"short_gen": SHORT_GEN, "long_gen": LONG_GEN,
                   "long_frac": LONG_FRAC},
           "step_ms": round(step_s * 1e3, 4),
           "prefill_ms": round(prefill_s * 1e3, 2)}
    for regime, mult in (("overload", OVERLOAD), ("moderate", MODERATE)):
        lam_rps = mult * capacity_rps
        arrivals_s, gen_len = make_trace(n_requests, lam_rps)
        cont = sim_continuous(arrivals_s, gen_len, step_s=step_s,
                              prefill_s=prefill_s)
        fixed = sim_fixed(arrivals_s, gen_len, step_s=step_s,
                          prefill_s=prefill_s)
        ratio = cont["goodput_tok_s"] / fixed["goodput_tok_s"]
        out[regime] = {
            "lam_rps": round(lam_rps, 3),
            "goodput_tok_s": {
                "continuous": round(cont["goodput_tok_s"], 2),
                "fixed": round(fixed["goodput_tok_s"], 2),
                "ratio": round(ratio, 3),
            },
            "ttft_s": {
                "continuous": {
                    "p50": round(float(np.percentile(cont["ttft_s"], 50)), 4),
                    "p99": round(float(np.percentile(cont["ttft_s"], 99)), 4),
                },
                "fixed": {
                    "p50": round(float(np.percentile(fixed["ttft_s"], 50)), 4),
                    "p99": round(float(np.percentile(fixed["ttft_s"], 99)), 4),
                },
            },
            "slot_occupancy": round(cont["occupancy"], 4),
        }
        print_fn(f"trace[{regime}]: lam {lam_rps:.1f} rps | goodput "
                 f"cont {cont['goodput_tok_s']:.0f} vs fixed "
                 f"{fixed['goodput_tok_s']:.0f} tok/s (x{ratio:.2f}) | "
                 f"cont TTFT p50/p99 "
                 f"{out[regime]['ttft_s']['continuous']['p50']:.3f}/"
                 f"{out[regime]['ttft_s']['continuous']['p99']:.3f} s | "
                 f"occ {cont['occupancy']:.2f}")
    return out


# --------------------------------------------------------------- #
def live_section(print_fn=print) -> dict:
    """Tiny-model smoke on this host: continuous vs fixed bit-exactness
    plus measured serving stats."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.plans import get_plan
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.serve import ContinuousEngine, Engine, Request

    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              vocab_size=512)
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))
    rng = np.random.default_rng(SEED)
    lens = [5, 9, 9, 13, 5, 7]
    prompts = [np.asarray(rng.integers(4, 400, (n,)), np.int32)
               for n in lens]
    max_new = 6
    plan = get_plan("data")

    ref = {}
    bylen = {}
    for i, p in enumerate(prompts):
        bylen.setdefault(len(p), []).append(i)
    for n, idxs in bylen.items():
        eng = Engine(model, plan, mesh, batch_size=len(idxs), max_len=64)
        out = eng.generate(params,
                           {"tokens": np.stack([prompts[i] for i in idxs])},
                           n_tokens=max_new)
        for row, i in enumerate(idxs):
            ref[i] = out["tokens"][row]

    ce = ContinuousEngine(model, plan, mesh, slots=3, max_len=64,
                          buckets=(8, 16, 32))
    res = ce.run(params, [Request(i, p) for i, p in enumerate(prompts)],
                 max_new=max_new, timing=True)
    bit_exact = all(
        res["outputs"][i].shape == ref[i].shape
        and bool(np.all(res["outputs"][i] == ref[i]))
        for i in range(len(prompts)))
    st = res["stats"]
    print_fn(f"live: bit-exact {bit_exact} | {st.n_tokens} tokens at "
             f"{st.tokens_per_s:.1f} tok/s | occupancy "
             f"{st.mean_occupancy:.2f}")
    return {
        "n_requests": len(prompts),
        "prompt_lens": lens,
        "max_new": max_new,
        "slots": 3,
        "bit_exact": bit_exact,
        "tokens_per_s": round(st.tokens_per_s, 2),
        "mean_occupancy": round(st.mean_occupancy, 3),
        "ttft_p50_s": round(float(np.percentile(
            sorted(st.ttft_s.values()), 50)), 4),
    }


def run(smoke: bool = False, live: bool = True, print_fn=print) -> int:
    """All three sections; writes ``benchmarks/out/serving_bench.*`` and
    the repo-root ``BENCH_10.json``.  Returns the failed-claim count."""
    placement = placement_section(print_fn=print_fn)
    n_requests = SMOKE_REQUESTS if smoke else N_REQUESTS
    trace = trace_section(
        n_requests,
        step_s=placement["single_site"]["decode_step_ms"] * 1e-3,
        prefill_s=placement["single_site"]["prefill_ms"] * 1e-3,
        print_fn=print_fn)
    live_rec = live_section(print_fn=print_fn) if live else None

    checks = {
        "goodput_ratio_ge_2":
            trace["overload"]["goodput_tok_s"]["ratio"] >= 2.0,
        "bit_exact": bool(live_rec["bit_exact"]) if live_rec else None,
        "far_site_local": placement["far_site_local"],
        "lan_pair_shared": placement["lan_pair_shared"],
    }
    n_fail = sum(1 for v in checks.values() if v is False)
    for name, ok in checks.items():
        if ok is False:
            print_fn(f"CLAIM-FAIL: {name}")

    bench = {
        "pr": 10,
        "source": "benchmarks/serving_bench.py",
        "seed": SEED,
        "smoke": smoke,
        "trace": trace,
        "live": live_rec,
        "placement": placement,
        "gates": checks,
    }
    path = os.path.join(_ROOT, "BENCH_10.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    print_fn(f"wrote {path} ({n_fail} claim failure(s))")

    md = ["# Continuous-batching serving bench", "",
          f"- trace: {n_requests} requests, goodput ratio "
          f"x{trace['overload']['goodput_tok_s']['ratio']} (gate >= 2)",
          f"- placement: {placement['groups']} on "
          f"{placement['scenario']}", ""]
    write_outputs(os.path.join(_ROOT, "benchmarks", "out"),
                  "serving_bench", bench, "\n".join(md),
                  print_fn=print_fn)
    return n_fail


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help=f"{SMOKE_REQUESTS} trace requests instead of "
                         f"{N_REQUESTS}")
    ap.add_argument("--no-live", action="store_true",
                    help="skip the live tiny-model smoke (analytic only)")
    args = ap.parse_args()
    sys.exit(run(smoke=args.smoke, live=not args.no_live))


if __name__ == "__main__":
    main()
