"""Benchmark harness entrypoint: one module per paper table/figure plus the
dry-run roofline and kernel micro-bench.

    PYTHONPATH=src python -m benchmarks.run            # everything cheap
    PYTHONPATH=src python -m benchmarks.run --sweep    # + re-run dry-runs

Aggregates the kernel micro-bench artifact and the wire-dtype winner map
into the repo-root ``BENCH_6.json`` perf-trajectory file (the ROADMAP's
measured-trajectory item), runs the chaos recovery bench
(``benchmarks/chaos_bench.py``), which writes ``BENCH_7.json``,
summarizes the static-analysis run (``repro.analysis``) into
``BENCH_8.json``, and closes the measured-rate calibration loop
(``benchmarks/calib_bench.py``), which writes ``BENCH_9.json``, runs
the continuous-batching serving bench (``benchmarks/serving_bench.py``,
``BENCH_10.json``), and finally re-checks every collected BENCH file's
pinned gate via ``benchmarks/trajectory.py`` so a regression in any
prior PR's promised metric fails this run.
Exit code = number of failed paper-claim checks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def write_bench_trajectory(out_dir: str, print_fn=print) -> int:
    """Compose ``BENCH_6.json`` at the repo root from the per-module
    artifacts under ``out_dir``; returns 1 if an input is missing."""
    kb_path = os.path.join(out_dir, "kernel_bench.json")
    wire_path = os.path.join(out_dir, "topology_sweep_wire_smoke.json")
    try:
        with open(kb_path) as f:
            kb = json.load(f)
        with open(wire_path) as f:
            wire = json.load(f)
    except OSError as e:
        print_fn(f"CLAIM-FAIL: BENCH_6.json inputs missing ({e})")
        return 1
    entries = wire["entries"]
    int8_cells = [
        {k: e[k] for k in ("kind", "n", "mix", "model", "regime")}
        | {"key": e["winner"]["key"], "tflops": e["winner"]["tflops"]}
        for e in entries
        if (e["winner"] or {}).get("wire_dtype") == "int8"]
    bench = {
        "pr": 6,
        "source": "benchmarks/run.py",
        "backend": kb["backend"],
        "kernels": kb["kernels"],
        "kernel_ratios": kb["ratios"],
        "kernel_numerics": kb["numerics"],
        "wire_sweep": {
            "mode": wire["mode"],
            "wire_dtypes": wire["wire_dtypes"],
            "n_cells": len(entries),
            "n_int8_winners": len(int8_cells),
            "int8_cells": int8_cells,
        },
    }
    path = os.path.join(_ROOT, "BENCH_6.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    print_fn(f"wrote {path} ({len(int8_cells)}/{len(entries)} int8-wire "
             f"winner cells)")
    return 0


def write_analysis_trajectory(report_path: str = None,
                              print_fn=print) -> int:
    """Compose ``BENCH_8.json`` at the repo root from the static-analysis
    JSON report (the CI ``static-analysis`` job writes one); runs the
    analyzer in-process when no report file exists yet.  Returns the
    analyzer's exit code: 1 when any finding is not baselined."""
    report = None
    if report_path and os.path.exists(report_path):
        with open(report_path) as f:
            report = json.load(f)
    if report is None:
        import contextlib
        import io
        from repro.analysis.__main__ import main as analysis_main
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            analysis_main(["--format", "json"])
        report = json.loads(buf.getvalue())
    bench = {
        "pr": 8,
        "source": "benchmarks/run.py",
        "passes": report["passes"],
        "summary": report["summary"],
        "exit_code": report["exit_code"],
    }
    path = os.path.join(_ROOT, "BENCH_8.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    s = report["summary"]
    if report["exit_code"]:
        print_fn(f"CLAIM-FAIL: static analysis has {s['new']} "
                 f"non-baselined finding(s)")
    print_fn(f"wrote {path} ({s['total']} finding(s), "
             f"{s['baselined']} baselined, over "
             f"{len(report['passes'])} passes)")
    return report["exit_code"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="also (re)run the 40-combo dry-run sweep "
                         "(~4 min single-pod + ~4 min multi-pod)")
    args = ap.parse_args()

    import benchmarks.kernel_bench as kernel_bench
    import benchmarks.latency_sweep as latency_sweep
    import benchmarks.paper_alg1 as paper_alg1
    import benchmarks.paper_figs as paper_figs
    import benchmarks.paper_table2 as paper_table2
    import benchmarks.roofline_table as roofline_table
    import benchmarks.topology_sweep as topology_sweep

    n_fail = 0
    for name, mod in (("paper_figs (Figs 3-7)", paper_figs),
                      ("paper_table2 (Table II)", paper_table2),
                      ("paper_alg1 (Algorithm 1)", paper_alg1),
                      ("kernel_bench", kernel_bench)):
        print(f"\n===== {name} =====")
        n_fail += mod.run()

    print("\n===== topology_sweep (winner maps, smoke) =====")
    n_fail += topology_sweep.run(smoke=True)
    print("\n===== topology_sweep (extended technique pool, smoke) =====")
    n_fail += topology_sweep.run(smoke=True, techniques="all")
    print("\n===== topology_sweep (fp32/bf16/int8 wire pool, smoke) =====")
    n_fail += topology_sweep.run(smoke=True, wire=True)
    print("\n===== latency_sweep (Fig.5-style curves, smoke) =====")
    n_fail += latency_sweep.run(smoke=True)

    print("\n===== BENCH_6.json (perf trajectory) =====")
    n_fail += write_bench_trajectory(
        os.path.join(_ROOT, "benchmarks", "out"))

    print("\n===== BENCH_8.json (static-analysis trajectory) =====")
    n_fail += write_analysis_trajectory()

    print("\n===== chaos_bench (elastic recovery, smoke) =====")
    import benchmarks.chaos_bench as chaos_bench
    n_fail += chaos_bench.run(smoke=True)

    print("\n===== calib_bench (BENCH_9.json, profile->refit loop) =====")
    import benchmarks.calib_bench as calib_bench
    n_fail += calib_bench.run()

    print("\n===== serving_bench (BENCH_10.json, smoke) =====")
    import benchmarks.serving_bench as serving_bench
    n_fail += serving_bench.run(smoke=True)

    print("\n===== trajectory (BENCH_6.. gate re-check) =====")
    import benchmarks.trajectory as trajectory
    n_fail += trajectory.run()

    if args.sweep:
        import subprocess
        for extra in ([], ["--multi-pod"]):
            rc = subprocess.call([sys.executable, "-m",
                                  "benchmarks.dryrun_sweep", *extra])
            n_fail += rc != 0

    for mesh in ("single", "multi"):
        print(f"\n===== roofline ({mesh}) =====")
        n_fail += roofline_table.run(mesh=mesh)

    print(f"\nTOTAL claim/bench failures: {n_fail}")
    sys.exit(n_fail)


if __name__ == "__main__":
    main()
