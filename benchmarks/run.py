"""Benchmark harness entrypoint: one module per paper table/figure plus the
dry-run roofline and kernel micro-bench.

    PYTHONPATH=src python -m benchmarks.run            # everything cheap
    PYTHONPATH=src python -m benchmarks.run --sweep    # + re-run dry-runs

Exit code = number of failed paper-claim checks.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="also (re)run the 40-combo dry-run sweep "
                         "(~4 min single-pod + ~4 min multi-pod)")
    args = ap.parse_args()

    import benchmarks.kernel_bench as kernel_bench
    import benchmarks.latency_sweep as latency_sweep
    import benchmarks.paper_alg1 as paper_alg1
    import benchmarks.paper_figs as paper_figs
    import benchmarks.paper_table2 as paper_table2
    import benchmarks.roofline_table as roofline_table
    import benchmarks.topology_sweep as topology_sweep

    n_fail = 0
    for name, mod in (("paper_figs (Figs 3-7)", paper_figs),
                      ("paper_table2 (Table II)", paper_table2),
                      ("paper_alg1 (Algorithm 1)", paper_alg1),
                      ("kernel_bench", kernel_bench)):
        print(f"\n===== {name} =====")
        n_fail += mod.run()

    print("\n===== topology_sweep (winner maps, smoke) =====")
    n_fail += topology_sweep.run(smoke=True)
    print("\n===== topology_sweep (extended technique pool, smoke) =====")
    n_fail += topology_sweep.run(smoke=True, techniques="all")
    print("\n===== latency_sweep (Fig.5-style curves, smoke) =====")
    n_fail += latency_sweep.run(smoke=True)

    if args.sweep:
        import subprocess
        for extra in ([], ["--multi-pod"]):
            rc = subprocess.call([sys.executable, "-m",
                                  "benchmarks.dryrun_sweep", *extra])
            n_fail += rc != 0

    for mesh in ("single", "multi"):
        print(f"\n===== roofline ({mesh}) =====")
        n_fail += roofline_table.run(mesh=mesh)

    print(f"\nTOTAL claim/bench failures: {n_fail}")
    sys.exit(n_fail)


if __name__ == "__main__":
    main()
