"""Benchmark: Algorithm 1 (paper §IV-H) — technique selection per cluster,
checked against the winner/only-survivor reported in each paper figure.

Selections run through the generalized ``core.search.PlanSearch`` path
(Algorithm 1 is its N=2 special case); the legacy ``select_technique``
wrapper is cross-checked to agree on every entry."""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.costmodel import PAPER_CLUSTERS, paper_workload
from repro.core.search import PlanSearch
from repro.core.selector import CostModelProber, select_technique

# (cluster, model) -> acceptable selections given the paper's results
PAPER_EXPECTED = {
    ("TACC-TACC", "gpt2m"): {("data", (0,))},       # C3: 2 RTX data wins
    ("TACC-TACC", "gpt2L"): {("zero2", (0, 1))},    # only survivor
    ("UTAH-GPN", "gpt2m"): {("data", (0,))},        # 18 min vs 26
    ("UTAH-GPN", "gpt2L"): {("zero2", (0, 1))},     # only survivor
    ("UTAH-MASS", "gpt2m"): {("data", (0,)), ("data", (1,))},
    ("UTAH-MASS", "gpt2L"): {("pipeshard", (0, 1))},
    ("BRIS-STAR", "gpt2m"): {("data", (0,))},       # 2 A30 data best
    ("BRIS-STAR", "gpt2L"): {("pipeshard", (0, 1))},  # only survivor
    ("GAT-AMST", "gpt2m"): {("data", (0,)), ("shard", (0,)),
                            ("data", (1,)), ("shard", (1,))},
    ("GAT-AMST", "gpt2L"): {("pipeshard", (0, 1))},   # only survivor
}


def run(print_fn=print) -> int:
    n_fail = 0
    print_fn("# Algorithm 1 selections (via PlanSearch)")
    print_fn("cluster,model,selected,vms,matches_paper,wrapper_agrees")
    for (cname, mname), expected in PAPER_EXPECTED.items():
        wl = paper_workload(get_config(mname))
        cluster = PAPER_CLUSTERS[cname]
        sel = PlanSearch.for_cluster(wl, cluster).select(delta=0.1)
        key = (sel.technique, tuple(sel.vms) if sel.vms else None)
        ok = key in expected
        legacy = select_technique(CostModelProber(wl, cluster), delta=0.1)
        agrees = (legacy.technique, legacy.vms) == (sel.technique, sel.vms)
        n_fail += (not ok) + (not agrees)
        print_fn(f"{cname},{mname},{sel.technique},"
                 f"{'+'.join(map(str, sel.vms or []))},{ok},{agrees}")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(run())
