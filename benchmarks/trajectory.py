"""Perf-trajectory collector: every repo-root ``BENCH_<N>.json`` (one
per perf PR, 6 onward) folded into a single per-PR history table, with
each file's *gated* metrics re-checked so a regression in any PR's
pinned claim fails the newest run loudly.

The gate registry below is the authoritative list of what each BENCH
file promised when it landed:

  6  — the int8 wire sweep produced at least one int8-wire winner cell
  7  — the chaos sweep's live recovery gate passed (all checks true)
  8  — the static-analysis run exited 0 (no non-baselined findings)
  9  — the calibration closed loop tightened: refit error < analytic
  10 — continuous-batching goodput >= 2x fixed on the mixed trace,
       live greedy tokens bit-exact, and the placement winner map keeps
       the far site local while the LAN pair shares a replica

Emits ``benchmarks/out/trajectory.{json,md}``.  Exit code = number of
gate failures across all collected files (a missing file is skipped
with a warning, not failed — older artifacts regenerate via
``benchmarks/run.py``).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Callable, Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.sweep_common import write_outputs


def _gate_6(d: dict) -> Tuple[bool, str]:
    n = d["wire_sweep"]["n_int8_winners"]
    return n >= 1, f"{n} int8-wire winner cell(s)"


def _gate_7(d: dict) -> Tuple[bool, str]:
    live = d["chaos"].get("live_gate") or {}
    ok = bool(live.get("ok"))
    bad = [k for k, v in (live.get("checks") or {}).items() if not v]
    return ok, "recovery checks all pass" if ok else f"failed: {bad}"


def _gate_8(d: dict) -> Tuple[bool, str]:
    code = d["exit_code"]
    return code == 0, f"analysis exit_code {code}"


def _gate_9(d: dict) -> Tuple[bool, str]:
    err = d["closed_loop"]["search_vs_measured_error"]
    ok = err["after"] < err["before"]
    return ok, f"refit error {err['after']} vs analytic {err['before']}"


def _gate_10(d: dict) -> Tuple[bool, str]:
    g = d["gates"]
    need = ("goodput_ratio_ge_2", "bit_exact", "far_site_local",
            "lan_pair_shared")
    bad = [k for k in need if g.get(k) is not True]
    ratio = d["trace"]["overload"]["goodput_tok_s"]["ratio"]
    return not bad, f"goodput x{ratio}" if not bad else f"failed: {bad}"


#: pr number -> (gate_fn, short metric description for the table)
GATES: Dict[int, Tuple[Callable[[dict], Tuple[bool, str]], str]] = {
    6: (_gate_6, "int8 wire winners >= 1"),
    7: (_gate_7, "chaos live recovery ok"),
    8: (_gate_8, "static analysis clean"),
    9: (_gate_9, "calib refit < analytic err"),
    10: (_gate_10, "serving goodput >= 2x + bit-exact + winner map"),
}


def collect(root: str = _ROOT, print_fn=print) -> Tuple[List[dict], int]:
    """Check every registered BENCH file; returns (rows, n_fail)."""
    rows: List[dict] = []
    n_fail = 0
    for pr in sorted(GATES):
        gate_fn, desc = GATES[pr]
        path = os.path.join(root, f"BENCH_{pr}.json")
        if not os.path.exists(path):
            print_fn(f"trajectory: BENCH_{pr}.json missing — skipped "
                     f"(regenerate via benchmarks/run.py)")
            rows.append({"pr": pr, "gate": desc, "ok": None,
                         "detail": "missing"})
            continue
        with open(path) as f:
            d = json.load(f)
        try:
            ok, detail = gate_fn(d)
        except (KeyError, TypeError) as e:
            ok, detail = False, f"malformed ({e!r})"
        rows.append({"pr": pr, "gate": desc, "ok": ok, "detail": detail,
                     "source": d.get("source", "?")})
        if not ok:
            n_fail += 1
            print_fn(f"TRAJECTORY-FAIL: BENCH_{pr}.json — {desc}: {detail}")
    return rows, n_fail


def run(print_fn=print) -> int:
    rows, n_fail = collect(print_fn=print_fn)
    mark = {True: "pass", False: "FAIL", None: "—"}
    md = ["# Perf trajectory (BENCH_6..)", "",
          "| PR | gated metric | status | detail |",
          "|---:|---|---|---|"]
    for r in rows:
        md.append(f"| {r['pr']} | {r['gate']} | {mark[r['ok']]} "
                  f"| {r['detail']} |")
        print_fn(f"  PR {r['pr']:>2} [{mark[r['ok']]:>4}] "
                 f"{r['gate']}: {r['detail']}")
    record = {"rows": rows, "n_fail": n_fail}
    write_outputs(os.path.join(_ROOT, "benchmarks", "out"), "trajectory",
                  record, "\n".join(md) + "\n", print_fn=print_fn)
    return n_fail


def main() -> None:
    sys.exit(run())


if __name__ == "__main__":
    main()
