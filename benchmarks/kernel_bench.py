"""Benchmark: kernel micro-bench (interpret mode on CPU — correctness-path
timing only; TPU wall-times come from deployment).  Emits
name,us_per_call,derived CSV per the harness convention."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(print_fn=print) -> int:
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    print_fn("name,us_per_call,derived")

    B, S, H, KV, D = 1, 128, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    t_kernel = _time(lambda *a: ops.flash_attention(
        *a, causal=True, block_q=64, block_k=64, interpret=True), q, k, v)
    flops = 4 * B * S * S * H * D
    print_fn(f"flash_attention_interp_{S},{t_kernel:.0f},"
             f"{flops / t_kernel / 1e6:.3f}GFLOPs_equiv")

    B, S, nh, hd, ds = 1, 256, 2, 32, 16
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    bs = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    cs = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, (nh,)), jnp.float32)
    t_ssd = _time(lambda *x: ops.ssd_scan(*x, chunk=64, interpret=True),
                  xh, dt, bs, cs, a)
    t_ref = _time(lambda *x: ref.ssd_ref(
        x[0].transpose(0, 2, 1, 3), x[1].transpose(0, 2, 1), *x[2:]),
        xh, dt, bs, cs, a)
    print_fn(f"ssd_scan_interp_{S},{t_ssd:.0f},vs_ref_{t_ref:.0f}us")
    return 0


if __name__ == "__main__":
    raise SystemExit(run())
