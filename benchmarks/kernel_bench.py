"""Benchmark: kernel micro-bench (interpret mode on CPU — correctness-path
timing only; TPU wall-times come from deployment).  Emits
name,us_per_call,derived CSV per the harness convention plus a
machine-readable ``benchmarks/out/kernel_bench.json`` artifact with
per-kernel us/call, GFLOP/s-equivalent throughput, and fp32 vs int8
ratios — ``benchmarks/run.py`` aggregates it into the repo-root
``BENCH_6.json`` perf-trajectory file.  Fails (non-zero return) when an
int8 kernel drifts from its fp32 reference, so the timing rows can
never outlive the numerics they claim to measure."""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sweep_common import md_table, write_outputs

# pinned operand PRNG seed — surfaced in every drift-failure message so
# a reported numerics break is reproducible from the message alone
SEED = 0


def drift_fail_message(kernel: str, metric: str, measured: float,
                       op: str, threshold: float) -> str:
    """The standardized numerics-drift failure line: names the kernel,
    the measured-vs-threshold comparison, and the pinned operand seed
    (tests/test_kernel_bench.py pins the format)."""
    return (f"CLAIM-FAIL[{kernel}]: {metric} {measured:.6g} {op} "
            f"threshold {threshold:g} (seed={SEED}) — timings above "
            f"measure a broken kernel")


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(print_fn=print, out: str | None = None) -> int:
    from repro.kernels import ops, ref
    rng = np.random.default_rng(SEED)
    n_fail = 0
    kernels: dict = {}
    print_fn("name,us_per_call,derived")

    # -- attention: fp32 flash kernel vs the int8-KV variant ------------
    B, S, H, KV, D = 1, 128, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    t_fp = _time(lambda *a: ops.flash_attention(
        *a, causal=True, block_q=64, block_k=64, interpret=True), q, k, v)
    flops = 4 * B * S * S * H * D
    print_fn(f"flash_attention_interp_{S},{t_fp:.0f},"
             f"{flops / t_fp / 1e6:.3f}GFLOPs_equiv")
    kernels["flash_attention_fp32"] = {
        "shape": [B, S, H, KV, D], "us_per_call": round(t_fp, 1),
        "gflops_equiv": round(flops / t_fp / 1e3, 4)}

    kq, ks = ops.quantize(k, block=D, axis=-1)
    vq, vs = ops.quantize(v, block=D, axis=-1)
    ks, vs = ks[..., 0], vs[..., 0]
    t_i8 = _time(lambda *a: ops.flash_attention_int8kv(
        *a, causal=True, block_q=64, block_k=64, interpret=True),
        q, kq, ks, vq, vs)
    print_fn(f"flash_attention_int8kv_interp_{S},{t_i8:.0f},"
             f"fp32_ratio_{t_fp / t_i8:.2f}x")
    kernels["flash_attention_int8kv"] = {
        "shape": [B, S, H, KV, D], "us_per_call": round(t_i8, 1),
        "gflops_equiv": round(flops / t_i8 / 1e3, 4),
        "speedup_vs_fp32": round(t_fp / t_i8, 3)}
    o_fp = ops.flash_attention(q, k, v, causal=True, block_q=64,
                               block_k=64, interpret=True)
    o_i8 = ops.flash_attention_int8kv(q, kq, ks, vq, vs, causal=True,
                                      block_q=64, block_k=64,
                                      interpret=True)
    cos = float(jnp.sum(o_fp * o_i8) / jnp.maximum(
        jnp.linalg.norm(o_fp) * jnp.linalg.norm(o_i8), 1e-9))
    if cos < 0.999:
        n_fail += 1
        print_fn(drift_fail_message("flash_attention_int8kv",
                                    "cosine vs fp32 flash", cos,
                                    "<", 0.999))

    # -- matmul: jnp fp32 vs the int8 blocked-quantized kernel ----------
    M, K, N = 256, 256, 256
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    mm_fp = jax.jit(jnp.matmul)
    t_mm = _time(mm_fp, x, w)
    mm_flops = 2 * M * K * N
    print_fn(f"matmul_fp32_{M},{t_mm:.0f},"
             f"{mm_flops / t_mm / 1e6:.3f}GFLOPs_equiv")
    kernels["matmul_fp32"] = {
        "shape": [M, K, N], "us_per_call": round(t_mm, 1),
        "gflops_equiv": round(mm_flops / t_mm / 1e3, 4)}
    t_q = _time(lambda *a: ops.int8_matmul(
        *a, block_m=128, block_k=128, block_n=128, interpret=True), x, w)
    print_fn(f"int8_matmul_interp_{M},{t_q:.0f},"
             f"fp32_ratio_{t_mm / t_q:.2f}x")
    kernels["int8_matmul"] = {
        "shape": [M, K, N], "us_per_call": round(t_q, 1),
        "gflops_equiv": round(mm_flops / t_q / 1e3, 4),
        "speedup_vs_fp32": round(t_mm / t_q, 3)}
    y_fp = mm_fp(x, w)
    y_q = ops.int8_matmul(x, w, block_m=128, block_k=128, block_n=128,
                          interpret=True)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    if rel > 0.02:
        n_fail += 1
        print_fn(drift_fail_message("int8_matmul", "rel error vs fp32",
                                    rel, ">", 0.02))

    # -- SSD scan vs the dense reference --------------------------------
    B, S, nh, hd, ds = 1, 256, 2, 32, 16
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    bs = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    cs = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, (nh,)), jnp.float32)
    t_ssd = _time(lambda *x_: ops.ssd_scan(*x_, chunk=64, interpret=True),
                  xh, dt, bs, cs, a)
    t_ref = _time(lambda *x_: ref.ssd_ref(
        x_[0].transpose(0, 2, 1, 3), x_[1].transpose(0, 2, 1), *x_[2:]),
        xh, dt, bs, cs, a)
    print_fn(f"ssd_scan_interp_{S},{t_ssd:.0f},vs_ref_{t_ref:.0f}us")
    kernels["ssd_scan"] = {
        "shape": [B, S, nh, hd, ds], "us_per_call": round(t_ssd, 1),
        "ref_us_per_call": round(t_ref, 1)}

    record = {
        "backend": jax.default_backend(), "interpret": True, "iters": 3,
        "seed": SEED,
        "kernels": kernels,
        "ratios": {
            "flash_attention_int8kv_vs_fp32": round(t_fp / t_i8, 3),
            "int8_matmul_vs_fp32": round(t_mm / t_q, 3)},
        "numerics": {"int8kv_cosine": round(cos, 6),
                     "int8_matmul_rel_err": round(rel, 6)},
    }
    rows = [[name, f"{r['us_per_call']:.0f}",
             f"{r.get('gflops_equiv', '-')}",
             f"{r['speedup_vs_fp32']:.2f}x" if "speedup_vs_fp32" in r
             else "-"]
            for name, r in kernels.items()]
    md = ("# Kernel micro-bench (interpret mode)\n\n"
          "Correctness-path timings on the CPU interpreter — relative "
          "numbers only; TPU wall-times come from deployment.\n\n"
          + md_table(["kernel", "us/call", "GFLOP/s equiv",
                      "speedup vs fp32"], rows))
    if out is None:
        out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "out")
    write_outputs(out, "kernel_bench", record, md, print_fn=print_fn)
    return n_fail


if __name__ == "__main__":
    raise SystemExit(run())
