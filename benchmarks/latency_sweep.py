"""Benchmark: Fig. 5-style degradation curves over an N-site topology.

Sweeps ONE edge's latency while every other edge stays metro-local and
tracks what the pruned ``core.search.PlanSearch`` picks at each point —
the N-site analogue of the paper's latency-degradation figure:

    PYTHONPATH=src python benchmarks/latency_sweep.py --smoke
    PYTHONPATH=src python benchmarks/latency_sweep.py                # line4
    PYTHONPATH=src python benchmarks/latency_sweep.py --kind ring

Two machine-checked findings come out of the default configs
(docs/benchmarks.md):

  * ``line`` (swept middle edge — the pipeline MUST cross it): the
    winner flips data@all → pipeshard@all → data on the cheap pair as
    latency grows; the flip points are reported.
  * ``ring`` (swept closing edge): all-sites Pipeshard is *immune* —
    a ring minus one edge is still a Hamiltonian path, so the search
    routes the pipeline around the dear edge and its TFLOP/s stays flat
    while every collective plan spanning the edge collapses.
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.sweep_common import (GPU_MIXES, LATENCY_REGIMES, WAN_GBPS,
                                     md_table, mix_sites, write_outputs)
from repro.configs import get_config
from repro.core.costmodel import avg_tflops, paper_workload
from repro.core.search import PlanSearch
from repro.core.topology import Link, Topology, line, make_topology, ring

METRO_MS = LATENCY_REGIMES["metro"]  # the paper's TACC-TACC RTT


def swept_topology(kind: str, n: int, mix_name: str,
                   lat_ms: float) -> Topology:
    """`kind` topology with one swept edge: the middle edge of a line
    (every all-sites pipeline crosses it), the closing edge of a ring
    (a pipeline can route around it)."""
    sites = mix_sites(n, GPU_MIXES[mix_name])
    metro = Link(METRO_MS * 1e-3, WAN_GBPS)
    swept = Link(lat_ms * 1e-3, WAN_GBPS)
    name = f"{kind}{n}-{mix_name}-swept"
    if kind == "line":
        links = [metro] * (n - 1)
        links[(n - 1) // 2] = swept
        return line(name, sites, links)
    if kind == "ring":
        links = [metro] * n
        links[n - 1] = swept         # edge (n-1, 0)
        return ring(name, sites, links)
    raise ValueError(f"latency sweep supports line/ring, not {kind!r}")


def sweep_point(lat_ms: float, *, kind: str, n: int, mix: str,
                wl, balance: str) -> dict:
    """Winner + reference series at one swept-edge latency."""
    topo = swept_topology(kind, n, mix, lat_ms)
    search = PlanSearch(wl, topo, stage_balance=balance)
    ranked = search.search()
    best = ranked[0] if ranked and ranked[0].feasible else None
    pipe_all = max((s.tflops for s in ranked
                    if s.candidate.technique == "pipeshard"
                    and len(s.candidate.sites) == n and s.feasible),
                   default=None)
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    data_pair = max((avg_tflops("data", wl, topo, [i, j]) or 0.0
                     for i, j in pairs), default=0.0) or None
    data_all = avg_tflops("data", wl, topo)
    single = max((avg_tflops(t, wl, topo, [i]) or 0.0
                  for i in range(n)
                  for t in ("data", "shard", "zero2")), default=0.0) or None
    return {
        "latency_ms": lat_ms,
        "winner": None if best is None else {
            "key": best.candidate.key,
            "technique": best.candidate.technique,
            "sites": list(best.candidate.sites),
            "tflops": round(best.tflops, 4)},
        "pipeshard_all": None if pipe_all is None else round(pipe_all, 4),
        "data_all": None if data_all is None else round(data_all, 4),
        "data_best_pair": None if data_pair is None else round(data_pair, 4),
        "best_single_site": None if single is None else round(single, 4),
    }


def latencies(points: int, lo_ms: float = 0.1,
              hi_ms: float = 200.0) -> List[float]:
    """Log-spaced swept-edge RTTs covering Table I and beyond."""
    if points == 1:
        return [lo_ms]
    r = math.log(hi_ms / lo_ms) / (points - 1)
    return [round(lo_ms * math.exp(r * k), 3) for k in range(points)]


def find_flips(rows: List[dict]) -> List[dict]:
    """Winner changes along the sweep, as (latency interval, from, to)."""
    flips = []
    for prev, cur in zip(rows[:-1], rows[1:]):
        a = (prev["winner"] or {}).get("key")
        b = (cur["winner"] or {}).get("key")
        if a != b:
            flips.append({"from": a, "to": b,
                          "between_ms": [prev["latency_ms"],
                                         cur["latency_ms"]]})
    return flips


def check_claims(rows: List[dict], flips: List[dict], kind: str,
                 n: int) -> List[str]:
    """The two machine-checked findings of the default configs."""
    failures = []
    winners = [(r["winner"] or {}).get("key", "") for r in rows]
    if kind == "line":
        # pipeshard-on-all-sites must win somewhere in the mid-range ...
        pipe_wins = [w.startswith("pipeshard@") and
                     w.count("+") == n - 1 for w in winners]
        if not any(pipe_wins):
            failures.append("line: all-sites pipeshard never wins")
        # ... and the search must flip to a 2-site data plan at the tail
        last = winners[-1]
        if not (last.startswith("data@") and last.count("+") == 1):
            failures.append(f"line: no flip to cheap-pair data "
                            f"(final winner {last})")
    if kind == "ring":
        # routing immunity: pipeshard@all TFLOP/s flat across the sweep
        pa = [r["pipeshard_all"] for r in rows
              if r["pipeshard_all"] is not None]
        if pa and (max(pa) - min(pa)) / max(pa) > 0.01:
            failures.append(f"ring: pipeshard@all not flat "
                            f"({min(pa):.2f}..{max(pa):.2f} TFLOP/s)")
    return failures


def to_markdown(rows: List[dict], flips: List[dict], *, kind: str, n: int,
                mix: str, model: str, balance: str) -> str:
    out = [f"# Latency sweep: {kind}{n} / {mix} / {model}", "",
           f"One {'middle' if kind == 'line' else 'closing'} edge swept; "
           f"all other edges at {METRO_MS} ms.  TFLOP/s per series, "
           f"`stage_balance={balance!r}`.", ""]
    headers = ["swept RTT (ms)", "winner", "winner TF", "pipeshard@all",
               "data@all", "best data@pair", "best single site"]
    fmt = lambda v: "-" if v is None else f"{v:.2f}"
    body = []
    for r in rows:
        w = r["winner"]
        body.append([f"{r['latency_ms']:g}",
                     "OOM" if w is None else w["key"],
                     "-" if w is None else f"{w['tflops']:.2f}",
                     fmt(r["pipeshard_all"]), fmt(r["data_all"]),
                     fmt(r["data_best_pair"]), fmt(r["best_single_site"])])
    out.append(md_table(headers, body))
    out.append("\n## Winner flips\n")
    if not flips:
        out.append("(none — one plan wins across the whole sweep)\n")
    for f in flips:
        lo, hi = f["between_ms"]
        out.append(f"- `{f['from']}` → `{f['to']}` between {lo:g} ms "
                   f"and {hi:g} ms\n")
    return "\n".join(out)


def run(*, smoke: bool = False, out: Optional[str] = None,
        kind: str = "line", n: int = 4, mix: str = "a30",
        model: str = "gpt2m", balance: str = "tflops",
        points: Optional[int] = None, print_fn=print) -> int:
    """Run the sweep; returns the number of failed claim checks."""
    npts = points if points is not None else (5 if smoke else 13)
    wl = paper_workload(get_config(model))
    t0 = time.perf_counter()
    rows = [sweep_point(lat, kind=kind, n=n, mix=mix, wl=wl,
                        balance=balance)
            for lat in latencies(npts)]
    elapsed = time.perf_counter() - t0
    flips = find_flips(rows)
    failures = check_claims(rows, flips, kind, n)
    md = to_markdown(rows, flips, kind=kind, n=n, mix=mix, model=model,
                     balance=balance)
    mode = "smoke" if smoke else "full"
    record = {"mode": mode, "kind": kind, "n": n, "mix": mix,
              "model": model, "balance": balance,
              "elapsed_s": round(elapsed, 2), "points": rows,
              "flips": flips}
    if out is None:
        out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "out")
    write_outputs(out, f"latency_sweep_{kind}{n}_{mode}", record, md,
                  print_fn=print_fn)
    for line_ in md.splitlines():
        print_fn(line_)
    for f in failures:
        print_fn(f"CLAIM-FAIL: {f}")
    return len(failures)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="5 sweep points instead of 13")
    ap.add_argument("--out", default=None,
                    help="output dir (default: benchmarks/out)")
    ap.add_argument("--kind", choices=("line", "ring"), default="line")
    ap.add_argument("--n", type=int, default=4, help="number of sites")
    ap.add_argument("--mix", choices=sorted(GPU_MIXES), default="a30")
    ap.add_argument("--model", default="gpt2m")
    ap.add_argument("--balance", choices=("even", "tflops"),
                    default="tflops")
    ap.add_argument("--points", type=int, default=None)
    args = ap.parse_args(argv)
    return run(smoke=args.smoke, out=args.out, kind=args.kind, n=args.n,
               mix=args.mix, model=args.model, balance=args.balance,
               points=args.points)


if __name__ == "__main__":
    raise SystemExit(main())
