"""Benchmark: roofline table from the dry-run sweep results
(results/dryrun/*.json) — §Roofline of EXPERIMENTS.md is generated from
this module's output."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")

ARCH_ORDER = [
    "minicpm3-4b", "phi-3-vision-4.2b", "phi3.5-moe-42b-a6.6b",
    "falcon-mamba-7b", "zamba2-2.7b", "llama3-405b", "phi4-mini-3.8b",
    "whisper-small", "deepseek-v2-236b", "llama3.2-3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "single") -> List[Dict]:
    recs = []
    for path in glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json")):
        with open(path) as f:
            recs.append(json.load(f))
    key = lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER
                     else 99, SHAPE_ORDER.index(r["shape"])
                     if r["shape"] in SHAPE_ORDER else 9)
    return sorted(recs, key=key)


def fmt_row(r: Dict) -> str:
    if r["status"] == "skip":
        return (f"{r['arch']},{r['shape']},{r.get('plan', '-')},SKIP,,,,,,"
                f"\"{r['reason'][:60]}\"")
    if r["status"] != "ok":
        return f"{r['arch']},{r['shape']},{r.get('plan', '-')},FAIL,,,,,," \
               f"\"{r.get('error', '')[:60]}\""
    frac = r["useful_flops_fraction"]
    return (f"{r['arch']},{r['shape']},{r['plan']},ok,"
            f"{r['compute_s'] * 1e3:.2f},{r['memory_s'] * 1e3:.2f},"
            f"{r['collective_s'] * 1e3:.2f},{r['dominant']},"
            f"{frac:.2f},{r['memory_per_device_bytes'] / 1e9:.2f}")


def run(print_fn=print, mesh: str = "single") -> int:
    recs = load(mesh)
    print_fn(f"# Roofline table ({mesh}-pod mesh, per step, per device)")
    print_fn("arch,shape,plan,status,compute_ms,memory_ms,collective_ms,"
             "dominant,useful_flops_frac,mem_gb_per_dev")
    n_fail = 0
    for r in recs:
        print_fn(fmt_row(r))
        n_fail += r["status"] == "fail"
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print_fn(f"# dominant-term histogram: {doms}; "
                 f"{len(ok)} ok / {len(recs)} total")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(run())
