"""Chaos benchmark: kill-site-at-step-k recovery over the topology zoo.

Two layers (docs/elasticity.md):

  * **analytic sweep** — for every (kind, N, mix) zoo cell and every
    kill target, drop the site and re-run the plan search over the
    survivors (``repro.train.replan.replan``): records the surviving
    technique, the TFLOP/s before/after, the search wall-clock, and the
    steps-lost-to-checkpoint accounting.  Gates: every degraded cell
    must still have a feasible plan, and severed-line kills must place
    within a single component.
  * **live gate** — the pinned recovery scenario runs for real in a
    subprocess (``repro.launch.reshard_check --chaos``): one site of a
    two-site Pipeshard run is killed mid-epoch; the replan must land on
    the survivor, the resharded optimizer state must be bit-exact vs
    the host-side reference re-placement, and the resumed loss sequence
    must match the single-site control exactly.  Recovery seconds are
    recorded against the pre-failure step-time budget as a metric (not
    a wall-clock gate — CI boxes jitter).

Emits ``benchmarks/out/chaos_bench.json`` and the repo-root
``BENCH_7.json`` perf-trajectory file (PR-6's ``BENCH_6.json`` format
family).  Exit code = number of failed gates.

    PYTHONPATH=src python -m benchmarks.chaos_bench --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.sweep_common import (LATENCY_REGIMES, TOPOLOGY_KINDS,
                                     build_topology)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "out")

KILL_STEP = 7          # analytic accounting: failure step ...
CKPT_EVERY = 2         # ... against this checkpoint cadence
STEPS_LOST = KILL_STEP % CKPT_EVERY


def analytic_scenarios(smoke: bool) -> List[Dict]:
    """Kill each site of each zoo cell and replan the survivors."""
    from repro.configs import get_config
    from repro.core.costmodel import paper_workload
    from repro.core.search import PlanSearch
    from repro.train.replan import replan

    kinds = ("ring", "line") if smoke else TOPOLOGY_KINDS
    ns = (2, 3) if smoke else (2, 3, 4)
    mixes = ("a30", "a30+t4") if smoke else ("a30", "a30+t4", "rtx+t4")
    regimes = ("regional",) if smoke else ("metro", "regional",
                                           "continental")
    wl = paper_workload(get_config("gpt2m"))
    rows = []
    for kind in kinds:
        for n in ns:
            if kind == "hub" and n < 3:
                continue
            for mix in mixes:
                for regime in regimes:
                    topo = build_topology(kind, n, mix,
                                          LATENCY_REGIMES[regime])
                    before = PlanSearch(wl, topo,
                                        stage_balance="tflops").best()
                    for dead in range(n):
                        row = {"kind": kind, "n": n, "mix": mix,
                               "regime": regime, "dead": dead,
                               "tflops_before":
                                   round(before.tflops, 2) if before
                                   else None,
                               "kill_step": KILL_STEP,
                               "ckpt_every": CKPT_EVERY,
                               "steps_lost": STEPS_LOST}
                        t0 = time.perf_counter()
                        try:
                            rp = replan(topo, (dead,), wl)
                            survivor, kept = topo.without_sites((dead,))
                            comps = [{kept[s] for s in comp}
                                     for comp in survivor.components()]
                            row |= {
                                "feasible": True,
                                "technique": rp.technique,
                                "sites_old": list(rp.sites_old),
                                "tflops_after": round(rp.tflops, 2),
                                "search_s": round(
                                    time.perf_counter() - t0, 4),
                                "n_components": len(comps),
                                "within_one_component": any(
                                    set(rp.sites_old) <= c
                                    for c in comps),
                            }
                            if before and before.tflops:
                                row["retained_frac"] = round(
                                    rp.tflops / before.tflops, 3)
                                # steps-lost work vs one pre-failure step
                                step_s = wl.flops_per_step / (
                                    before.tflops * 1e12)
                                row["step_time_before_s"] = round(
                                    step_s, 4)
                        except RuntimeError as e:
                            row |= {"feasible": False, "error": str(e)}
                        rows.append(row)
    return rows


def live_gate(print_fn=print) -> Dict:
    """The pinned two-site Pipeshard kill, executed for real."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.reshard_check", "--chaos",
           "--kill-step", "3", "--dead", "1", "--total-steps", "6",
           "--ckpt-every", "2"]
    t0 = time.perf_counter()
    out = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=560, env=env)
    if out.returncode != 0:
        print_fn(f"live gate subprocess failed:\n{out.stderr[-2000:]}")
        return {"ok": False, "error": out.stderr[-500:]}
    res = json.loads([l for l in out.stdout.splitlines()
                      if l.startswith("{")][-1])
    losses = res["losses_pre"] + res["losses_post"]
    pre_times = res.get("losses_pre", [])
    checks = {
        "failed_and_recovered": bool(res["failed"]),
        "single_site_survivor": res["sites_old"] == [0],
        "opt_bitexact": bool(res["opt_bitexact"]),
        "params_bitexact": bool(res["params_bitexact"]),
        "loss_matches_control":
            res["losses_post"] == res["losses_control"],
        "steps_lost_within_cadence": res["steps_lost"] <= 2,
        "losses_finite": all(x == x and abs(x) < 1e9 for x in losses),
    }
    return {
        "ok": all(checks.values()),
        "checks": checks,
        "technique": res["technique"],
        "resumed_from": res["resumed_from"],
        "steps_lost": res["steps_lost"],
        "search_s": round(res["search_s"], 4),
        "reshard_s": round(res["reshard_s"], 4),
        "recovery_s": round(res["recovery_s"], 4),
        "wall_s": round(time.perf_counter() - t0, 1),
        "n_pre_steps": len(pre_times),
    }


def run(smoke: bool = True, live: bool = True, print_fn=print) -> int:
    """Run the chaos bench; returns the number of failed gates."""
    n_fail = 0
    rows = analytic_scenarios(smoke)
    infeasible = [r for r in rows if not r["feasible"]]
    if infeasible:
        n_fail += 1
        print_fn(f"GATE-FAIL: {len(infeasible)} degraded cells with no "
                 f"feasible plan (gpt2m fits everywhere in the zoo)")
    # severed topologies must never place across a partition
    bad_span = [r for r in rows
                if r["feasible"]
                and not r.get("within_one_component", True)]
    if bad_span:
        n_fail += 1
        print_fn(f"GATE-FAIL: {len(bad_span)} replans span a partition")
    retained = [r["retained_frac"] for r in rows
                if r.get("retained_frac")]
    print_fn(f"analytic: {len(rows)} kill scenarios, "
             f"{len(rows) - len(infeasible)} feasible; retained "
             f"throughput {min(retained):.2f}x..{max(retained):.2f}x "
             f"(median {sorted(retained)[len(retained) // 2]:.2f}x)")

    gate: Dict = {"skipped": True}
    if live:
        gate = live_gate(print_fn)
        if not gate.get("ok"):
            n_fail += 1
            print_fn(f"GATE-FAIL: live chaos gate {gate.get('checks')}")
        else:
            print_fn(f"live gate: recovered via {gate['technique']} in "
                     f"{gate['recovery_s']:.2f}s (search "
                     f"{gate['search_s']:.3f}s + reshard "
                     f"{gate['reshard_s']:.2f}s), "
                     f"{gate['steps_lost']} step(s) lost")

    record = {"mode": "smoke" if smoke else "full",
              "kill_step": KILL_STEP, "ckpt_every": CKPT_EVERY,
              "scenarios": rows, "live_gate": gate,
              "n_gate_failures": n_fail}
    os.makedirs(OUT_DIR, exist_ok=True)
    art = os.path.join(OUT_DIR, "chaos_bench.json")
    with open(art, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")
    bench = {
        "pr": 7,
        "source": "benchmarks/chaos_bench.py",
        "chaos": {
            "mode": record["mode"],
            "n_scenarios": len(rows),
            "n_feasible": len(rows) - len(infeasible),
            "retained_frac_min": min(retained) if retained else None,
            "retained_frac_max": max(retained) if retained else None,
            "live_gate": gate,
        },
    }
    path = os.path.join(_ROOT, "BENCH_7.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    print_fn(f"wrote {art} and {path}")
    return n_fail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small zoo slice + the single live gate")
    ap.add_argument("--no-live", action="store_true",
                    help="analytic sweep only (no subprocess training)")
    args = ap.parse_args(argv)
    return run(smoke=args.smoke, live=not args.no_live)


if __name__ == "__main__":
    sys.exit(main())
