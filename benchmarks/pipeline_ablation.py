"""Benchmark: Pipeshard microbatch ablation (paper §III-A: "the training
batch is split into microbatches; forward and backward are pipelined").

Sweeps n_micro for llama3.2-3b × train_4k on the multi-pod mesh and
reports, per choice: the GPipe bubble fraction (n_stages-1)/(n_micro +
n_stages-1) (idle compute), pod-crossing ppermute bytes, and per-device
memory — the bubble-vs-memory tradeoff Alpa's DP solves analytically.

Heavy (one 512-device compile per point): run explicitly via
    PYTHONPATH=src python -m benchmarks.pipeline_ablation
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import json
import sys
import time


def run(print_fn=print, micros=(2, 4, 8, 16)) -> int:
    import jax

    from repro.configs import get_config, get_shape
    from repro.configs.base import TrainConfig
    from repro.core.pipeline import pipeline_mesh
    from repro.core.plans import get_plan
    from repro.launch import roofline as rl
    from repro.launch.dryrun import build_step
    from repro.launch.mesh import make_production_mesh
    from repro.models import Model

    cfg = get_config("llama3.2-3b")
    shape = get_shape("train_4k")
    plan = get_plan("pipeshard")
    n_stages = 2
    print_fn("# Pipeshard microbatch ablation "
             "(llama3.2-3b x train_4k x 2x16x16, 2 stages)")
    print_fn("n_micro,bubble_frac,dcn_gb_per_dev,ici_gb_per_dev,"
             "collective_s,mem_gb_per_dev,compile_s")
    rows = []
    for m in micros:
        base = make_production_mesh(multi_pod=True)
        mesh = pipeline_mesh(base, n_stages)
        model = Model(cfg)
        tcfg = TrainConfig(microbatches=m)
        t0 = time.time()
        with jax.set_mesh(mesh):
            step, args, acost = build_step(model, plan, mesh, cfg, shape,
                                           tcfg)
            compiled = step.lower(*args).compile()
        roof = rl.from_compiled(
            compiled, arch=cfg.name, shape=shape.name, mesh_name="2x16x16",
            plan=f"pipeshard_m{m}", analytic=acost, n_devices=512,
            crosses_pod=True)
        bubble = (n_stages - 1) / (m + n_stages - 1)
        row = dict(n_micro=m, bubble=bubble,
                   dcn_gb=roof.dcn_bytes_per_device / 1e9,
                   ici_gb=roof.collective_bytes_per_device / 1e9,
                   coll_s=roof.collective_s,
                   mem_gb=roof.memory_per_device_bytes / 1e9,
                   compile_s=time.time() - t0)
        rows.append(row)
        print_fn(f"{m},{bubble:.3f},{row['dcn_gb']:.3f},{row['ici_gb']:.2f},"
                 f"{row['coll_s']:.2f},{row['mem_gb']:.2f},"
                 f"{row['compile_s']:.0f}")
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "pipeline_ablation.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(run())
