"""Benchmark: Pipeshard microbatch + schedule ablations.

Two modes:

  * ``--schedules`` (analytic, seconds — the CI gate with ``--smoke``):
    sweeps the microbatch count m for each pipeline schedule (GPipe /
    1F1B / interleaved, docs/schedules.md) over two scenarios and
    machine-checks the schedule claims:

      - **bubble**: gpt2m on a 3-site A30 metro line — the interleaved
        schedule's (S-1)/(v·m) bubble makes it the fastest pipeline at
        small m, and GPipe's m-in-flight stash blows the 24 GB budget
        at large m while 1F1B (min(S, m) in flight) keeps fitting.
      - **memory flip**: gpt2L (batch 52) on a 3-site RTX continental
        line at the paper's m=4 — GPipe OOMs, 1F1B fits, and the
        schedule-aware `PlanSearch` flips the winner from a 2-site Data
        fallback to Pipeshard-on-everything under 1F1B (the ISSUE-4
        acceptance scenario; `tests/test_search.py` pins it too).

    JSON + markdown land in ``benchmarks/out/`` for
    ``tools/render_figs.py``.

  * legacy XLA mode (no flag): sweeps n_micro for llama3.2-3b × train_4k
    on the multi-pod mesh and reports bubble fraction, pod-crossing
    ppermute bytes, and per-device memory per choice.  Heavy — one
    512-device compile per point:

        PYTHONPATH=src python -m benchmarks.pipeline_ablation
"""
import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


# --------------------------------------------------------------------- #
# analytic schedule ablation
# --------------------------------------------------------------------- #

SCHEDS = ("gpipe", "1f1b", "interleaved")


def _schedule_rows(wl_base, topo, micros):
    """Per (m, schedule): bubble, in-flight stash, memory, step time."""
    import dataclasses
    from repro.core.costmodel import (pipeline_bubble_fraction,
                                      pipeline_inflight_microbatches,
                                      technique_step_cost)
    n = topo.n_sites
    rows = []
    for m in micros:
        wl = dataclasses.replace(wl_base, microbatches=m)
        for sched in SCHEDS:
            c = technique_step_cost("pipeshard", wl, topo, schedule=sched)
            rows.append({
                "n_micro": m, "schedule": sched,
                "bubble": round(pipeline_bubble_fraction(sched, n, m), 4),
                "inflight": round(
                    pipeline_inflight_microbatches(sched, n, m), 2),
                "mem_gb": round(c.mem_required_gb, 2),
                "mem_avail_gb": round(c.mem_available_gb, 2),
                "fits": c.fits,
                "step_s": round(c.total_s, 4),
                "tflops": None if not c.fits else round(
                    wl.flops_per_step / c.total_s / 1e12, 2),
            })
    return rows


def _winners(wl_base, topo, micros):
    """Full-search winner per m, legacy (GPipe-only) vs schedule-aware."""
    import dataclasses
    from repro.core.search import PlanSearch
    out = []
    for m in micros:
        wl = dataclasses.replace(wl_base, microbatches=m)
        best = PlanSearch(wl, topo).best()
        legacy = PlanSearch(wl, topo, schedules=("gpipe",)).best()
        out.append({
            "n_micro": m,
            "winner": None if best is None else best.candidate.key,
            "winner_schedule": None if best is None
            else best.candidate.schedule,
            "winner_tflops": None if best is None
            else round(best.tflops, 2),
            "legacy_winner": None if legacy is None
            else legacy.candidate.key,
            "legacy_tflops": None if legacy is None
            else round(legacy.tflops, 2),
        })
    return out


def _check_schedule_claims(bubble_rows, mem_rows, mem_winners,
                           print_fn) -> int:
    """The machine-checked schedule claims; returns #failures."""
    fails = []
    by = lambda rows, m, s: next(r for r in rows
                                 if r["n_micro"] == m and
                                 r["schedule"] == s)
    ms = sorted({r["n_micro"] for r in bubble_rows})
    for m in ms:
        gp, il = by(bubble_rows, m, "gpipe"), by(bubble_rows, m,
                                                 "interleaved")
        f1b = by(bubble_rows, m, "1f1b")
        if not (il["bubble"] < gp["bubble"] == f1b["bubble"]):
            fails.append(f"bubble ordering broken at m={m}")
        if f1b["step_s"] != gp["step_s"]:
            fails.append(f"1f1b != gpipe step time at m={m}")
        if f1b["mem_gb"] > gp["mem_gb"]:
            fails.append(f"1f1b stashes more than gpipe at m={m}")
    # the schedule contest crosses over in m: at the smallest m the
    # (S-1)/(v·m) bubble buys more than the v-fold p2p costs, so
    # interleaved is the fastest pipeline; as m grows the bubble
    # amortizes away and GPipe/1F1B retake the lead
    m_lo, m_hi = min(ms), max(ms)
    if by(bubble_rows, m_lo, "interleaved")["step_s"] >= \
            by(bubble_rows, m_lo, "gpipe")["step_s"]:
        fails.append(f"interleaved not fastest at small m={m_lo}")
    if by(bubble_rows, m_hi, "interleaved")["step_s"] <= \
            by(bubble_rows, m_hi, "gpipe")["step_s"]:
        fails.append(f"no schedule crossover by m={m_hi}")
    # large m: gpipe's stash must eventually OOM while 1f1b still fits
    last = max(ms)
    if by(bubble_rows, last, "gpipe")["fits"] or \
            not by(bubble_rows, last, "1f1b")["fits"]:
        fails.append(f"no gpipe-OOM/1f1b-fits split at m={last}")
    # the memory-flip scenario at the paper's m=4 (small m, 3 stages)
    m4 = next((w for w in mem_winners if w["n_micro"] == 4), None)
    gp4, f1b4 = by(mem_rows, 4, "gpipe"), by(mem_rows, 4, "1f1b")
    if gp4["fits"] or not f1b4["fits"]:
        fails.append("memory scenario: gpipe should OOM at m=4 and "
                     "1f1b fit")
    if m4 is None or m4["winner_schedule"] != "1f1b" \
            or "pipeshard" not in (m4["winner"] or ""):
        fails.append(f"memory scenario: winner at m=4 is {m4} — "
                     f"expected a pipeshard#1f1b flip")
    elif m4["legacy_winner"] and "pipeshard" in m4["legacy_winner"]:
        fails.append("memory scenario: legacy search already picked "
                     "pipeshard — no flip to demonstrate")
    for f in fails:
        print_fn(f"CLAIM-FAIL: {f}")
    return len(fails)


def _md_rows(rows, keys, headers):
    from benchmarks.sweep_common import md_table
    return md_table(headers, [[str(r[k]) for k in keys] for r in rows])


def run_schedules(print_fn=print, smoke: bool = False,
                  out: str = None) -> int:
    """Analytic schedule ablation; returns #failed claims."""
    from benchmarks.sweep_common import write_outputs
    from repro.configs import get_config
    from repro.core.costmodel import paper_workload
    from repro.core.topology import Link, Site, line

    t0 = time.perf_counter()
    # fully analytic, so smoke and full share the grid; --smoke only
    # switches the output stem (CI never clobbers the committed full
    # artifacts render_figs.py draws from)
    micros = (1, 2, 4, 8, 16)
    a30 = line("a30line3",
               [Site(("A30", "A30"), name=f"S{i}") for i in range(3)],
               [Link(0.1e-3, 3.0)] * 2)
    rtx = line("rtx3",
               [Site(("RTX", "RTX"), name=f"S{i}") for i in range(3)],
               [Link(57.4e-3, 3.0)] * 2)
    wl_bubble = paper_workload(get_config("gpt2m"))
    wl_mem = paper_workload(get_config("gpt2L"), global_batch=52)

    bubble_rows = _schedule_rows(wl_bubble, a30, micros)
    mem_micros = sorted(set(micros) | {3, 4})
    mem_rows = _schedule_rows(wl_mem, rtx, mem_micros)
    mem_winners = _winners(wl_mem, rtx, mem_micros)
    n_fail = _check_schedule_claims(bubble_rows, mem_rows, mem_winners,
                                    print_fn)
    elapsed = time.perf_counter() - t0
    mode = "smoke" if smoke else "full"

    keys = ("n_micro", "schedule", "bubble", "inflight", "mem_gb",
            "fits", "step_s", "tflops")
    headers = ("m", "schedule", "bubble", "in-flight", "mem GB", "fits",
               "step s", "TFLOP/s")
    md = "\n".join([
        "# Pipeline schedule ablation", "",
        "Schedules reorder ticks, not math (docs/schedules.md): GPipe "
        "and 1F1B share the `(S-1)/m` bubble but 1F1B stashes only "
        "`min(S, m)` microbatches; the interleaved schedule divides the "
        "bubble by its v virtual stages and pays v crossings of every "
        "stage boundary.", "",
        "## Bubble scenario — gpt2m, 3-site A30 metro line "
        "(0.1 ms edges)", "",
        _md_rows(bubble_rows, keys, headers),
        "## Memory scenario — gpt2L (batch 52), 3-site RTX continental "
        "line (57.4 ms edges)", "",
        _md_rows(mem_rows, keys, headers),
        "## Search winners on the memory scenario", "",
        _md_rows(mem_winners,
                 ("n_micro", "winner", "winner_tflops", "legacy_winner",
                  "legacy_tflops"),
                 ("m", "schedule-aware winner", "TFLOP/s",
                  "GPipe-only winner", "TFLOP/s")),
        f"At the paper's m=4 the schedule-aware search flips the winner "
        f"from the GPipe-only fallback to `pipeshard#1f1b` on all three "
        f"sites — GPipe's 4-microbatch stash misses the 24 GB budget "
        f"that 1F1B's min(S, m)=3 makes.", ""])
    record = {"mode": mode, "elapsed_s": round(elapsed, 2),
              "scenarios": {
                  "bubble": {"model": "gpt2m", "topology": "a30line3",
                             "latency_ms": 0.1, "rows": bubble_rows},
                  "memory": {"model": "gpt2L", "topology": "rtx3",
                             "latency_ms": 57.4, "rows": mem_rows,
                             "winners": mem_winners}}}
    if out is None:
        out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "out")
    write_outputs(out, f"pipeline_schedules_{mode}", record, md,
                  print_fn=print_fn)
    print_fn(f"# schedule ablation ({mode}): {len(bubble_rows)} + "
             f"{len(mem_rows)} rows, {elapsed:.1f}s, {n_fail} failures")
    return n_fail


# --------------------------------------------------------------------- #
# legacy heavy mode (512 forced host devices, one compile per point)
# --------------------------------------------------------------------- #

def run(print_fn=print, micros=(2, 4, 8, 16)) -> int:
    import jax

    from repro.configs import get_config, get_shape
    from repro.configs.base import TrainConfig
    from repro.core.pipeline import pipeline_mesh
    from repro.core.plans import get_plan
    from repro.launch import roofline as rl
    from repro.launch.dryrun import build_step
    from repro.launch.mesh import make_production_mesh
    from repro.models import Model

    cfg = get_config("llama3.2-3b")
    shape = get_shape("train_4k")
    plan = get_plan("pipeshard")
    n_stages = 2
    print_fn("# Pipeshard microbatch ablation "
             "(llama3.2-3b x train_4k x 2x16x16, 2 stages)")
    print_fn("n_micro,bubble_frac,dcn_gb_per_dev,ici_gb_per_dev,"
             "collective_s,mem_gb_per_dev,compile_s")
    rows = []
    for m in micros:
        base = make_production_mesh(multi_pod=True)
        mesh = pipeline_mesh(base, n_stages)
        model = Model(cfg)
        tcfg = TrainConfig(microbatches=m)
        t0 = time.time()
        with jax.set_mesh(mesh):
            step, args, acost = build_step(model, plan, mesh, cfg, shape,
                                           tcfg)
            compiled = step.lower(*args).compile()
        roof = rl.from_compiled(
            compiled, arch=cfg.name, shape=shape.name, mesh_name="2x16x16",
            plan=f"pipeshard_m{m}", analytic=acost, n_devices=512,
            crosses_pod=True)
        bubble = (n_stages - 1) / (m + n_stages - 1)
        row = dict(n_micro=m, bubble=bubble,
                   dcn_gb=roof.dcn_bytes_per_device / 1e9,
                   ici_gb=roof.collective_bytes_per_device / 1e9,
                   coll_s=roof.collective_s,
                   mem_gb=roof.memory_per_device_bytes / 1e9,
                   compile_s=time.time() - t0)
        rows.append(row)
        print_fn(f"{m},{bubble:.3f},{row['dcn_gb']:.3f},{row['ici_gb']:.2f},"
                 f"{row['coll_s']:.2f},{row['mem_gb']:.2f},"
                 f"{row['compile_s']:.0f}")
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "pipeline_ablation.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--schedules", action="store_true",
                    help="analytic GPipe/1F1B/interleaved ablation "
                         "(seconds; the CI gate with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="[--schedules only] write *_smoke artifacts "
                         "(same analytic grid) so CI never clobbers "
                         "the committed full outputs")
    ap.add_argument("--out", default=None,
                    help="output dir (default: benchmarks/out)")
    args = ap.parse_args(argv)
    if args.schedules:
        return run_schedules(smoke=args.smoke, out=args.out)
    # heavy XLA mode: the forced device count must precede any jax init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
    return run()


if __name__ == "__main__":
    sys.exit(main())
