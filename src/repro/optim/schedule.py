"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_at(step, cfg: TrainConfig):
    s = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - 0.9 * frac
    else:  # cosine to 10%
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * decay
