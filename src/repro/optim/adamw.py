"""AdamW with global-norm clipping, built so ZeRO plans can shard its state.

The optimizer state mirrors the parameter pytree (m, v per leaf), so a plan
can place it with arbitrary PartitionSpecs (ZeRO-2 shards it over the data
axes).  Updates are pure functions of (grads, state, params) — the paper's
ZeRO2 reduce-scatter / all-gather pattern is realized by the *shardings*
the train step pins on grads / opt state / new params, not by this module.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array        # scalar int32
    m: Any                 # first moment  (params-shaped)
    v: Any                 # second moment (params-shaped)


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_NO_DECAY = ("scale", "bias", "gates", "dt_bias", "A_log", "D", "norm_scale",
             "q_norm", "kv_norm")


def _decay_mask(path) -> bool:
    last = ""
    for p in path:
        if hasattr(p, "key"):
            last = str(p.key)
    return last not in _NO_DECAY and "norm" not in last


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig,
                 lr: jax.Array) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state.v, grads)

    def upd(path, p, m, v):
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
