"""Model + input-spec registry: builds Model objects and the batch pytrees
(concrete or ShapeDtypeStruct) for every (arch, input-shape) combination."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model


def build_model(arch_or_cfg, *, use_pallas: bool = False) -> Model:
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) \
        else get_config(arch_or_cfg)
    return Model(cfg, use_pallas=use_pallas)


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def abstractify(pytree) -> Any:
    """ShapeDtypeStruct skeleton of a pytree of (possibly concrete)
    arrays — the one way the runtime (``train.loop``, ``serve.engine``)
    and the static plan verifier (``repro.analysis.planlint``) build
    abstract pytrees, so shardings computed from either agree.  Leaves
    that are already abstract pass through unchanged; sharding metadata
    is deliberately dropped (specs are the plans' job)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                       jnp.result_type(x)), pytree)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                abstract: bool = True, rng: np.random.Generator = None
                ) -> Dict[str, Any]:
    """Batch pytree for a (model, input-shape) pair.

    ``abstract=True`` returns ShapeDtypeStructs (dry-run lowering, zero
    allocation); otherwise concrete random arrays (smoke tests).
    Train/prefill shapes give the full-sequence batch; decode shapes give
    the single-token batch (the cache comes from Model.init_cache).

    For the stub-frontend families, the modality encoder is NOT built
    (per assignment): ``patch_embeds`` / ``frames`` are precomputed
    embeddings of the documented shape.
    """
    B, S = shape.global_batch, shape.seq_len
    mk_i = (lambda s: _struct(s, jnp.int32)) if abstract else \
        (lambda s: jnp.asarray(rng.integers(0, min(cfg.vocab_size, 1000), s),
                               jnp.int32))
    mk_f = (lambda s: _struct(s, jnp.dtype(cfg.dtype))) if abstract else \
        (lambda s: jnp.asarray(rng.standard_normal(s) * 0.02,
                               jnp.dtype(cfg.dtype)))

    if shape.kind == "decode":
        batch = {"tokens": mk_i((B, 1))}
        return batch

    batch = {}
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.n_patches
        batch["patch_embeds"] = mk_f((B, cfg.n_patches, cfg.vision_dim))
    if cfg.family == "encdec":
        batch["frames"] = mk_f((B, cfg.enc_seq_len, cfg.d_model))
    batch["tokens"] = mk_i((B, s_text))
    if shape.kind == "train":
        batch["labels"] = mk_i((B, s_text))
    return batch
