"""Per-layer blocks for every architecture family.

Every block family exposes a uniform (init, forward, prefill, decode)
quartet so that model.py can stack layer parameters on a leading [L] axis
and drive them with ``lax.scan`` — which is also exactly the layout the
Pipeshard plan slices into pipeline stages.
Forward/prefill/decode all return ``(x, aux)`` / ``(x, cache, aux)`` with a
scalar aux (MoE load-balance loss; 0.0 elsewhere) to keep scan signatures
uniform across families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


# --------------------------------------------------------------------- #
# dense (llama/phi/gpt2/minicpm3) — also the LM backbone of the VLM
# --------------------------------------------------------------------- #

def init_dense_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 4)
    p = {
        "norm1": init_norm(r[0], cfg.d_model, cfg.norm),
        "norm2": init_norm(r[1], cfg.d_model, cfg.norm),
        "mlp": init_mlp(r[2], cfg.d_model, cfg.d_ff, cfg.activation),
    }
    if cfg.mla is not None:
        p["mla"] = attn.init_mla(r[3], cfg)
    else:
        p["attn"] = attn.init_attention(r[3], cfg)
    return p


def dense_block_forward(x, p, cfg: ModelConfig, *, positions, window=0,
                        use_pallas=False):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a = attn.mla_forward(h, p["mla"], cfg, positions=positions,
                             window=window, use_pallas=use_pallas)
    else:
        a = attn.attention_forward(h, p["attn"], cfg, positions=positions,
                                   window=window, use_pallas=use_pallas)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def dense_block_prefill(x, p, cfg: ModelConfig, *, positions, cache, window=0):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_prefill(h, p["mla"], cfg, positions=positions,
                                    cache=cache, window=window)
    else:
        a, cache = attn.attention_prefill(h, p["attn"], cfg,
                                          positions=positions, cache=cache,
                                          window=window)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    return x, cache, jnp.zeros((), jnp.float32)


def dense_block_decode(x, p, cfg: ModelConfig, *, cache, window=0):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(h, p["mla"], cfg, cache=cache,
                                   window=window)
    else:
        a, cache = attn.attention_decode(h, p["attn"], cfg, cache=cache,
                                         window=window)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    return x, cache, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------- #
# MoE (phi3.5-moe, deepseek-v2)
# --------------------------------------------------------------------- #

def init_moe_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 4)
    p = {
        "norm1": init_norm(r[0], cfg.d_model, cfg.norm),
        "norm2": init_norm(r[1], cfg.d_model, cfg.norm),
        "moe": moe_mod.init_moe(r[2], cfg),
    }
    if cfg.mla is not None:
        p["mla"] = attn.init_mla(r[3], cfg)
    else:
        p["attn"] = attn.init_attention(r[3], cfg)
    return p


def moe_block_forward(x, p, cfg: ModelConfig, *, positions, window=0,
                      use_pallas=False):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a = attn.mla_forward(h, p["mla"], cfg, positions=positions,
                             window=window, use_pallas=use_pallas)
    else:
        a = attn.attention_forward(h, p["attn"], cfg, positions=positions,
                                   window=window, use_pallas=use_pallas)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    m, aux = moe_mod.moe_forward(h, p["moe"], cfg)
    return x + m, aux


def moe_block_prefill(x, p, cfg: ModelConfig, *, positions, cache, window=0):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_prefill(h, p["mla"], cfg, positions=positions,
                                    cache=cache, window=window)
    else:
        a, cache = attn.attention_prefill(h, p["attn"], cfg,
                                          positions=positions, cache=cache,
                                          window=window)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    m, aux = moe_mod.moe_forward(h, p["moe"], cfg)
    return x + m, cache, aux


def moe_block_decode(x, p, cfg: ModelConfig, *, cache, window=0):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(h, p["mla"], cfg, cache=cache,
                                   window=window)
    else:
        a, cache = attn.attention_decode(h, p["attn"], cfg, cache=cache,
                                         window=window)
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    m, aux = moe_mod.moe_forward(h, p["moe"], cfg)
    return x + m, cache, aux


# --------------------------------------------------------------------- #
# SSM (falcon-mamba: norm -> mamba1 -> residual)
# --------------------------------------------------------------------- #

def init_ssm_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 2)
    return {
        "norm": init_norm(r[0], cfg.d_model, cfg.norm),
        "mamba": ssm_mod.init_mamba1(r[1], cfg),
    }


def ssm_block_forward(x, p, cfg: ModelConfig, *, use_pallas=False, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, _ = ssm_mod.mamba1_forward(h, p["mamba"], cfg, use_pallas=use_pallas)
    return x + y, jnp.zeros((), jnp.float32)


def ssm_block_decode(x, p, cfg: ModelConfig, *, cache, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, cache = ssm_mod.mamba1_decode(h, p["mamba"], cfg, state=cache)
    return x + y, cache, jnp.zeros((), jnp.float32)


def ssm_block_prefill(x, p, cfg: ModelConfig, *, cache, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, cache = ssm_mod.mamba1_forward(h, p["mamba"], cfg, state=cache)
    return x + y, cache, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------- #
# hybrid (zamba2: groups of mamba2 layers + one shared attention block)
# --------------------------------------------------------------------- #

def init_mamba2_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 2)
    return {
        "norm": init_norm(r[0], cfg.d_model, cfg.norm),
        "mamba": ssm_mod.init_mamba2(r[1], cfg),
    }


def mamba2_block_forward(x, p, cfg: ModelConfig, *, use_pallas=False, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, _ = ssm_mod.mamba2_forward(h, p["mamba"], cfg, use_pallas=use_pallas)
    return x + y, jnp.zeros((), jnp.float32)


def mamba2_block_prefill(x, p, cfg: ModelConfig, *, cache, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, cache = ssm_mod.mamba2_forward(h, p["mamba"], cfg, state=cache)
    return x + y, cache, jnp.zeros((), jnp.float32)


def mamba2_block_decode(x, p, cfg: ModelConfig, *, cache, **_):
    h = apply_norm(x, p["norm"], cfg.norm, cfg.norm_eps)
    y, cache = ssm_mod.mamba2_decode(h, p["mamba"], cfg, state=cache)
    return x + y, cache, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------- #
# whisper decoder block (self-attn + cross-attn + mlp)
# --------------------------------------------------------------------- #

def init_encdec_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 6)
    return {
        "norm1": init_norm(r[0], cfg.d_model, cfg.norm),
        "self_attn": attn.init_attention(r[1], cfg),
        "norm2": init_norm(r[2], cfg.d_model, cfg.norm),
        "cross_attn": attn.init_attention(r[3], cfg),
        "norm3": init_norm(r[4], cfg.d_model, cfg.norm),
        "mlp": init_mlp(r[5], cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _cross_attention(h, p, cfg: ModelConfig, enc_out):
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt)) + p["bq"].astype(dt)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt)) + p["bk"].astype(dt)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt)) + p["bv"].astype(dt)
    o = attn.chunked_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt)) + p["bo"].astype(dt)


def _cross_attention_cached(h, p, k, v):
    """Decode-time cross attention against precomputed enc K/V."""
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt)) + p["bq"].astype(dt)
    B, F = k.shape[0], k.shape[1]
    o = attn.decode_attention(q, k, v, jnp.ones((B, F), bool))
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt)) + p["bo"].astype(dt)


def encdec_block_forward(x, p, cfg: ModelConfig, *, positions, enc_out, **_):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    x = x + attn.attention_forward(h, p["self_attn"], cfg,
                                   positions=positions, causal=True)
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + _cross_attention(h, p["cross_attn"], cfg, enc_out)
    h = apply_norm(x, p["norm3"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def encdec_block_prefill(x, p, cfg: ModelConfig, *, positions, enc_out,
                         cache, **_):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    a, self_cache = attn.attention_prefill(h, p["self_attn"], cfg,
                                           positions=positions,
                                           cache=cache["self"])
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + _cross_attention(h, p["cross_attn"], cfg, enc_out)
    h = apply_norm(x, p["norm3"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    dt = x.dtype
    ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                    p["cross_attn"]["wk"].astype(dt)) \
        + p["cross_attn"]["bk"].astype(dt)
    cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                    p["cross_attn"]["wv"].astype(dt)) \
        + p["cross_attn"]["bv"].astype(dt)
    new_cache = {"self": self_cache, "cross_k": ck.astype(cache["cross_k"].dtype),
                 "cross_v": cv.astype(cache["cross_v"].dtype)}
    return x, new_cache, jnp.zeros((), jnp.float32)


def encdec_block_decode(x, p, cfg: ModelConfig, *, cache, **_):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    a, self_cache = attn.attention_decode(h, p["self_attn"], cfg,
                                          cache=cache["self"])
    x = x + a
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    x = x + _cross_attention_cached(h, p["cross_attn"],
                                    cache["cross_k"], cache["cross_v"])
    h = apply_norm(x, p["norm3"], cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(h, p["mlp"], cfg.activation)
    new_cache = dict(cache, self=self_cache)
    return x, new_cache, jnp.zeros((), jnp.float32)


# whisper encoder block: bidirectional self-attn + mlp
def init_encoder_block(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 3)
    return {
        "norm1": init_norm(r[0], cfg.d_model, cfg.norm),
        "attn": attn.init_attention(r[1], cfg),
        "norm2": init_norm(r[2], cfg.d_model, cfg.norm),
        "mlp": init_mlp(jax.random.fold_in(rng, 3), cfg.d_model, cfg.d_ff,
                        cfg.activation),
    }


def encoder_block_forward(x, p, cfg: ModelConfig, *, positions):
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    x = x + attn.attention_forward(h, p["attn"], cfg, positions=positions,
                                   causal=False)
    h = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    return x + apply_mlp(h, p["mlp"], cfg.activation)
