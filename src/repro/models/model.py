"""Model assembly: embeddings -> stacked layer scan -> head, for every
architecture family, with train / prefill / decode entry points.

Layer parameters are stacked on a leading ``[n_layers]`` axis and driven by
``lax.scan`` (compile time independent of depth; sliceable into Pipeshard
stages).  Decode carries a constant-shape cache pytree through the same scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_norm, dense_init, embed, embed_init, init_embedding,
    init_learned_positions, init_norm, unembed,
)

Params = Dict[str, Any]


def _stack_init(fn, rng, n: int):
    return jax.vmap(fn)(jax.random.split(rng, n))


_BLOCK = {
    "dense": (blocks.init_dense_block, blocks.dense_block_forward,
              blocks.dense_block_prefill, blocks.dense_block_decode),
    "vlm": (blocks.init_dense_block, blocks.dense_block_forward,
            blocks.dense_block_prefill, blocks.dense_block_decode),
    "moe": (blocks.init_moe_block, blocks.moe_block_forward,
            blocks.moe_block_prefill, blocks.moe_block_decode),
    "ssm": (blocks.init_ssm_block, blocks.ssm_block_forward,
            blocks.ssm_block_prefill, blocks.ssm_block_decode),
    "hybrid": (blocks.init_mamba2_block, blocks.mamba2_block_forward,
               blocks.mamba2_block_prefill, blocks.mamba2_block_decode),
    "encdec": (blocks.init_encdec_block, blocks.encdec_block_forward,
               blocks.encdec_block_prefill, blocks.encdec_block_decode),
}


class Model:
    """Functional model wrapper around a ModelConfig."""

    def __init__(self, cfg: ModelConfig, *, use_pallas: bool = False):
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.compute_dtype = jnp.dtype(cfg.dtype)
        # Optional PartitionSpec pinned on logits right after unembedding.
        # Set by the step builders under weight-sharding plans so the
        # [B, S, vocab] tensor (and its fp32 softmax temporaries) stays
        # vocab-sharded instead of being all-gathered per device.
        self.logits_pspec = None
        # Optional PartitionSpec pinned on the residual stream at each
        # layer boundary (FSDP plans): the remat-saved activations then
        # shard their d_model dim over the model axis instead of holding
        # a full [L, B_loc, S, d] copy per device (270 GB for llama3-405b).
        self.resid_pspec = None

    # ----------------------------------------------------------------- #
    # init
    # ----------------------------------------------------------------- #
    def init(self, rng) -> Params:
        cfg = self.cfg
        r = jax.random.split(rng, 8)
        init_block = _BLOCK[cfg.family][0]
        params: Params = {
            "embed": init_embedding(r[0], cfg.vocab_size, cfg.d_model),
            "final_norm": init_norm(r[1], cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(r[2], cfg.vocab_size,
                                               cfg.d_model)
        if not cfg.rope_theta and cfg.family != "ssm":
            params["pos_embed"] = init_learned_positions(
                r[3], cfg.max_seq_len, cfg.d_model)

        if cfg.family == "hybrid":
            k = cfg.hybrid_attn_every
            G = cfg.n_layers // k
            per_group = lambda rg: _stack_init(
                lambda rr: init_block(rr, cfg), rg, k)
            params["layers"] = {                     # [G, k, ...] + [G]
                "blocks": _stack_init(per_group, r[4], G),
                "gates": jnp.ones((G,)),
            }
            params["shared"] = blocks.init_dense_block(r[5], cfg)
        else:
            params["layers"] = _stack_init(
                lambda rr: init_block(rr, cfg), r[4], cfg.n_layers)

        if cfg.family == "encdec":
            params["encoder"] = {
                "layers": _stack_init(
                    lambda rr: blocks.init_encoder_block(rr, cfg), r[5],
                    cfg.n_enc_layers),
                "norm": init_norm(r[6], cfg.d_model, cfg.norm),
                "pos": init_learned_positions(
                    jax.random.fold_in(r[6], 1), cfg.enc_seq_len, cfg.d_model),
            }
        if cfg.family == "vlm":
            rs = jax.random.split(r[5], 2)
            params["projector"] = {
                "w1": dense_init(rs[0], (cfg.vision_dim, cfg.d_model),
                                 cfg.vision_dim),
                "w2": dense_init(rs[1], (cfg.d_model, cfg.d_model),
                                 cfg.d_model),
            }
        return params

    # ----------------------------------------------------------------- #
    # shared pieces
    # ----------------------------------------------------------------- #
    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array, int]:
        """Returns (x, positions, n_prefix) where n_prefix = non-text prefix
        length (VLM patches)."""
        cfg, dt = self.cfg, self.compute_dtype
        tokens = batch["tokens"]
        x = embed(tokens, params["embed"], dt)
        n_prefix = 0
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(dt)        # [B, P, vdim]
            p = jnp.einsum("bpv,vd->bpd", patches,
                           params["projector"]["w1"].astype(dt))
            p = jax.nn.gelu(p.astype(jnp.float32)).astype(dt)
            p = jnp.einsum("bpd,de->bpe", p,
                           params["projector"]["w2"].astype(dt))
            x = jnp.concatenate([p, x], axis=1)
            n_prefix = patches.shape[1]
        B, S = x.shape[0], x.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if "pos_embed" in params:
            x = x + params["pos_embed"]["table"].astype(dt)[positions]
        return x, positions, n_prefix

    def _head(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(x, table, self.compute_dtype)
        if self.logits_pspec is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, self.logits_pspec)
        return logits

    def _encode(self, params, batch) -> jax.Array:
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg, dt = self.cfg, self.compute_dtype
        frames = batch["frames"].astype(dt)                   # [B, F, d]
        B, F = frames.shape[0], frames.shape[1]
        pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
        x = frames + params["encoder"]["pos"]["table"].astype(dt)[pos]

        def body(h, layer_p):
            return blocks.encoder_block_forward(
                h, layer_p, cfg, positions=pos), None

        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
        return apply_norm(x, params["encoder"]["norm"], cfg.norm, cfg.norm_eps)

    # ----------------------------------------------------------------- #
    # full-sequence forward (train)
    # ----------------------------------------------------------------- #
    def run_stack(self, stack, x, positions, *, shared=None, enc_out=None,
                  window: int = 0, remat: bool = True, layer_valid=None
                  ) -> Tuple[jax.Array, jax.Array]:
        """Run a (slice of the) stacked layer parameters over activations.

        ``stack`` is ``params["layers"]`` or a stage-local slice of it
        (Pipeshard); ``shared`` is the hybrid family's shared attention
        block (replicated across stages).  Returns (x, aux_sum).

        ``layer_valid``: optional boolean mask over the stack's leading
        axis (groups for hybrid).  Slots marked False are identity
        pass-throughs — the activations skip the layer unchanged and the
        slot contributes zero aux.  This is how Pipeshard realizes uneven
        per-stage layer counts: every stage's slice is padded to the
        longest stage and the padding is masked out here
        (core/pipeline.make_pipeline_loss).
        """
        cfg = self.cfg
        fwd = _BLOCK[cfg.family][1]

        def block_fn(h, layer_p):
            if self.resid_pspec is not None:
                h = jax.lax.with_sharding_constraint(h, self.resid_pspec)
            return fwd(h, layer_p, cfg, positions=positions, window=window,
                       use_pallas=self.use_pallas,
                       **({"enc_out": enc_out} if enc_out is not None else {}))

        if remat:
            block_fn = jax.checkpoint(block_fn)

        if cfg.family == "hybrid":
            def shared_block(h, gate):
                y, _ = blocks.dense_block_forward(
                    h, shared, cfg, positions=positions, window=window,
                    use_pallas=self.use_pallas)
                return h + gate.astype(h.dtype) * (y - h)

            if remat:
                shared_block = jax.checkpoint(shared_block)

            def group_fn(h, inp):
                layer_p, gate = inp
                h = shared_block(h, gate)
                h, auxs = jax.lax.scan(
                    lambda hh, lp: block_fn(hh, lp), h, layer_p)
                return h, jnp.sum(auxs)

            body, xs = group_fn, (stack["blocks"], stack["gates"])
        else:
            body, xs = block_fn, stack

        if layer_valid is None:
            x, auxs = jax.lax.scan(body, x, xs)
        else:
            def masked_body(h, inp):
                valid, real = inp
                out, aux = body(h, real)
                return (jnp.where(valid, out, h),
                        jnp.where(valid, aux, jnp.zeros_like(aux)))

            x, auxs = jax.lax.scan(masked_body, x, (layer_valid, xs))
        return x, jnp.sum(auxs)

    def forward(self, params, batch, *, window: int = 0,
                remat: bool = True) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits [B, S, V], aux_loss)."""
        cfg = self.cfg
        x, positions, _ = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch) if cfg.family == "encdec" else None
        x, aux = self.run_stack(params["layers"], x, positions,
                                shared=params.get("shared"), enc_out=enc_out,
                                window=window, remat=remat)
        return self._head(params, x), aux

    # ----------------------------------------------------------------- #
    # loss
    # ----------------------------------------------------------------- #
    def loss(self, params, batch, *, remat: bool = True
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch, remat=remat)
        return lm_loss(self.cfg, logits, batch, aux)

    # ----------------------------------------------------------------- #
    # caches
    # ----------------------------------------------------------------- #
    def init_cache(self, batch: int, capacity: int, *,
                   window: int = 0, kv_dtype: str = "fp32") -> Any:
        """Decode cache pytree, leaves stacked on the layer axis.
        ``capacity`` is the KV length to materialize; a nonzero ``window``
        bounds it (ring buffer) for the long-context decode variant.
        ``kv_dtype='int8'`` (plain-GQA attention families only) swaps in
        the quantized ``QuantKVCache`` — decode then runs through the
        int8-KV Pallas kernel (docs/quantization.md)."""
        cfg, dt = self.cfg, self.compute_dtype
        cap = min(capacity, window) if window else capacity
        if kv_dtype not in ("fp32", "int8"):
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                             f"expected 'fp32' or 'int8'")
        if kv_dtype == "int8" and (
                cfg.family not in ("dense", "vlm", "moe")
                or cfg.mla is not None):
            raise ValueError(
                "kv_dtype='int8' needs a plain-GQA attention cache; "
                f"family {cfg.family!r}"
                + (" with MLA" if cfg.mla is not None else "")
                + " stores no quantizable k/v tensors")

        def stack(make, n):
            return jax.tree.map(
                lambda *xs: jnp.stack(xs), *[make() for _ in range(n)])

        if cfg.family in ("dense", "vlm", "moe"):
            if cfg.mla is not None:
                make = lambda: attn_mod.init_mla_cache(batch, cap, cfg.mla, dt)
            elif kv_dtype == "int8":
                make = lambda: attn_mod.init_quant_kv_cache(
                    batch, cap, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim)
            else:
                make = lambda: attn_mod.init_kv_cache(
                    batch, cap, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim, dt)
            return stack(make, cfg.n_layers)
        if cfg.family == "ssm":
            return stack(lambda: ssm_mod.init_ssm_state(cfg, batch, dt),
                         cfg.n_layers)
        if cfg.family == "hybrid":
            k = cfg.hybrid_attn_every
            G = cfg.n_layers // k
            ssm_one = lambda: stack(
                lambda: ssm_mod.init_ssm_state(cfg, batch, dt), k)
            return {
                "ssm": stack(ssm_one, G),                       # [G, k, ...]
                "attn": stack(lambda: attn_mod.init_kv_cache(
                    batch, cap, cfg.n_kv_heads, cfg.head_dim,
                    cfg.head_dim, dt), G),
            }
        if cfg.family == "encdec":
            F = cfg.enc_seq_len
            make = lambda: {
                "self": attn_mod.init_kv_cache(
                    batch, cap, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim, dt),
                "cross_k": jnp.zeros((batch, F, cfg.n_heads, cfg.head_dim), dt),
                "cross_v": jnp.zeros((batch, F, cfg.n_heads, cfg.head_dim), dt),
            }
            return stack(make, cfg.n_layers)
        raise ValueError(cfg.family)

    def init_slot_cache(self, batch: int, capacity: int, *,
                        window: int = 0, kv_dtype: str = "fp32") -> Any:
        """Per-slot decode cache for continuous batching: ``init_cache``
        with every ring ``index`` leaf widened by a trailing ``[batch]``
        axis, so each slot tracks its own fill position and can hold a
        different request (``serve.engine.ContinuousEngine``).  SSM state
        carries no index and is shared unchanged."""
        cache = self.init_cache(batch, capacity, window=window,
                                kv_dtype=kv_dtype)

        def widen(path, leaf):
            if any(getattr(p, "name", "") == "index" for p in path):
                return jnp.zeros(leaf.shape + (batch,), leaf.dtype)
            return leaf

        return jax.tree_util.tree_map_with_path(widen, cache)

    # ----------------------------------------------------------------- #
    # prefill: full forward that also fills the cache
    # ----------------------------------------------------------------- #
    def prefill(self, params, batch, cache, *, window: int = 0,
                last_pos=None) -> Tuple[jax.Array, Any]:
        """Returns (last-position logits [B, V], filled cache).

        ``last_pos``: optional traced int32 scalar — read the logits at
        this sequence position instead of the final one.  This is how a
        bucket-padded prefill (continuous batching) reads the true
        prompt's last token while the pad tail stays causally invisible.
        """
        cfg = self.cfg
        x, positions, _ = self._embed_inputs(params, batch)
        pre = _BLOCK[cfg.family][2]
        enc_out = self._encode(params, batch) if cfg.family == "encdec" else None

        def block_fn(h, inp):
            layer_p, layer_c = inp
            h, c, _ = pre(h, layer_p, cfg, positions=positions, cache=layer_c,
                          window=window,
                          **({"enc_out": enc_out} if enc_out is not None else {}))
            return h, c

        if cfg.family == "hybrid":
            shared = params["shared"]

            def group_fn(h, inp):
                layer_p, gate, g_cache = inp
                y, ac, _ = blocks.dense_block_prefill(
                    h, shared, cfg, positions=positions,
                    cache=g_cache["attn"], window=window)
                h = h + gate.astype(h.dtype) * (y - h)
                h, sc = jax.lax.scan(
                    lambda hh, i: (lambda r: (r[0], r[1]))(
                        blocks.mamba2_block_prefill(
                            hh, i[0], cfg, cache=i[1])[:2]),
                    h, (layer_p, g_cache["ssm"]))
                return h, {"attn": ac, "ssm": sc}

            x, new_cache = jax.lax.scan(
                group_fn, x,
                (params["layers"]["blocks"], params["layers"]["gates"],
                 {"attn": cache["attn"], "ssm": cache["ssm"]}))
        else:
            x, new_cache = jax.lax.scan(block_fn, x,
                                        (params["layers"], cache))
        x_last = x[:, -1:] if last_pos is None else \
            jax.lax.dynamic_slice_in_dim(x, last_pos, 1, 1)
        logits = self._head(params, x_last)[:, 0]
        return logits, new_cache

    # ----------------------------------------------------------------- #
    # decode: one token through the stack
    # ----------------------------------------------------------------- #
    def decode_step(self, params, cache, tokens, *, window: int = 0
                    ) -> Tuple[jax.Array, Any]:
        """tokens: [B, 1] -> (logits [B, V], new cache)."""
        cfg, dt = self.cfg, self.compute_dtype
        dec = _BLOCK[cfg.family][3]
        x = embed(tokens, params["embed"], dt)
        if "pos_embed" in params:
            pos = self._cache_index(cache)
            pe = params["pos_embed"]["table"].astype(dt)[
                jnp.clip(pos, 0, cfg.max_seq_len - 1)]
            x = x + (pe[None, None] if pos.ndim == 0 else pe[:, None])

        def block_fn(h, inp):
            layer_p, layer_c = inp
            h, c, _ = dec(h, layer_p, cfg, cache=layer_c, window=window)
            return h, c

        if cfg.family == "hybrid":
            shared = params["shared"]

            def group_fn(h, inp):
                layer_p, gate, g_cache = inp
                y, ac, _ = blocks.dense_block_decode(
                    h, shared, cfg, cache=g_cache["attn"], window=window)
                h = h + gate.astype(h.dtype) * (y - h)
                h, sc = jax.lax.scan(
                    lambda hh, i: (lambda r: (r[0], r[1]))(
                        blocks.mamba2_block_decode(hh, i[0], cfg,
                                                   cache=i[1])[:2]),
                    h, (layer_p, g_cache["ssm"]))
                return h, {"attn": ac, "ssm": sc}

            x, new_cache = jax.lax.scan(
                group_fn, x,
                (params["layers"]["blocks"], params["layers"]["gates"],
                 {"attn": cache["attn"], "ssm": cache["ssm"]}))
        else:
            x, new_cache = jax.lax.scan(block_fn, x,
                                        (params["layers"], cache))
        return self._head(params, x)[:, 0], new_cache

    # ----------------------------------------------------------------- #
    @staticmethod
    def _cache_index(cache) -> jax.Array:
        """Current absolute position from any cache pytree (first leaf
        named 'index'; stacked => take layer 0).  Scalar for the shared
        -index caches of ``init_cache``; ``[batch]`` for the per-slot
        caches of ``init_slot_cache`` (continuous batching)."""
        idx = None

        def find(path, leaf):
            nonlocal idx
            if idx is None and any(
                    getattr(p, "name", "") == "index" for p in path):
                idx = leaf
            return leaf

        jax.tree_util.tree_map_with_path(find, cache)
        if idx is None:
            return jnp.zeros((), jnp.int32)
        return idx[0]


def lm_loss(cfg: ModelConfig, logits, batch, aux
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal-LM objective: shifted xent + z-loss + (MoE) aux loss.
    Shared by the plain and pipelined (core/pipeline.py) paths."""
    labels = batch["labels"]
    if cfg.family == "vlm":
        # text token i is predicted by position P + i - 1 of the
        # concatenated [patches; text] sequence
        Pn = batch["patch_embeds"].shape[1]
        logits = logits[:, Pn - 1:-1]
    else:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    mask = labels >= 0
    labels_safe = jnp.where(mask, labels, 0)
    # NB: no take_along_axis/log_softmax here — those force XLA to
    # all-gather the fp32 [B, S, vocab] logits per device when the vocab
    # dim is model-sharded.  logsumexp + a one-hot contraction partition
    # cleanly over the sharded vocab axis instead.
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    onehot = jax.nn.one_hot(labels_safe, logits.shape[-1],
                            dtype=logits32.dtype)
    label_logit = jnp.einsum("...v,...v->...", logits32, onehot)
    nll = lse - label_logit
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    # z-loss keeps the softmax normalizer in check (PaLM-style)
    zl = jnp.sum(jnp.where(mask, jnp.square(lse), 0.0)) / denom
    loss = ce + 1e-4 * zl + aux
    acc = jnp.sum(jnp.where(
        mask, (jnp.argmax(logits, -1) == labels_safe), False)) / denom
    return loss, {"ce": ce, "aux": aux, "zloss": zl, "accuracy": acc,
                  "tokens": denom.astype(jnp.float32)}


def cast_params(params, dtype):
    """Cast floating-point leaves (bf16 deployment of fp32-initialized params)."""
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, params)
