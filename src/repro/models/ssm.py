"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Training/prefill use chunked scans:
  * Mamba1 — per-channel diagonal recurrence; within-chunk
    ``lax.associative_scan``, inter-chunk state carried by a ``lax.scan``.
  * Mamba2 — the SSD block decomposition: the intra-chunk part is a masked
    (decay-weighted) attention-like matmul ``(L ∘ C Bᵀ) X`` and only chunk
    boundary states are materialized, which is the memory layout the Pallas
    kernel (kernels/mamba_scan.py) tiles into VMEM.

Decode keeps a constant-size state: conv ring buffer + SSM state — this is
what makes the ``long_500k`` shape native for ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


# --------------------------------------------------------------------- #
# causal depthwise conv (kernel size d_conv, shift-based)
# --------------------------------------------------------------------- #

def causal_conv(x, w, b):
    """x: [B, S, C]; w: [K, C]; b: [C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i:i + S] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def conv_step(x_new, conv_state, w, b):
    """One-token conv. x_new: [B, 1, C]; conv_state: [B, K-1, C] holds the
    previous K-1 inputs. Returns (y [B,1,C], new_state)."""
    full = jnp.concatenate([conv_state, x_new], axis=1)      # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y[:, None, :].astype(x_new.dtype), full[:, 1:]


# --------------------------------------------------------------------- #
# Mamba1
# --------------------------------------------------------------------- #

class SSMState(NamedTuple):
    conv: jax.Array   # [B, K-1, conv_channels]
    h: jax.Array      # mamba1: [B, d_inner, d_state]; mamba2: [B, nh, hd, ds]


def init_mamba1(rng, cfg: ModelConfig):
    s, d = cfg.ssm, cfg.d_model
    di, ds = s.expand * d, s.d_state
    dt_rank = max(1, (d + 15) // 16)
    r = jax.random.split(rng, 6)
    # S4D-real initialization of A
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(r[0], (d, 2 * di), d),
        "conv_w": dense_init(r[1], (s.d_conv, di), s.d_conv),
        "conv_b": jnp.zeros((di,)),
        "x_proj": dense_init(r[2], (di, dt_rank + 2 * ds), di),
        "dt_proj": dense_init(r[3], (dt_rank, di), dt_rank),
        "dt_bias": jnp.log(jnp.expm1(0.01 * jnp.ones((di,)))),  # softplus^-1
        "A_log": jnp.log(A),
        "D": jnp.ones((di,)),
        "out_proj": dense_init(r[4], (di, d), di),
    }


def _mamba1_inner(x_conv, z, params, cfg: ModelConfig, h0, chunk: int):
    """x_conv: [B, S, di] post-conv+silu; returns (y [B,S,di], h_last)."""
    s = cfg.ssm
    di, ds = s.expand * cfg.d_model, s.d_state
    dt_rank = params["dt_proj"].shape[0]
    dt = x_conv.dtype

    proj = jnp.einsum("bsc,cr->bsr", x_conv, params["x_proj"].astype(dt))
    dt_raw, B_s, C_s = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_raw, params["dt_proj"].astype(dt))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # [di, ds]

    S = x_conv.shape[1]
    pad = (-S) % chunk
    xp = jnp.pad(x_conv.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    dp = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B_s.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C_s.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    n = xp.shape[1] // chunk
    Bsz = x_conv.shape[0]

    def split_chunks(t):
        return t.reshape(Bsz, n, chunk, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    def body(h, inp):
        xc, dc, bc, cc = inp                    # [B,K,di],[B,K,di],[B,K,ds]x2
        a = jnp.exp(dc[..., None] * A)          # [B,K,di,ds]
        b = (dc * xc)[..., None] * bc[:, :, None, :]

        def comb(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])

        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        states = bb + aa * h[:, None]
        y = jnp.einsum("bkds,bks->bkd", states, cc)
        return states[:, -1], y

    h_last, ys = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (split_chunks(xp), split_chunks(dp), split_chunks(Bp), split_chunks(Cp)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, n * chunk, di)[:, :S]
    y = y + params["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(dt), h_last


def mamba1_forward(x, params, cfg: ModelConfig, *, state: SSMState = None,
                   use_pallas: bool = False):
    """x: [B, S, d] -> ([B, S, d], new_state or None)."""
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = causal_conv(x_in, params["conv_w"], params["conv_b"])
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(dt)
    B = x.shape[0]
    h0 = jnp.zeros((B, di, s.d_state), jnp.float32) if state is None else state.h
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        y, h_last = kernel_ops.mamba1_scan_op(
            x_conv, z, params, cfg, h0, chunk=s.chunk)
    else:
        y, h_last = _mamba1_inner(x_conv, z, params, cfg, h0, chunk=s.chunk)
    out = jnp.einsum("bsc,cd->bsd", y, params["out_proj"].astype(dt))
    new_state = None
    if state is not None:
        conv = jnp.concatenate([state.conv, x_in], axis=1)[:, -(s.d_conv - 1):]
        new_state = SSMState(conv=conv.astype(state.conv.dtype),
                             h=h_last.astype(state.h.dtype))
    return out, new_state


def mamba1_decode(x, params, cfg: ModelConfig, *, state: SSMState):
    """One token: x [B, 1, d]."""
    s = cfg.ssm
    ds = s.d_state
    dt = x.dtype
    dt_rank = params["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv = conv_step(x_in, state.conv, params["conv_w"], params["conv_b"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32))
    proj = jnp.einsum("bsc,cr->bsr", x_c.astype(dt), params["x_proj"].astype(dt))
    dt_raw, B_s, C_s = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_raw, params["dt_proj"].astype(dt))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))[:, 0]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(delta[..., None] * A)                      # [B, di, ds]
    b = (delta * x_c[:, 0])[..., None] * B_s[:, 0, None, :].astype(jnp.float32)
    h = a * state.h.astype(jnp.float32) + b
    y = jnp.einsum("bds,bs->bd", h, C_s[:, 0].astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32) * x_c[:, 0]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = jnp.einsum("bc,cd->bd", y.astype(dt), params["out_proj"].astype(dt))
    return out[:, None], SSMState(conv=conv.astype(state.conv.dtype),
                                  h=h.astype(state.h.dtype))


# --------------------------------------------------------------------- #
# Mamba2 / SSD
# --------------------------------------------------------------------- #

def _m2_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = s.n_heads or di // s.head_dim
    return di, nh, di // nh, s.d_state


def init_mamba2(rng, cfg: ModelConfig):
    s, d = cfg.ssm, cfg.d_model
    di, nh, hd, ds = _m2_dims(cfg)
    r = jax.random.split(rng, 4)
    conv_ch = di + 2 * ds
    return {
        "in_proj": dense_init(r[0], (d, 2 * di + 2 * ds + nh), d),
        "conv_w": dense_init(r[1], (s.d_conv, conv_ch), s.d_conv),
        "conv_b": jnp.zeros((conv_ch,)),
        "dt_bias": jnp.log(jnp.expm1(0.01 * jnp.ones((nh,)))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,)),
        "norm_scale": jnp.ones((di,)),   # gated RMSNorm before out_proj
        "out_proj": dense_init(r[2], (di, d), di),
    }


def _ssd_chunk_scan(xh, dt_h, B_s, C_s, A, h0, chunk: int):
    """SSD block decomposition.

    xh: [B, S, nh, hd]; dt_h: [B, S, nh]; B_s/C_s: [B, S, ds];
    A: [nh] (negative); h0: [B, nh, hd, ds].  Returns (y, h_last).
    """
    Bsz, S, nh, hd = xh.shape
    ds = B_s.shape[-1]
    pad = (-S) % chunk
    xp = jnp.pad(xh.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    dp = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B_s.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C_s.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    n = xp.shape[1] // chunk

    def split(t):
        return t.reshape(Bsz, n, chunk, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    def body(h, inp):
        xc, dc, bc, cc = inp          # [B,K,nh,hd],[B,K,nh],[B,K,ds],[B,K,ds]
        da = dc * A                   # [B,K,nh] log-decay increments (<=0)
        s_cum = jnp.cumsum(da, axis=1)               # [B,K,nh]
        # intra-chunk: M[i,j] = exp(s_i - s_j) dt_j (C_i . B_j), i >= j
        scores = jnp.einsum("bis,bjs->bij", cc, bc)  # [B,K,K]
        decay = s_cum[:, :, None, :] - s_cum[:, None, :, :]   # [B,i,j,nh]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        M = jnp.where(causal[None, :, :, None],
                      jnp.exp(decay) * dc[:, None, :, :], 0.0)
        M = M * scores[..., None]                     # [B,i,j,nh]
        y_intra = jnp.einsum("bijh,bjhd->bihd", M, xc)
        # inter-chunk: y_i += exp(s_i) C_i . h_carry
        y_inter = jnp.einsum("bis,bhds->bihd", cc, h) \
            * jnp.exp(s_cum)[..., None]
        y = y_intra + y_inter
        # state update: h' = exp(s_K) h + sum_j exp(s_K - s_j) dt_j x_j ⊗ B_j
        tail = jnp.exp(s_cum[:, -1:, :] - s_cum) * dc  # [B,K,nh]
        dh = jnp.einsum("bjh,bjhd,bjs->bhds", tail, xc, bc)
        h_new = jnp.exp(s_cum[:, -1])[:, :, None, None] * h + dh
        return h_new, y

    h_last, ys = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (split(xp), split(dp), split(Bp), split(Cp)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, n * chunk, nh, hd)[:, :S]
    return y, h_last


def mamba2_forward(x, params, cfg: ModelConfig, *, state: SSMState = None,
                   use_pallas: bool = False):
    s = cfg.ssm
    di, nh, hd, ds = _m2_dims(cfg)
    dt = x.dtype
    B = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ds], axis=-1)
    xBC = causal_conv(xBC, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(dt)
    x_in, B_s, C_s = jnp.split(xBC, [di, di + ds], axis=-1)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32)
                            + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = x_in.reshape(B, -1, nh, hd)
    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32) if state is None else \
        state.h.astype(jnp.float32)
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        y, h_last = kernel_ops.ssd_scan_op(xh, delta, B_s, C_s, A, h0,
                                           chunk=s.chunk)
    else:
        y, h_last = _ssd_chunk_scan(xh, delta, B_s, C_s, A, h0, chunk=s.chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, -1, di)
    # gated RMSNorm (mamba2 places the gate inside the norm)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    out = jnp.einsum("bsc,cd->bsd", y.astype(dt), params["out_proj"].astype(dt))
    new_state = None
    if state is not None:
        conv = jnp.concatenate(
            [state.conv, proj[..., di:2 * di + 2 * ds]], axis=1)[:, -(s.d_conv - 1):]
        new_state = SSMState(conv=conv.astype(state.conv.dtype),
                             h=h_last.astype(state.h.dtype))
    return out, new_state


def mamba2_decode(x, params, cfg: ModelConfig, *, state: SSMState):
    s = cfg.ssm
    di, nh, hd, ds = _m2_dims(cfg)
    dt = x.dtype
    B = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ds], axis=-1)
    xBC_c, conv = conv_step(xBC, state.conv, params["conv_w"], params["conv_b"])
    xBC_c = jax.nn.silu(xBC_c.astype(jnp.float32))
    x_in, B_s, C_s = jnp.split(xBC_c[:, 0], [di, di + ds], axis=-1)
    delta = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                            + params["dt_bias"].astype(jnp.float32))  # [B,nh]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(delta * A)                                  # [B, nh]
    xh = x_in.reshape(B, nh, hd)
    dh = jnp.einsum("bh,bhd,bs->bhds", delta, xh, B_s)
    h = a[:, :, None, None] * state.h.astype(jnp.float32) + dh
    y = jnp.einsum("bhds,bs->bhd", h, C_s)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, di)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    out = jnp.einsum("bc,cd->bd", y.astype(dt), params["out_proj"].astype(dt))
    return out[:, None], SSMState(conv=conv.astype(state.conv.dtype),
                                  h=h.astype(state.h.dtype))


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    if s.version == 1:
        di = s.expand * cfg.d_model
        return SSMState(conv=jnp.zeros((batch, s.d_conv - 1, di), dtype),
                        h=jnp.zeros((batch, di, s.d_state), jnp.float32))
    di, nh, hd, ds = _m2_dims(cfg)
    return SSMState(conv=jnp.zeros((batch, s.d_conv - 1, di + 2 * ds), dtype),
                    h=jnp.zeros((batch, nh, hd, ds), jnp.float32))
