"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch is done megablocks-style rather than with a GShard one-hot tensor:
tokens are sorted by their assigned expert, packed into a fixed-capacity
``[E, C, d]`` buffer (scatter), batch-matmul'd through the experts and
scattered back with the router weights.  The ``[E, C, d]`` buffer is what
gets sharded on the expert axis for expert parallelism — under the `shard`
plan the scatter/gather lowers to the all-to-all the paper's Alpa plans use.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_moe(rng, cfg: ModelConfig):
    m, d = cfg.moe, cfg.d_model
    eff = m.expert_d_ff or cfg.d_ff
    r = jax.random.split(rng, 5)
    p = {
        "router": dense_init(r[0], (d, m.n_experts), d),
        "w_gate": dense_init(r[1], (m.n_experts, d, eff), d),
        "w_up": dense_init(r[2], (m.n_experts, d, eff), d),
        "w_down": dense_init(r[3], (m.n_experts, eff, d), eff),
    }
    if m.n_shared_experts:
        ns = m.n_shared_experts
        rs = jax.random.split(r[4], 3)
        p["shared_gate"] = dense_init(rs[0], (d, ns * eff), d)
        p["shared_up"] = dense_init(rs[1], (d, ns * eff), d)
        p["shared_down"] = dense_init(rs[2], (ns * eff, d), ns * eff)
    return p


def _expert_ffn(buf, params):
    """buf: [E, C, d] -> [E, C, d] through per-expert SwiGLU."""
    dt = buf.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))


def moe_forward(x, params, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Dispatch wrapper: ``cfg.moe_dispatch_axes`` (set by the step
    builders under SPMD plans) switches to per-data-shard local routing.

    The global path sorts ALL tokens jointly — on a 256-chip mesh that
    lowers to an all-gather of the full [T, d] token matrix per MoE layer
    (measured 2.5e6 ms of collective time for deepseek-v2 prefill_32k,
    EXPERIMENTS.md §Perf H1).  The sharded path routes each data shard's
    tokens locally inside a partial-manual shard_map; expert weights stay
    model-axis sharded in auto-SPMD, so the only cross-device traffic left
    is the token/expert all-to-all XLA inserts for the expert einsum."""
    axes = getattr(cfg, "moe_dispatch_axes", None) or ()
    if not axes:
        return _moe_forward_impl(x, params, cfg)
    axes = tuple(axes)
    dt = x.dtype

    @partial(jax.shard_map, axis_names=set(axes),
             in_specs=(P(axes if len(axes) > 1 else axes[0]), P()),
             out_specs=(P(axes if len(axes) > 1 else axes[0]), P()),
             check_vma=False)
    def run(x_loc, p):
        # fp32 at every shard_map boundary (activations AND param/cotangent
        # leaves): the XLA CPU SPMD partitioner CHECK-fails transposing
        # bf16 through partial-manual shard_map (same bug + workaround as
        # core/pipeline.py's carriers).
        out, aux = _moe_forward_impl(x_loc, p, cfg)
        return out.astype(jnp.float32), \
            jax.lax.pmean(aux, axes if len(axes) > 1 else axes[0])

    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    out, aux = run(x.astype(jnp.float32), p32)
    return out.astype(dt), aux


def _moe_forward_impl(x, params, cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d].  Returns (out, aux_loss).

    aux_loss is the standard load-balance loss  E * sum_e f_e * p_e  where
    f_e = fraction of tokens routed to e, p_e = mean router prob of e.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    dt = x.dtype
    xf = x.reshape(T, d)

    # router matmul in the model dtype (casting xf to fp32 here doubles
    # the bytes of every activation gather XLA schedules around it);
    # only the softmax runs in fp32
    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # [T, E]
    gate_vals, choices = jax.lax.top_k(probs, m.top_k)           # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load balance auxiliary ------------------------------------- #
    assign_onehot = jax.nn.one_hot(choices, m.n_experts, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(assign_onehot, axis=1), axis=0)       # [E]
    p_e = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f_e * p_e) * m.router_aux_coef

    # ---- sort-based dispatch ----------------------------------------- #
    E = m.n_experts
    # capacity floor keeps tiny decode batches drop-free
    cap = min(max(int(m.capacity_factor * T * m.top_k / E) + 1,
                  min(T, 16)), T)
    flat_expert = choices.reshape(-1)                            # [T*k]
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), m.top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within expert = running index - offset of this expert's first slot
    counts = jnp.bincount(sorted_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * m.top_k, dtype=jnp.int32) - offsets[sorted_expert]
    keep = rank < cap
    slot = sorted_expert * cap + jnp.where(keep, rank, 0)        # [T*k]

    buf = jnp.zeros((E * cap, d), dt)
    gathered = jnp.where(keep[:, None], xf[sorted_token], 0).astype(dt)
    buf = buf.at[slot].add(gathered)                              # scatter
    buf = buf.reshape(E, cap, d)
    # pin the buffer expert-sharded: otherwise XLA replicates the full
    # [E, cap, d] buffer across the model axis before the expert einsum
    # (~16x the necessary traffic; EXPERIMENTS.md §Perf H1 iter 2)
    expert_axis = getattr(cfg, "moe_expert_axis", "")
    if expert_axis:
        buf = jax.lax.with_sharding_constraint(buf, P(expert_axis))
    out_buf = _expert_ffn(buf, params)
    if expert_axis:
        out_buf = jax.lax.with_sharding_constraint(out_buf, P(expert_axis))
    out_buf = out_buf.reshape(E * cap, d)

    contrib = out_buf[slot] * (sorted_gate * keep)[:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[sorted_token].add(contrib)

    # ---- shared (always-on) experts ----------------------------------- #
    if m.n_shared_experts:
        g = jnp.einsum("td,df->tf", xf, params["shared_gate"].astype(dt))
        u = jnp.einsum("td,df->tf", xf, params["shared_up"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        out = out + jnp.einsum("tf,fd->td", h, params["shared_down"].astype(dt))

    return out.reshape(B, S, d), aux
