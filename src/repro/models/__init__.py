from repro.models.model import Model, cast_params

__all__ = ["Model", "cast_params"]
