from repro.models.model import Model, cast_params
from repro.models.registry import abstractify

__all__ = ["Model", "cast_params", "abstractify"]
