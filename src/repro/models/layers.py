"""Primitive layers shared by every architecture family.

All parameters live in plain nested dicts of jnp arrays so that sharding
rules (core/sharding.py) can match on key paths, layers can be stacked on a
leading ``[n_layers, ...]`` axis for ``lax.scan``, and ``jax.eval_shape``
can produce allocation-free ShapeDtypeStructs for the multi-pod dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------- #

def dense_init(rng, shape, in_dim: Optional[int] = None, dtype=jnp.float32,
               scale: float = 1.0):
    """Truncated-normal fan-in init (std = scale / sqrt(in_dim))."""
    if in_dim is None:
        in_dim = shape[0]
    std = scale / math.sqrt(max(in_dim, 1))
    return (std * jax.random.truncated_normal(rng, -3.0, 3.0, shape)).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (0.02 * jax.random.truncated_normal(rng, -3.0, 3.0, shape)).astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------- #
# normalization
# --------------------------------------------------------------------- #

def rmsnorm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(rng, d: int, kind: str):
    del rng
    if kind == "rmsnorm":
        return {"scale": ones((d,))}
    return {"scale": ones((d,)), "bias": zeros((d,))}


def apply_norm(x, params, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps)
    return layernorm(x, params["scale"], params["bias"], eps)


# --------------------------------------------------------------------- #
# rotary position embeddings
# --------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D] (rotate pairs (x[..2i], x[..2i+1]));
    positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# activations / MLP
# --------------------------------------------------------------------- #

def init_mlp(rng, d: int, d_ff: int, activation: str):
    r = jax.random.split(rng, 3)
    if activation == "silu":  # SwiGLU: gate + up + down
        return {
            "w_gate": dense_init(r[0], (d, d_ff), d),
            "w_up": dense_init(r[1], (d, d_ff), d),
            "w_down": dense_init(r[2], (d_ff, d), d_ff),
        }
    return {  # plain GELU MLP (gpt2 / whisper)
        "w_up": dense_init(r[0], (d, d_ff), d),
        "b_up": zeros((d_ff,)),
        "w_down": dense_init(r[1], (d_ff, d), d_ff),
        "b_down": zeros((d,)),
    }


def apply_mlp(x, params, activation: str):
    if activation == "silu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
    h = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = h + params["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
    return out + params["b_down"].astype(x.dtype)


# --------------------------------------------------------------------- #
# embeddings
# --------------------------------------------------------------------- #

def init_embedding(rng, vocab: int, d: int):
    return {"table": embed_init(rng, (vocab, d))}


def embed(tokens, params, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(x, params, dtype):
    """Project back to vocabulary; logits in fp32 for a stable softmax."""
    table = params["table"].astype(dtype)
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)


def init_learned_positions(rng, max_seq: int, d: int):
    return {"table": embed_init(rng, (max_seq, d))}
