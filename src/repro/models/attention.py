"""Attention: chunked flash-style causal GQA, sliding windows, decode over
KV caches, and Multi-head Latent Attention (MLA) with an absorbed-matmul
latent-cache decode path.

The chunked implementation is the memory-bounded pure-jnp path (and the
oracle for kernels/flash_attention.py); on TPU the Pallas kernel can be
swapped in via ``use_pallas`` in the model call.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# chunked (flash-style) attention over full sequences
# --------------------------------------------------------------------- #

def _pad_to_multiple(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    q_positions=None,
    kv_positions=None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    use_pallas: bool = False,
):
    """Memory-bounded attention.

    q: [B, Sq, H, Dk]; k: [B, Sk, KV, Dk]; v: [B, Sk, KV, Dv]; H % KV == 0.
    Softmax accumulates in fp32 with the online max/denominator recurrence.
    Returns [B, Sq, H, Dv].
    """
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.flash_attention(
            q, k, v, causal=causal, window=window)

    B, Sq, H, Dk = q.shape
    _, Sk, KV, Dv = v.shape
    group = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dk, jnp.float32))

    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(Sk, dtype=jnp.int32)[None, :]
    q_positions = jnp.broadcast_to(q_positions, (B, Sq))
    kv_positions = jnp.broadcast_to(kv_positions, (B, Sk))

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    q, _ = _pad_to_multiple(q, q_chunk, 1)
    qpos, _ = _pad_to_multiple(q_positions, q_chunk, 1)
    k, _ = _pad_to_multiple(k, k_chunk, 1)
    v, _ = _pad_to_multiple(v, k_chunk, 1)
    # padded kv slots get position +inf-ish so the causal mask kills them
    kpos = jnp.pad(kv_positions, ((0, 0), (0, (-Sk) % k_chunk)),
                   constant_values=jnp.iinfo(jnp.int32).max)
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // k_chunk

    qc = q.reshape(B, nq, q_chunk, H, Dk).transpose(1, 0, 2, 3, 4)
    qp = qpos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kc = k.reshape(B, nk, k_chunk, KV, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, KV, Dv).transpose(1, 0, 2, 3, 4)
    kp = kpos.reshape(B, nk, k_chunk).transpose(1, 0, 2)

    def q_block(carry, q_in):
        qi, qpi = q_in  # [B, Cq, H, Dk], [B, Cq]
        qi32 = (qi.astype(jnp.float32) * scale).reshape(
            B, q_chunk, KV, group, Dk)

        @jax.checkpoint
        def kv_block(acc, kv_in):
            m, l, o = acc
            kj, vj, kpj = kv_in
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qi32, kj.astype(jnp.float32))
            if causal:  # mask: [B, Cq, Cj]
                mask = qpi[:, :, None] >= kpj[:, None, :]
                if window:
                    mask &= (qpi[:, :, None] - kpj[:, None, :]) < window
            else:  # only mask padded kv slots
                mask = jnp.broadcast_to(
                    (kpj < jnp.iinfo(jnp.int32).max)[:, None, :],
                    (B, q_chunk, kpj.shape[1]))
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkgqj,bjkd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        acc0 = (
            jnp.full((B, KV, group, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, group, q_chunk), jnp.float32),
            jnp.zeros((B, KV, group, q_chunk, Dv), jnp.float32),
        )
        (m, l, o), _ = jax.lax.scan(kv_block, acc0, (kc, vc, kp))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, Dv)
        return carry, out.astype(q.dtype)

    # remat on both scan levels: without it AD saves the fp32 [Cq, Ck]
    # probability chunks for every (q, kv) block pair — the O(S²) memory
    # that flash attention exists to avoid (the Pallas kernel does this
    # structurally; this is the jnp path's equivalent).
    q_block = jax.checkpoint(q_block)
    _, out = jax.lax.scan(q_block, None, (qc, qp))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq]


# --------------------------------------------------------------------- #
# decode attention over a (possibly ring-buffered) KV cache
# --------------------------------------------------------------------- #

def _ring_valid(index, batch: int, capacity: int):
    """Filled-slot mask [batch, capacity] for a ring index that is either
    a scalar (one write position shared by the whole batch — the fixed
    -batch engine) or per-slot ``[batch]`` (continuous batching, where
    every slot tracks its own fill; serve/engine.ContinuousEngine)."""
    slots = jnp.arange(capacity, dtype=jnp.int32)
    filled = jnp.minimum(index, capacity)
    if index.ndim == 0:
        return jnp.broadcast_to(slots[None, :] < filled, (batch, capacity))
    return slots[None, :] < filled[:, None]


def _append_token(buf, new, slot):
    """Write one token's row (``new``: [B, 1, ...]) into ``buf``
    ([B, S, ...]) at ring position ``slot`` — a scalar (shared index) or
    per-slot ``[B]`` vector (each batch row writes its own position)."""
    new = new.astype(buf.dtype)
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, slot, 1)
    return jax.vmap(
        lambda b, n, s: jax.lax.dynamic_update_slice_in_dim(b, n, s, 0)
    )(buf, new, slot)


def _decode_positions(index):
    """RoPE positions [*, 1] of the token being decoded: the cache index
    broadcast ([1, 1]) for a scalar index, per-slot [B, 1] otherwise."""
    return index[None, None] if index.ndim == 0 else index[:, None]


def decode_attention(q, k_cache, v_cache, valid_mask):
    """One-token attention. q: [B, 1, H, Dk]; caches [B, S, KV, D*];
    valid_mask: [B, S] bool marking filled slots."""
    B, _, H, Dk = q.shape
    KV = k_cache.shape[2]
    group = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dk, jnp.float32))
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, group, Dk)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, -1).astype(q.dtype)


class KVCache(NamedTuple):
    """Ring-buffered KV cache (window=0 => plain cache of full length)."""
    k: jax.Array          # [B, S, KV, Dk]
    v: jax.Array          # [B, S, KV, Dv]
    index: jax.Array      # int32 next write position (total tokens):
                          # scalar (shared) or [B] (per-slot, continuous
                          # batching)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]

    def slot_positions(self):
        """Absolute position stored in each slot; -1 for empty slots."""
        S = self.capacity
        slots = jnp.arange(S, dtype=jnp.int32)
        n = self.index
        # slot s holds position: the largest p < n with p % S == s
        last = n - 1 - (n - 1 - slots) % S
        return jnp.where(slots < jnp.minimum(n, S), jnp.where(
            last >= 0, last, -1), jnp.where(last >= n - S, last, -1))

    def valid(self, batch: int):
        return _ring_valid(self.index, batch, self.capacity)


def init_kv_cache(batch: int, capacity: int, kv_heads: int, dk: int, dv: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, kv_heads, dk), dtype),
        v=jnp.zeros((batch, capacity, kv_heads, dv), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def cache_append(cache: KVCache, k_new, v_new) -> KVCache:
    """Append one token (k_new/v_new: [B, 1, KV, D]) at the ring position."""
    slot = jnp.mod(cache.index, cache.capacity)
    k = _append_token(cache.k, k_new, slot)
    v = _append_token(cache.v, v_new, slot)
    return KVCache(k=k, v=v, index=cache.index + 1)


# --------------------------------------------------------------------- #
# int8-quantized KV cache (docs/quantization.md): per-(token, head)
# absmax scales over head_dim; decode attends through the int8-KV Pallas
# kernel (kernels/quantized.py) with the ring fill state as its dynamic
# key-validity mask
# --------------------------------------------------------------------- #

class QuantKVCache(NamedTuple):
    """Ring-buffered int8 KV cache — 4x smaller than the fp32 ``KVCache``
    at the cost of one absmax scale per (token, kv-head)."""
    k_q: jax.Array        # [B, S, KV, Dk] int8
    k_scale: jax.Array    # [B, S, KV] fp32
    v_q: jax.Array        # [B, S, KV, Dv] int8
    v_scale: jax.Array    # [B, S, KV] fp32
    index: jax.Array      # int32 next write position: scalar or [B]

    @property
    def capacity(self) -> int:
        return self.k_q.shape[1]

    def valid(self, batch: int):
        return _ring_valid(self.index, batch, self.capacity)


def init_quant_kv_cache(batch: int, capacity: int, kv_heads: int, dk: int,
                        dv: int) -> QuantKVCache:
    return QuantKVCache(
        k_q=jnp.zeros((batch, capacity, kv_heads, dk), jnp.int8),
        k_scale=jnp.ones((batch, capacity, kv_heads), jnp.float32),
        v_q=jnp.zeros((batch, capacity, kv_heads, dv), jnp.int8),
        v_scale=jnp.ones((batch, capacity, kv_heads), jnp.float32),
        index=jnp.zeros((), jnp.int32),
    )


def _quant_kv(x):
    """[B, S, KV, D] fp -> (int8 payload, [B, S, KV] fp32 scales): one
    absmax block spanning the whole head_dim per (token, kv-head)."""
    from repro.kernels import ops as kernel_ops
    q, s = kernel_ops.quantize(x, block=x.shape[-1], axis=-1)
    return q, s[..., 0]


def quant_cache_append(cache: QuantKVCache, k_new, v_new) -> QuantKVCache:
    """Quantize + append one token (k_new/v_new: [B, 1, KV, D])."""
    slot = jnp.mod(cache.index, cache.capacity)
    kq, ks = _quant_kv(k_new)
    vq, vs = _quant_kv(v_new)
    return QuantKVCache(
        k_q=_append_token(cache.k_q, kq, slot),
        k_scale=_append_token(cache.k_scale, ks, slot),
        v_q=_append_token(cache.v_q, vq, slot),
        v_scale=_append_token(cache.v_scale, vs, slot),
        index=cache.index + 1)


def _ring_fill(buf, new, S: int):
    """Prefill a ring buffer leaf: keep the most recent ``capacity``
    entries of ``new`` [B, S, ...] in slot = pos % capacity layout."""
    cap = buf.shape[1]
    if S >= cap:
        roll = -((S - cap) % cap) if cap else 0
        return jnp.roll(new[:, S - cap:], roll, axis=1).astype(buf.dtype)
    return jax.lax.dynamic_update_slice_in_dim(
        buf, new.astype(buf.dtype), 0, 1)


def quant_cache_prefill(cache: QuantKVCache, k, v, S: int) -> QuantKVCache:
    """Fill the quantized cache from full-sequence k/v [B, S, KV, D]."""
    kq, ks = _quant_kv(k)
    vq, vs = _quant_kv(v)
    return QuantKVCache(
        k_q=_ring_fill(cache.k_q, kq, S),
        k_scale=_ring_fill(cache.k_scale, ks, S),
        v_q=_ring_fill(cache.v_q, vq, S),
        v_scale=_ring_fill(cache.v_scale, vs, S),
        index=jnp.asarray(S, jnp.int32))


def quant_decode_attention(q, cache: QuantKVCache):
    """One-token attention over the int8 cache via the Pallas int8-KV
    kernel; the traced ring fill state rides the kernel's dynamic
    key-validity input.  Every cached token is in the past, so the mask
    alone (causal=False) reproduces ``decode_attention``'s semantics."""
    from repro.kernels import ops as kernel_ops
    B = q.shape[0]
    return kernel_ops.flash_attention_int8kv(
        q, cache.k_q, cache.k_scale, cache.v_q, cache.v_scale,
        valid=cache.valid(B).astype(jnp.float32), causal=False, block_q=8)


# --------------------------------------------------------------------- #
# standard GQA attention parameters
# --------------------------------------------------------------------- #

def init_attention(rng, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(r[0], (d, H, hd), d),
        "wk": dense_init(r[1], (d, KV, hd), d),
        "wv": dense_init(r[2], (d, KV, hd), d),
        "wo": dense_init(r[3], (H, hd, d), H * hd),
    }
    if cfg.norm == "layernorm":  # gpt2/whisper-style attention biases
        p["bq"] = jnp.zeros((H, hd))
        p["bk"] = jnp.zeros((KV, hd))
        p["bv"] = jnp.zeros((KV, hd))
        p["bo"] = jnp.zeros((d,))
    return p


def _qkv(x, params, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return q, k, v


def _out(o, params):
    dt = o.dtype
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    if "bo" in params:
        y = y + params["bo"].astype(dt)
    return y


def attention_forward(x, params, cfg: ModelConfig, *, positions,
                      causal: bool = True, window: int = 0,
                      use_pallas: bool = False):
    """Full-sequence attention (train / prefill / encoder)."""
    q, k, v = _qkv(x, params, cfg)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=causal, window=window,
                          q_positions=positions, kv_positions=positions,
                          use_pallas=use_pallas)
    return _out(o, params)


def attention_prefill(x, params, cfg: ModelConfig, *, positions,
                      cache: KVCache, window: int = 0):
    """Prefill: run full attention AND fill the cache with k/v."""
    q, k, v = _qkv(x, params, cfg)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, kv_positions=positions)
    S = x.shape[1]
    if isinstance(cache, QuantKVCache):
        return _out(o, params), quant_cache_prefill(cache, k, v, S)
    cap = cache.capacity
    if S >= cap:  # keep the most recent `cap` tokens
        k_keep, v_keep = k[:, S - cap:], v[:, S - cap:]
        # ring layout: slot = pos % cap
        roll = -((S - cap) % cap) if cap else 0
        k_keep = jnp.roll(k_keep, roll, axis=1)
        v_keep = jnp.roll(v_keep, roll, axis=1)
        new = KVCache(k=k_keep.astype(cache.k.dtype),
                      v=v_keep.astype(cache.v.dtype),
                      index=jnp.asarray(S, jnp.int32))
    else:
        k_full = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), 0, 1)
        v_full = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), 0, 1)
        new = KVCache(k=k_full, v=v_full, index=jnp.asarray(S, jnp.int32))
    return _out(o, params), new


def attention_decode(x, params, cfg: ModelConfig, *, cache: KVCache,
                     window: int = 0):
    """One-token decode: x [B, 1, d]."""
    B = x.shape[0]
    q, k, v = _qkv(x, params, cfg)
    pos = _decode_positions(cache.index)
    if cfg.rope_theta:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if isinstance(cache, QuantKVCache):
        cache = quant_cache_append(cache, k, v)
        o = quant_decode_attention(q, cache)
    else:
        cache = cache_append(cache, k, v)
        o = decode_attention(q, cache.k, cache.v, cache.valid(B))
    return _out(o, params), cache


# --------------------------------------------------------------------- #
# Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2)
# --------------------------------------------------------------------- #

class MLACache(NamedTuple):
    c_kv: jax.Array      # [B, S, R] latent cache
    k_rope: jax.Array    # [B, S, rope_dim]
    index: jax.Array

    @property
    def capacity(self) -> int:
        return self.c_kv.shape[1]

    def valid(self, batch: int):
        return _ring_valid(self.index, batch, self.capacity)


def init_mla_cache(batch: int, capacity: int, mla: MLAConfig, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, capacity, mla.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, capacity, mla.rope_head_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def init_mla(rng, cfg: ModelConfig):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    r = jax.random.split(rng, 8)
    p = {}
    q_in = d
    if m.q_lora_rank:
        p["w_dq"] = dense_init(r[0], (d, m.q_lora_rank), d)
        p["q_norm"] = jnp.ones((m.q_lora_rank,))
        q_in = m.q_lora_rank
    p["w_uq"] = dense_init(r[1], (q_in, H, m.nope_head_dim + m.rope_head_dim), q_in)
    p["w_dkv"] = dense_init(r[2], (d, m.kv_lora_rank), d)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,))
    p["w_kr"] = dense_init(r[3], (d, m.rope_head_dim), d)
    p["w_uk"] = dense_init(r[4], (H, m.kv_lora_rank, m.nope_head_dim), m.kv_lora_rank)
    p["w_uv"] = dense_init(r[5], (H, m.kv_lora_rank, m.v_head_dim), m.kv_lora_rank)
    p["wo"] = dense_init(r[6], (H, m.v_head_dim, d), H * m.v_head_dim)
    return p


def _mla_q(x, params, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm
    m, dt = cfg.mla, x.dtype
    if "w_dq" in params:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt))
        cq = rmsnorm(cq, params["q_norm"], cfg.norm_eps)
    else:
        cq = x
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(x, params, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm
    dt = x.dtype
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["w_kr"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(x, params, cfg: ModelConfig, *, positions, window: int = 0,
                use_pallas: bool = False):
    """Full-sequence MLA: decompress K/V per head and run chunked attention."""
    m, dt = cfg.mla, x.dtype
    q_nope, q_rope = _mla_q(x, params, cfg, positions)
    c_kv, k_rope = _mla_latent(x, params, cfg, positions)
    k_nope = jnp.einsum("bsr,hrk->bshk", c_kv, params["w_uk"].astype(dt))
    v = jnp.einsum("bsr,hrk->bshk", c_kv, params["w_uv"].astype(dt))
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], H, m.rope_head_dim))],
        axis=-1)
    o = chunked_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, kv_positions=positions,
                          use_pallas=use_pallas)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))


def mla_prefill(x, params, cfg: ModelConfig, *, positions, cache: MLACache,
                window: int = 0):
    out = mla_forward(x, params, cfg, positions=positions, window=window)
    c_kv, k_rope = _mla_latent(x, params, cfg, positions)
    S, cap = x.shape[1], cache.capacity
    if S >= cap:
        roll = -((S - cap) % cap) if cap else 0
        c_keep = jnp.roll(c_kv[:, S - cap:], roll, axis=1)
        r_keep = jnp.roll(k_rope[:, S - cap:], roll, axis=1)
        new = MLACache(c_kv=c_keep.astype(cache.c_kv.dtype),
                       k_rope=r_keep.astype(cache.k_rope.dtype),
                       index=jnp.asarray(S, jnp.int32))
    else:
        new = MLACache(
            c_kv=jax.lax.dynamic_update_slice_in_dim(
                cache.c_kv, c_kv.astype(cache.c_kv.dtype), 0, 1),
            k_rope=jax.lax.dynamic_update_slice_in_dim(
                cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, 1),
            index=jnp.asarray(S, jnp.int32))
    return out, new


def mla_decode(x, params, cfg: ModelConfig, *, cache: MLACache,
               window: int = 0):
    """Absorbed-matmul decode: scores computed directly in latent space, so
    the cache stays [B, S, kv_lora + rope] — MLA's memory win."""
    m, dt = cfg.mla, x.dtype
    B = x.shape[0]
    pos = _decode_positions(cache.index)
    q_nope, q_rope = _mla_q(x, params, cfg, pos)          # [B,1,H,*]
    c_new, r_new = _mla_latent(x, params, cfg, pos)       # [B,1,R], [B,1,rope]
    slot = jnp.mod(cache.index, cache.capacity)
    cache = MLACache(
        c_kv=_append_token(cache.c_kv, c_new, slot),
        k_rope=_append_token(cache.k_rope, r_new, slot),
        index=cache.index + 1)
    # absorb W_uk into q: q_lat[h] = q_nope[h] @ W_uk[h]
    q_lat = jnp.einsum("bqhk,hrk->bqhr", q_nope, params["w_uk"].astype(dt))
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.nope_head_dim + m.rope_head_dim,
                                       jnp.float32))
    s = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                   cache.c_kv.astype(jnp.float32))
    s += jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32),
                    cache.k_rope.astype(jnp.float32))
    s = s * scale
    s = jnp.where(cache.valid(B)[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", w, cache.c_kv.astype(jnp.float32))
    o = jnp.einsum("bqhr,hrk->bqhk", ctx_lat.astype(dt),
                   params["w_uv"].astype(dt))
    return jnp.einsum("bqhk,hkd->bqd", o, params["wo"].astype(dt)), cache
