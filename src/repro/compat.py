"""Compatibility shims for older jax releases (see DESIGN.md §6).

The codebase is written against the modern jax API (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh`` with
``axis_types``).  The pinned toolchain in some environments ships jax
0.4.x where those live elsewhere (or don't exist); importing any
``repro`` package installs equivalents into the ``jax`` namespace so both
the library and its tests run unchanged on either version:

  * ``jax.set_mesh(mesh)``   -> the Mesh context manager itself (the
    0.4.x global-mesh context has the same scope semantics for our
    ``with jax.set_mesh(mesh):`` usage);
  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
    axis_names=..., check_vma=...)`` -> ``jax.experimental.shard_map``
    with ``auto = mesh.axis_names - axis_names`` and
    ``check_rep = check_vma``;
  * ``jax.sharding.AxisType``  -> a small stand-in enum (only ever used
    to request Auto axes, which is 0.4.x's only behavior anyway).

``make_mesh(shape, axes)`` here is the version-agnostic constructor —
prefer it over calling ``jax.make_mesh`` with ``axis_types`` directly.
"""
from __future__ import annotations

import enum
import inspect
from typing import Optional, Sequence

import jax
import jax.sharding


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


_HAS_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters

# Native modern shard_map implies a partitioner that supports the
# partial-manual (ManualSubgroup) SPMD pattern; the 0.4.x experimental
# shard_map accepts `auto=` but its XLA CHECK-fails partitioning the
# surrounding auto region (pipeshard pipeline, per-shard MoE dispatch):
# on jax 0.4.37 the process aborts with
#   F xla/hlo/utils/hlo_sharding_util.cc:2750]
#   Check failed: sharding.IsManualSubgroup()
# — a fatal C++ CHECK, not a Python exception, so it cannot be caught
# and turned into a skip at runtime.  Paths needing partial-auto gate on
# this flag instead (evaluated before the shims below are installed, so
# it reflects the real jax).  Full triage: docs/architecture.md
# §"Slow tests and the jax 0.4.x gate".
NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              *, devices=None) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types where supported."""
    kw = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPES:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def _set_mesh(mesh):
    """0.4.x fallback for jax.set_mesh: the Mesh *is* a context manager
    that scopes the global physical mesh."""
    return mesh


def _ambient_mesh():
    """The mesh installed by the 0.4.x global-mesh context (`with mesh:`,
    which is what our set_mesh shim scopes)."""
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    if m.empty:
        raise ValueError("shard_map without mesh= needs an enclosing "
                         "jax.set_mesh(...)")
    return m


def _shard_map(f=None, *, mesh=None, in_specs, out_specs, axis_names=None,
               check_vma: bool = True):
    from jax.experimental.shard_map import shard_map as _sm

    def bind(fn):
        def call(*args):
            m = mesh if mesh is not None else _ambient_mesh()
            auto = frozenset()
            if axis_names is not None:
                auto = frozenset(m.axis_names) - frozenset(axis_names)
            sm = _sm(fn, mesh=m, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma, auto=auto)
            return sm(*args)
        return call
    return bind if f is None else bind(f)


def install() -> None:
    """Idempotently add missing modern-API names to the jax namespace."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map


install()
