"""Training loop: plan-aware pretraining driver.

Mirrors the paper's measurement methodology (§III-B): wall-clock per epoch
and average achieved TFLOP/s (model FLOPs 6·N·D / step time), which is what
Algorithm 1 probes when choosing a technique.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.plans import Plan
from repro.core.steps import build_train_step
from repro.models.model import Model
from repro.models.registry import abstractify
from repro.optim import init_adamw
from repro.train.checkpoint import save_checkpoint


@dataclass
class TrainResult:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    metrics_last: Dict[str, float] = field(default_factory=dict)

    @property
    def avg_step_time(self) -> float:
        times = self.step_times[1:] or self.step_times  # drop compile step
        return float(np.mean(times)) if times else float("nan")

    def tflops(self, model_flops_per_step: float) -> float:
        t = self.avg_step_time
        return model_flops_per_step / t / 1e12 if t > 0 else 0.0


def model_flops_per_step(cfg: ModelConfig, tokens_per_step: int) -> float:
    """6·N_active·D — the paper's 'training performance' denominator."""
    return 6.0 * cfg.active_param_count() * tokens_per_step


def train(model: Model, plan: Plan, mesh, tcfg: TrainConfig, loader, *,
          steps: int, params=None, opt_state=None,
          log_every: int = 10, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 0, stage_layers=None,
          schedule: str = "gpipe", start_step: int = 0,
          on_step_failure: Optional[Callable[[int], None]] = None,
          log_fn: Callable[[str], None] = print) -> TrainResult:
    """Plan-aware training driver; ``stage_layers`` and ``schedule``
    thread a searched pipeline ``Placement``'s per-stage layer split and
    tick-order schedule into the step builder (uneven splits run
    pad-and-masked, alternative schedules via the scheduled runner —
    core/pipeline.py, docs/schedules.md).

    ``start_step`` resumes mid-run: steps ``start_step..steps-1`` are
    executed against the same deterministic batch sequence
    (``loader.batch_at(i)``) and absolute step numbers, so a restored
    checkpoint continues exactly where the original run would have been
    — the elastic-recovery resume path (``repro.train.replan``,
    docs/elasticity.md).

    ``on_step_failure`` is the fault-injection hook: called with the
    absolute step index before each step executes; raising from it
    (e.g. ``repro.train.replan.SiteFailure``, via ``kill_site_at``)
    kills the run deterministically mid-epoch — the exception leaves
    ``train`` with the partial ``TrainResult`` attached as its
    ``result`` attribute, so the chaos benchmark can account for
    steps-lost and pre-failure step times.
    """
    cfg = model.cfg
    with jax.set_mesh(mesh):
        if params is None:
            params = model.init(jax.random.key(tcfg.seed))
        if opt_state is None:
            opt_state = init_adamw(params)
        first = loader.batch_at(start_step)
        p_shapes = abstractify(params)
        b_shapes = abstractify(first)
        step_fn, sh = build_train_step(model, plan, mesh, tcfg,
                                       params_shapes=p_shapes,
                                       batch_shapes=b_shapes,
                                       stage_layers=stage_layers,
                                       schedule=schedule)
        params = jax.device_put(params, sh["params"])
        opt_state = jax.device_put(opt_state, sh["opt"])

        result = TrainResult()
        metrics: Dict[str, Any] = {}
        flops = model_flops_per_step(
            cfg, first["tokens"].shape[0] * first["tokens"].shape[1]
            * loader.n_shards)
        for i in range(start_step, steps):
            if on_step_failure is not None:
                try:
                    on_step_failure(i)
                except BaseException as e:
                    e.result = result        # partial losses/step times
                    raise
            batch = jax.device_put(loader.batch_at(i), sh["batch"])
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])         # blocks on completion
            dt = time.perf_counter() - t0
            result.losses.append(loss)
            result.step_times.append(dt)
            if log_every and (i % log_every == 0 or i == steps - 1):
                log_fn(f"step {i:5d} loss {loss:8.4f} "
                       f"ce {float(metrics['ce']):8.4f} "
                       f"gnorm {float(metrics['grad_norm']):7.3f} "
                       f"{dt * 1e3:8.1f} ms "
                       f"{flops / max(dt, 1e-9) / 1e12:6.2f} TFLOP/s")
            if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, i + 1, params, opt_state)
        result.metrics_last = {k: float(v) for k, v in metrics.items()}
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, params, opt_state)
    return result
