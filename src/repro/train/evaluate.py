"""Held-out evaluation: perplexity over a packed dataset.

The paper's end goal is low-perplexity SLMs whose embeddings feed
domain-specific vector databases (§I) — this is the measurement half, plus
the mean-pooled hidden-state embedding extractor those databases would
ingest.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plans import Plan
from repro.models.model import Model


def evaluate_perplexity(model: Model, params, loader, *, max_batches: int = 0,
                        mesh=None) -> Dict[str, float]:
    """Token-level NLL / perplexity over (up to) one epoch."""
    @jax.jit
    def batch_nll(params, batch):
        _, metrics = model.loss(params, batch, remat=False)
        return metrics["ce"], metrics["tokens"]

    total_nll = 0.0
    total_tokens = 0.0
    n = loader.batches_per_epoch if not max_batches \
        else min(max_batches, loader.batches_per_epoch)
    ctx = jax.set_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        for i in range(n):
            batch = jax.tree.map(jnp.asarray, loader.batch_at(i))
            ce, toks = batch_nll(params, batch)
            total_nll += float(ce) * float(toks)
            total_tokens += float(toks)
    nll = total_nll / max(total_tokens, 1.0)
    return {"nll": nll, "perplexity": math.exp(min(nll, 30.0)),
            "tokens": total_tokens}


def embed_texts(model: Model, params, token_batches) -> np.ndarray:
    """Mean-pooled final hidden states — the embeddings the paper's vector
    databases store.  token_batches: iterable of [B, S] int32."""
    cfg = model.cfg

    @jax.jit
    def pool(params, tokens):
        x, positions, _ = model._embed_inputs(params, {"tokens": tokens})
        h, _ = model.run_stack(params["layers"], x, positions,
                               shared=params.get("shared"), remat=False)
        mask = (tokens > 0).astype(jnp.float32)[..., None]
        return jnp.sum(h.astype(jnp.float32) * mask, axis=1) \
            / jnp.maximum(jnp.sum(mask, axis=1), 1.0)

    outs = [np.asarray(pool(params, jnp.asarray(t))) for t in token_batches]
    return np.concatenate(outs, axis=0)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
