"""Elastic re-planning: lose a site mid-run, search the survivors, resume.

The recovery path the chaos benchmark exercises (docs/elasticity.md):

  1. a deterministic fault (``SiteFailure``, injected through
     ``train(on_step_failure=...)`` by ``kill_site_at``) kills the run at
     an exact step;
  2. ``replan`` drops the dead sites from the ``core.topology.Topology``
     (``without_sites``), splits the survivors into connected
     ``components`` (a dead site can sever the only path between the
     rest), runs ``core.search.PlanSearch`` inside each component, and
     keeps the globally best feasible plan — with the index maps back to
     the *original* topology so device blocks can be re-used;
  3. ``reshard_checkpoint`` restores the newest complete checkpoint
     straight onto the new plan's layout (``repro.train.reshard``) —
     params and AdamW moments bit-exact, no recomputation;
  4. ``train(start_step=...)`` resumes against the same deterministic
     batch sequence, so the post-recovery loss sequence matches a run
     that never failed (tests/test_reshard.py pins this).

``train_elastic`` wires all four into one driver and reports the
recovery accounting (search / reshard seconds, steps lost) that
``benchmarks/chaos_bench.py`` gates on a step-time budget.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax

from repro.configs.base import TrainConfig
from repro.core.costmodel import TECHNIQUES, Workload
from repro.core.plans import Placement, get_plan
from repro.core.search import PlanSearch
from repro.core.topology import Topology
from repro.launch.mesh import placement_mesh
from repro.models.model import Model
from repro.optim import init_adamw
from repro.train.checkpoint import latest_checkpoint, save_checkpoint
from repro.train.loop import TrainResult, train
from repro.train.reshard import reshard_checkpoint


class SiteFailure(RuntimeError):
    """A site (or set of sites) dropped out at a training step.

    Raised from a ``train(on_step_failure=...)`` hook; ``train`` attaches
    the partial ``TrainResult`` as the exception's ``result`` attribute
    before re-raising, so the driver can account for pre-failure steps.

    Attributes:
        step: the absolute step index the failure struck at (that step
            and everything after it did not execute).
        dead_sites: original-topology indices of the lost sites.
    """

    def __init__(self, step: int, dead_sites: Sequence[int],
                 reason: str = "site lost"):
        self.step = int(step)
        self.dead_sites = tuple(int(i) for i in dead_sites)
        super().__init__(
            f"step {self.step}: site(s) "
            f"{'+'.join(f'V{i + 1}' for i in self.dead_sites)} "
            f"failed ({reason})")


def kill_site_at(step: int, dead_sites: Sequence[int]
                 ) -> Callable[[int], None]:
    """Deterministic fault injector for ``train(on_step_failure=...)``:
    raises ``SiteFailure(step, dead_sites)`` the moment the run reaches
    ``step`` — the chaos benchmark's kill-site-at-step-k scenario."""
    dead = tuple(dead_sites)

    def hook(i: int) -> None:
        if i == step:
            raise SiteFailure(i, dead)

    return hook


@dataclass(frozen=True)
class ReplanResult:
    """What the survivor search decided.

    Attributes:
        topology: the component sub-topology the winning plan was
            searched on (site indices are *local* to it).
        technique: winning technique (a ``core.plans.PLANS`` key).
        placement: winning ``core.plans.Placement`` — sites index into
            ``topology``.
        sites_old: per placed site, its index in the ORIGINAL topology
            (so the original per-site device blocks can be re-used:
            ``placement_devices``).
        tflops: the cost model's score for the winner.
        search_s: wall-clock seconds the survivor search took.
        dead_sites: original indices of the sites that were removed.
    """
    topology: Topology
    technique: str
    placement: Placement
    sites_old: Tuple[int, ...]
    tflops: float
    search_s: float
    dead_sites: Tuple[int, ...]


def replan(topo: Topology, dead_sites: Sequence[int], wl: Workload, *,
           techniques: Tuple[str, ...] = TECHNIQUES,
           stage_balance: str = "tflops",
           schedules: Optional[Tuple[str, ...]] = None,
           **search_kw) -> ReplanResult:
    """Search the surviving topology for the best feasible plan.

    Drops ``dead_sites``, splits the survivors into connected components
    (``Topology.components`` — losing a middle site can disconnect the
    rest, and a plan cannot span sites with no path between them), runs
    a ``core.search.PlanSearch`` inside each component, and returns the
    globally best feasible candidate with its index maps composed back
    to the original topology.

    Args:
        topo: the original topology the failed run was planned on.
        dead_sites: original site indices that died.
        wl: the workload being re-placed (same model/batch as the run).
        techniques: technique pool (default: the paper's four).
        stage_balance: stage balancing for pipeline candidates; defaults
            to ``"tflops"`` — degraded survivor sets are exactly where
            uneven splits pay (the searched ``stage_layers`` then ride
            into ``reshard_checkpoint``'s validation).
        schedules: pipeline schedule pool (default: the search's).
        **search_kw: forwarded to ``PlanSearch``.

    Raises:
        ValueError: ``dead_sites`` is empty/invalid or kills every site.
        RuntimeError: no surviving component has a feasible plan (every
            candidate OOMs) — need more GPU memory.
    """
    if not dead_sites:
        raise ValueError("replan without dead sites — nothing to do")
    t0 = time.perf_counter()
    survivor, kept = topo.without_sites(dead_sites)
    if schedules is not None:
        search_kw["schedules"] = tuple(schedules)
    best: Optional[Tuple[float, PlanSearch, "object", Topology,
                         Tuple[int, ...]]] = None
    for comp in survivor.components():
        drop = [i for i in range(survivor.n_sites) if i not in comp]
        sub, sub_kept = survivor.without_sites(drop) if drop \
            else (survivor, tuple(range(survivor.n_sites)))
        search = PlanSearch(wl, sub, techniques=tuple(techniques),
                            stage_balance=stage_balance, **search_kw)
        top = search.best()
        if top is not None and (best is None or top.tflops > best[0]):
            best = (top.tflops, search, top, sub, sub_kept)
    if best is None:
        raise RuntimeError(
            f"no feasible plan on the survivors of {topo.name} minus "
            f"{tuple(dead_sites)} — every candidate exceeds memory")
    tflops, search, top, sub, sub_kept = best
    placement = search.placement(top.candidate)
    sites_old = tuple(kept[sub_kept[s]] for s in placement.sites)
    return ReplanResult(
        topology=sub, technique=top.candidate.technique,
        placement=placement, sites_old=sites_old, tflops=float(tflops),
        search_s=time.perf_counter() - t0,
        dead_sites=tuple(int(i) for i in dead_sites))


# --------------------------------------------------------------------- #
# site -> device blocks (one device per GPU, in site order)
# --------------------------------------------------------------------- #

def site_device_blocks(topo: Topology, devices=None) -> List[Tuple]:
    """Per-site device blocks under the one-device-per-GPU convention
    ``launch.mesh.make_topology_mesh`` consumes: site i owns the next
    ``len(topo.sites[i].gpus)`` devices.  Fixing the blocks up front
    means a replanned run re-uses exactly the surviving sites' devices.
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    blocks, off = [], 0
    for s in topo.sites:
        n = len(s.gpus)
        if off + n > len(devs):
            raise ValueError(f"topology {topo.name} needs "
                             f"{sum(len(t.gpus) for t in topo.sites)} "
                             f"devices, have {len(devs)}")
        blocks.append(tuple(devs[off:off + n]))
        off += n
    return blocks


def placement_devices(blocks: Sequence[Tuple],
                      sites_old: Sequence[int]) -> List:
    """Flatten the original-topology device blocks of a placement's
    sites (``ReplanResult.sites_old`` order) into the device list
    ``launch.mesh.placement_mesh`` consumes."""
    return [d for i in sites_old for d in blocks[i]]


# --------------------------------------------------------------------- #
# the elastic driver
# --------------------------------------------------------------------- #

@dataclass
class ElasticRun:
    """One elastic training run's outcome + recovery accounting.

    Attributes:
        result: the final ``TrainResult`` (the post-recovery segment
            when a failure struck, else the whole run).
        pre: the pre-failure partial ``TrainResult`` (None: no failure).
        failure: the ``SiteFailure`` that struck (None: clean run).
        replan: the survivor search's ``ReplanResult`` (None: clean run).
        resumed_from: checkpoint step the recovery restarted at.
        steps_lost: steps re-executed = failure step - checkpoint step.
        search_s / reshard_s / recovery_s: recovery phase wall-clocks
            (recovery covers search + restore + reshard, NOT the resumed
            training itself).
    """
    result: TrainResult
    pre: Optional[TrainResult] = None
    failure: Optional[SiteFailure] = None
    replan: Optional[ReplanResult] = None
    resumed_from: Optional[int] = None
    steps_lost: int = 0
    search_s: float = 0.0
    reshard_s: float = 0.0
    recovery_s: float = 0.0

    @property
    def failed(self) -> bool:
        return self.failure is not None

    @property
    def losses(self) -> List[float]:
        """Pre-failure + post-recovery losses, concatenated in executed
        order (re-executed steps appear twice, as they ran twice)."""
        pre = self.pre.losses if self.pre else []
        return list(pre) + list(self.result.losses)


def train_elastic(model: Model, topo: Topology, technique: str,
                  placement: Placement, tcfg: TrainConfig, loader, *,
                  steps: int, ckpt_dir: str, ckpt_every: int = 1,
                  on_step_failure: Optional[Callable[[int], None]] = None,
                  devices=None, model_axis: int = 1,
                  techniques: Tuple[str, ...] = TECHNIQUES,
                  log_every: int = 0,
                  log_fn: Callable[[str], None] = print,
                  **search_kw) -> ElasticRun:
    """Run a plan with fault tolerance: on ``SiteFailure``, replan over
    the survivors, reshard the newest checkpoint onto the winner, and
    resume — the whole elastic path of docs/elasticity.md in one call.

    A step-0 checkpoint is saved before training starts (params/opt
    initialized here, deterministically from ``tcfg.seed``), so recovery
    is possible even when the failure strikes before the first periodic
    checkpoint lands.

    Args:
        model: the model to train.
        topo: the full (pre-failure) topology.
        technique: initial plan name (``core.plans.PLANS`` key).
        placement: initial ``core.plans.Placement`` on ``topo``.
        tcfg: training config.
        loader: deterministic ``data.pipeline.Loader``.
        steps: total steps to reach (absolute).
        ckpt_dir: checkpoint directory (required — it IS the recovery
            mechanism).
        ckpt_every: periodic checkpoint interval in steps.
        on_step_failure: fault hook forwarded to ``train`` (e.g.
            ``kill_site_at``); only fires on the first segment.
        devices: explicit devices (default all local); carved into
            per-site blocks (``site_device_blocks``).
        model_axis: tensor-parallel degree inside each site.
        techniques: survivor-search technique pool.
        log_every / log_fn: forwarded to ``train``.
        **search_kw: forwarded to ``replan`` / ``PlanSearch``.

    Returns:
        An ``ElasticRun`` — clean or recovered.

    Raises:
        RuntimeError: no feasible plan on the survivors, or no complete
            checkpoint to recover from.
    """
    if not ckpt_dir:
        raise ValueError("train_elastic needs ckpt_dir — checkpoints are "
                         "the recovery mechanism")
    plan = get_plan(technique)
    blocks = site_device_blocks(topo, devices)
    mesh = placement_mesh(topo, plan, placement, model=model_axis,
                          devices=placement_devices(
                              blocks, placement.sites))
    params = model.init(jax.random.key(tcfg.seed))
    opt_state = init_adamw(params)
    save_checkpoint(ckpt_dir, 0, params, opt_state)
    try:
        res = train(model, plan, mesh, tcfg, loader, steps=steps,
                    params=params, opt_state=opt_state,
                    ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                    stage_layers=placement.stage_layers,
                    schedule=placement.schedule,
                    on_step_failure=on_step_failure,
                    log_every=log_every, log_fn=log_fn)
        return ElasticRun(result=res)
    except SiteFailure as fail:
        pre = getattr(fail, "result", TrainResult())
        first = loader.batch_at(0)
        wl = Workload(model.cfg, int(first["tokens"].shape[1]),
                      loader.global_batch, steps_per_epoch=steps,
                      microbatches=tcfg.microbatches)
        t0 = time.perf_counter()
        rp = replan(topo, fail.dead_sites, wl, techniques=techniques,
                    **search_kw)
        ckpt = latest_checkpoint(ckpt_dir)
        if ckpt is None:
            raise RuntimeError(
                f"no complete checkpoint in {ckpt_dir} to recover "
                f"from") from fail
        plan2 = get_plan(rp.technique)
        mesh2 = placement_mesh(rp.topology, plan2, rp.placement,
                               model=model_axis,
                               devices=placement_devices(
                                   blocks, rp.sites_old))
        t1 = time.perf_counter()
        params2, opt2, step0 = reshard_checkpoint(
            ckpt, model, plan2, mesh2, placement=rp.placement)
        t2 = time.perf_counter()
        log_fn(f"recovered at step {step0}: {rp.technique}@"
               f"{'+'.join(f'V{i + 1}' for i in rp.sites_old)} "
               f"(search {rp.search_s:.2f}s, reshard {t2 - t1:.2f}s, "
               f"{fail.step - step0} step(s) lost)")
        post = train(model, plan2, mesh2, tcfg, loader, steps=steps,
                     start_step=step0, params=params2, opt_state=opt2,
                     ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                     stage_layers=rp.placement.stage_layers,
                     schedule=rp.placement.schedule,
                     log_every=log_every, log_fn=log_fn)
        return ElasticRun(result=post, pre=pre, failure=fail, replan=rp,
                          resumed_from=step0,
                          steps_lost=fail.step - step0,
                          search_s=rp.search_s, reshard_s=t2 - t1,
                          recovery_s=t2 - t0)
