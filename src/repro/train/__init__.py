from repro.train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                    save_checkpoint, verify_checkpoint)
from repro.train.loop import TrainResult, model_flops_per_step, train
from repro.train.replan import (ElasticRun, ReplanResult, SiteFailure,
                                kill_site_at, replan, train_elastic)
from repro.train.reshard import (reshard_checkpoint, reshard_state, restage,
                                 stage_view, unstage_view)

__all__ = ["ElasticRun", "ReplanResult", "SiteFailure", "TrainResult",
           "kill_site_at", "latest_checkpoint", "model_flops_per_step",
           "replan", "reshard_checkpoint", "reshard_state",
           "restore_checkpoint", "restage", "save_checkpoint",
           "stage_view", "train", "train_elastic", "unstage_view",
           "verify_checkpoint"]
