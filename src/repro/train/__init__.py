from repro.train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import TrainResult, model_flops_per_step, train

__all__ = ["TrainResult", "latest_checkpoint", "model_flops_per_step",
           "restore_checkpoint", "save_checkpoint", "train"]
