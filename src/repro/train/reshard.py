"""Cross-plan checkpoint resharding: restore any saved run onto any plan.

``train/checkpoint.py`` saves gathered-to-host canonical pytrees — every
leaf is the full array, the layer stack is in logical layer order — so a
checkpoint is already layout-independent.  Resharding between two
(technique x placement x stage_layers) layouts therefore decomposes:

  * **re-placement**: compute the destination plan's param/optimizer
    shardings on the destination mesh (``core.plans.Plan
    .param_shardings`` / ``opt_specs``) and ``device_put`` every leaf
    onto them — ``reshard_checkpoint`` — with AdamW moments carried
    leaf-for-leaf (m/v live on exactly the param sharding under fsdp,
    and on the ZeRO largest-dim spec under zero2/shard_zero);
  * **re-staging**: when the destination is a pipeline with a different
    stage count or ``stage_layers`` split, the per-stage layer
    assignment changes.  The runtime gathers stages from the canonical
    stack at trace time via the pad-and-mask convention
    (``core.pipeline.stage_gather_index``), so ``stage_view`` /
    ``unstage_view`` / ``restage`` here apply the *same* index outside
    the runtime: they materialize a layout's padded stage-major view,
    invert it back to canonical, and map one pipeline layout straight
    into another — the host-side reference re-placement the chaos gate
    checks bit-exactness against (docs/elasticity.md).

Everything is bit-exact: no leaf is recomputed, cast (unless
``allow_cast``), or renormalized — ``tests/test_reshard.py`` pins parity
across zero2→fsdp, data→pipeshard, stage-count and stage-order changes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.costmodel import parse_schedule
from repro.core.pipeline import stage_gather_index
from repro.core.plans import Placement, Plan
from repro.core.sharding import named_shardings
from repro.optim import AdamWState, init_adamw
from repro.train.checkpoint import restore_checkpoint


# --------------------------------------------------------------------- #
# stage re-slicing: canonical stack <-> padded stage-major views
# --------------------------------------------------------------------- #

def normalized_stage_layers(n_layers: int,
                            placement: Placement) -> Tuple[int, ...]:
    """The per-chunk layer split a pipeline placement runs: its explicit
    ``stage_layers`` when present, else the even split — which must
    divide (``core.pipeline.validate_stages`` enforces the same rule at
    trace time).

    Raises:
        ValueError: no explicit split and ``n_layers`` does not divide
            into the placement's chunk count.
    """
    _, virt = parse_schedule(placement.schedule)
    n_chunks = placement.n_stages * virt
    if placement.stage_layers is not None:
        return tuple(int(l) for l in placement.stage_layers)
    if n_layers % n_chunks != 0:
        raise ValueError(
            f"{n_layers} layers do not divide into {n_chunks} chunks "
            f"({placement.n_stages} stages, {placement.schedule}) and the "
            f"placement carries no explicit stage_layers")
    return (n_layers // n_chunks,) * n_chunks


def stage_view(stack, stage_layers, n_stages: int,
               schedule: str = "gpipe") -> Tuple[Any, np.ndarray]:
    """A layout's padded stage-major view of a canonical layer stack.

    Applies ``core.pipeline.stage_gather_index`` — bit-for-bit the
    gather ``make_pipeline_loss`` performs at trace time — on host
    arrays: chunk ``c = k * n_stages + s`` of stage s lands back to
    back, padded to the longest chunk by repeating its last layer.

    Args:
        stack: canonical ``[L, ...]`` stacked layer pytree (host or
            device arrays).
        stage_layers: per-chunk layer counts (see
            ``normalized_stage_layers``).
        n_stages: pipeline stages.
        schedule: tick-order schedule (fixes the virtual-stage factor).

    Returns:
        ``(staged, layer_valid)``: the gathered pytree with leading axis
        ``n_stages * virt * max(stage_layers)`` and the boolean validity
        mask over that axis (False = padding slot).
    """
    _, virt = parse_schedule(schedule)
    idx, valid = stage_gather_index(stage_layers, n_stages, virt)
    staged = jax.tree.map(
        lambda leaf: np.take(np.asarray(jax.device_get(leaf)), idx, axis=0),
        stack)
    return staged, valid


def unstage_view(staged, stage_layers, n_stages: int,
                 schedule: str = "gpipe"):
    """Invert ``stage_view``: drop padding slots and reorder the chunks
    back into logical layer order, recovering the canonical stack
    bit-exactly (property-tested round trip, tests/test_reshard.py).
    """
    _, virt = parse_schedule(schedule)
    split = tuple(int(l) for l in stage_layers)
    if len(split) != n_stages * virt:
        raise ValueError(f"split {split} has {len(split)} entries for "
                         f"{n_stages} stages x {virt} virtual")
    max_l = max(split)
    # position of chunk c inside the stage-major view
    chunk_of = [k * n_stages + s
                for s in range(n_stages) for k in range(virt)]
    pos = {c: p for p, c in enumerate(chunk_of)}
    rows = np.concatenate([
        pos[c] * max_l + np.arange(split[c])
        for c in range(len(split))]).astype(np.int32)

    def un(leaf):
        arr = np.asarray(jax.device_get(leaf))
        if arr.shape[0] != n_stages * virt * max_l:
            raise ValueError(
                f"staged leaf has leading axis {arr.shape[0]}, expected "
                f"{n_stages * virt * max_l} for split {split}")
        return np.take(arr, rows, axis=0)

    return jax.tree.map(un, staged)


def restage(staged, src_layers, src_stages: int, dst_layers,
            dst_stages: int, *, src_schedule: str = "gpipe",
            dst_schedule: str = "gpipe"):
    """Map one pipeline layout's staged view directly into another's —
    the per-stage layer re-slice of a stage-count / split / schedule
    change, e.g. a 2-stage even view into a 3-stage uneven one after a
    site joins (or the reverse after one dies).

    Returns:
        ``(staged_dst, layer_valid_dst)`` as from ``stage_view``.
    """
    canon = unstage_view(staged, src_layers, src_stages,
                         schedule=src_schedule)
    return stage_view(canon, dst_layers, dst_stages, schedule=dst_schedule)


# --------------------------------------------------------------------- #
# re-placement: host checkpoint -> any plan's device layout
# --------------------------------------------------------------------- #

def state_templates(model, *, seed: int = 0) -> Tuple[Any, AdamWState]:
    """Abstract (shape/dtype) templates for a model's params + AdamW
    state, without allocating either — what ``restore_checkpoint``
    validates a checkpoint against."""
    p_like = jax.eval_shape(lambda: model.init(jax.random.key(seed)))
    o_like = jax.eval_shape(init_adamw, p_like)
    return p_like, o_like


def plan_state_shardings(plan: Plan, params_like, cfg: ModelConfig,
                         mesh) -> Dict[str, Any]:
    """The (params, opt) NamedSharding trees a plan trains under on a
    mesh — the same shardings ``core.steps.build_train_step`` jits with,
    so a checkpoint restored onto them needs no further movement."""
    p_specs = plan.param_specs(params_like, cfg, mesh)
    o_specs = plan.opt_specs(params_like, cfg, mesh)
    opt_specs = AdamWState(step=P(), m=o_specs, v=o_specs)
    return {"params": named_shardings(p_specs, mesh),
            "opt": named_shardings(opt_specs, mesh)}


def reshard_state(params_host, opt_host, plan: Plan, cfg: ModelConfig,
                  mesh) -> Tuple[Any, Optional[AdamWState]]:
    """Place host-canonical (params, opt) pytrees onto a plan's layout.

    The host-side reference re-placement: pure ``device_put`` onto
    ``plan_state_shardings`` — no values change, AdamW moments map
    leaf-for-leaf.  ``reshard_checkpoint`` is this plus the restore.
    """
    sh = plan_state_shardings(plan, params_host, cfg, mesh)
    params = jax.device_put(params_host, sh["params"])
    opt = None if opt_host is None else jax.device_put(opt_host, sh["opt"])
    return params, opt


def reshard_checkpoint(path: str, model, plan: Plan, mesh, *,
                       placement: Optional[Placement] = None,
                       allow_cast: bool = False,
                       verify: bool = True) -> Tuple[Any, Any, int]:
    """Restore a checkpoint onto a (possibly different) plan's layout.

    The full cross-plan map: integrity-verified restore of the canonical
    host pytrees, templates from the model, destination shardings from
    ``(plan, mesh)``, every leaf — params and AdamW moments alike —
    placed onto them.  For a pipeline destination, ``placement`` is
    validated up front: its ``stage_layers`` (or the even split) must
    partition the model's stack, so an impossible re-stage fails here
    rather than steps later at trace time.

    Args:
        path: checkpoint directory.
        model: the ``repro.models.Model`` being restored (shapes,
            dtypes, and the config the plan's sharding rules read).
        plan: destination execution plan (``core.plans.PLANS``).
        mesh: destination mesh (from ``launch.mesh.placement_mesh`` for
            a searched placement).
        placement: the destination ``core.plans.Placement``; required
            checks apply only to pipeline plans.
        allow_cast: forwarded to ``restore_checkpoint`` (dtype-changing
            restores are refused by default).
        verify: forwarded to ``restore_checkpoint`` (sha256 shard
            verification).

    Returns:
        ``(params, opt_state, step)`` on the destination layout;
        ``opt_state`` is None when the checkpoint carries none.
    """
    cfg = model.cfg
    if plan.pipeline:
        if placement is None:
            raise ValueError("pipeline destination needs the Placement "
                             "(stage count + stage_layers)")
        normalized_stage_layers(cfg.n_layers, placement)  # raises if bad
    p_like, o_like = state_templates(model)
    shardings = plan_state_shardings(plan, p_like, cfg, mesh)
    return restore_checkpoint(path, p_like, o_like, shardings,
                              allow_cast=allow_cast, verify=verify)
