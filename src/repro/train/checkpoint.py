"""Checkpointing: params + optimizer state + step to sharded .npz files.

Leaves are flattened with tree paths as keys; arrays are gathered to host
(fine at SLM scale, the paper's regime) and split across ``n_files`` npz
shards to bound file sizes.  Restore reproduces the exact pytree and can
re-place leaves onto any sharding (plan changes between runs are allowed —
the technique-selection algorithm may switch plans mid-project, and
elastic re-planning reshards checkpoints across plans wholesale:
``repro.train.reshard``, docs/elasticity.md).

Durability contract (what the chaos path leans on):

  * saves are *atomic*: shards and manifest are written to a
    ``step_XXXXXXXX.tmp`` staging directory, the manifest is fsynced,
    and the directory is renamed into place last — a crash mid-save can
    never leave a directory ``latest_checkpoint`` would return;
  * every shard's sha256 is recorded in ``manifest.json`` and verified
    on restore, so a truncated or bit-rotted shard fails loudly instead
    of silently resuming from garbage;
  * restore refuses dtype mismatches (a saved fp32 master leaf restored
    onto a bf16 template used to downcast silently) unless the caller
    passes ``allow_cast=True``.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None, *,
                    n_files: int = 4, extra: Optional[Dict] = None) -> str:
    """Atomically write one checkpoint directory; returns its path.

    All shards land in ``step_XXXXXXXX.tmp`` first; the manifest (with
    per-shard sha256 checksums) is written and fsynced, then the staging
    directory is renamed to its final name.  ``latest_checkpoint``
    ignores ``.tmp`` and manifest-less directories, so a save that dies
    at any point is invisible to resume.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.isdir(tmp):                   # stale staging from a crash
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    manifest: Dict[str, Any] = {"step": step, "files": {},
                                "checksums": {}, "extra": extra or {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        keys = sorted(flat)
        shards = [keys[i::n_files] for i in range(n_files)]
        for i, ks in enumerate(shards):
            if not ks:
                continue
            fname = f"{name}_{i:02d}.npz"
            np.savez(os.path.join(tmp, fname), **{k: flat[k] for k in ks})
            manifest["files"].setdefault(name, []).append(fname)
            manifest["checksums"][fname] = _sha256(os.path.join(tmp, fname))
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.isdir(path):                  # re-saving the same step
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def _complete(ckpt_dir: str, d: str) -> bool:
    return not d.endswith(".tmp") and \
        os.path.isfile(os.path.join(ckpt_dir, d, "manifest.json"))


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    """Newest *complete* checkpoint: ``.tmp`` staging directories and
    directories without a manifest (a pre-atomic partial save) are
    skipped — they can never be resumed from."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and _complete(ckpt_dir, d))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def verify_checkpoint(path: str) -> Dict[str, Any]:
    """Integrity-check a checkpoint directory and return its manifest.

    Raises:
        ValueError: manifest missing (partial save), a listed shard file
            is missing, or a shard's sha256 does not match the manifest
            (truncation / corruption).  Legacy manifests without
            checksums verify shard *existence* only.
    """
    mpath = os.path.join(path, "manifest.json")
    if not os.path.isfile(mpath):
        raise ValueError(f"{path}: no manifest.json — incomplete "
                         f"checkpoint (crashed mid-save?)")
    with open(mpath) as f:
        manifest = json.load(f)
    sums = manifest.get("checksums", {})
    for name, fnames in manifest.get("files", {}).items():
        for fname in fnames:
            fpath = os.path.join(path, fname)
            if not os.path.isfile(fpath):
                raise ValueError(f"{path}: shard {fname} listed in the "
                                 f"manifest is missing")
            want = sums.get(fname)
            if want is not None and _sha256(fpath) != want:
                raise ValueError(f"{path}: shard {fname} fails its "
                                 f"sha256 check — truncated or corrupt")
    return manifest


def restore_checkpoint(path: str, params_like, opt_like=None,
                       shardings: Optional[Dict] = None, *,
                       allow_cast: bool = False,
                       verify: bool = True) -> Tuple[Any, Any, int]:
    """Restore onto templates; optional shardings re-place the leaves.

    Args:
        path: checkpoint directory (from ``save_checkpoint`` /
            ``latest_checkpoint``).
        params_like: params template (arrays or ShapeDtypeStructs) fixing
            tree structure, shapes, and dtypes.
        opt_like: optional optimizer-state template.
        shardings: optional ``{"params": ..., "opt": ...}`` sharding
            pytrees the restored leaves are placed onto.
        allow_cast: permit dtype-changing restores (saved fp32 onto a
            bf16 template, or vice versa).  Off by default — a silent
            downcast destroys master-weight precision, so mismatches
            raise ``ValueError``.
        verify: check per-shard sha256 checksums before loading
            (``verify_checkpoint``).

    Raises:
        ValueError: integrity failure, shape mismatch, or (without
            ``allow_cast``) dtype mismatch.
    """
    manifest = verify_checkpoint(path) if verify else \
        json.load(open(os.path.join(path, "manifest.json")))

    def load(name, like, shard_tree):
        flat: Dict[str, np.ndarray] = {}
        for fname in manifest["files"].get(name, []):
            with np.load(os.path.join(path, fname)) as z:
                flat.update({k: z[k] for k in z.files})
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        out = []
        shard_leaves = (jax.tree.leaves(shard_tree)
                        if shard_tree is not None else None)
        for i, (p, leaf) in enumerate(leaves_paths[0]):
            key = "/".join(
                str(getattr(q, "key", getattr(q, "name", getattr(q, "idx", q))))
                for q in p)
            if key not in flat:
                raise ValueError(f"{name}/{key}: not in checkpoint {path}")
            arr = flat[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(f"{key}: ckpt {arr.shape} != {leaf.shape}")
            if arr.dtype != np.dtype(leaf.dtype) and not allow_cast:
                raise ValueError(
                    f"{key}: checkpoint dtype {arr.dtype} != template "
                    f"{np.dtype(leaf.dtype)}; a silent cast would lose "
                    f"master-weight precision — pass allow_cast=True to "
                    f"convert deliberately")
            a = jnp.asarray(arr, dtype=leaf.dtype)
            if shard_leaves is not None:
                a = jax.device_put(a, shard_leaves[i])
            out.append(a)
        return jax.tree_util.tree_unflatten(leaves_paths[1], out)

    params = load("params", params_like,
                  shardings.get("params") if shardings else None)
    opt = None
    if opt_like is not None and "opt" in manifest["files"]:
        opt = load("opt", opt_like, shardings.get("opt") if shardings else None)
    return params, opt, manifest["step"]
