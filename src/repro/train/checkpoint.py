"""Checkpointing: params + optimizer state + step to sharded .npz files.

Leaves are flattened with tree paths as keys; arrays are gathered to host
(fine at SLM scale, the paper's regime) and split across ``n_files`` npz
shards to bound file sizes.  Restore reproduces the exact pytree and can
re-place leaves onto any sharding (plan changes between runs are allowed —
the technique-selection algorithm may switch plans mid-project).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None, *,
                    n_files: int = 4, extra: Optional[Dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    manifest: Dict[str, Any] = {"step": step, "files": {},
                                "extra": extra or {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        keys = sorted(flat)
        shards = [keys[i::n_files] for i in range(n_files)]
        for i, ks in enumerate(shards):
            if not ks:
                continue
            fname = f"{name}_{i:02d}.npz"
            np.savez(os.path.join(path, fname), **{k: flat[k] for k in ks})
            manifest["files"].setdefault(name, []).append(fname)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def restore_checkpoint(path: str, params_like, opt_like=None,
                       shardings: Optional[Dict] = None
                       ) -> Tuple[Any, Any, int]:
    """Restore onto templates; optional shardings re-place the leaves."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def load(name, like, shard_tree):
        flat: Dict[str, np.ndarray] = {}
        for fname in manifest["files"].get(name, []):
            with np.load(os.path.join(path, fname)) as z:
                flat.update({k: z[k] for k in z.files})
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        out = []
        shard_leaves = (jax.tree.leaves(shard_tree)
                        if shard_tree is not None else None)
        for i, (p, leaf) in enumerate(leaves_paths[0]):
            key = "/".join(
                str(getattr(q, "key", getattr(q, "name", getattr(q, "idx", q))))
                for q in p)
            arr = flat[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(f"{key}: ckpt {arr.shape} != {leaf.shape}")
            a = jnp.asarray(arr, dtype=leaf.dtype)
            if shard_leaves is not None:
                a = jax.device_put(a, shard_leaves[i])
            out.append(a)
        return jax.tree_util.tree_unflatten(leaves_paths[1], out)

    params = load("params", params_like,
                  shardings.get("params") if shardings else None)
    opt = None
    if opt_like is not None and "opt" in manifest["files"]:
        opt = load("opt", opt_like, shardings.get("opt") if shardings else None)
    return params, opt, manifest["step"]
