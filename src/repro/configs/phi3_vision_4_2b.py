"""Phi-3-Vision-4.2B — phi3-mini LM backbone + CLIP vision frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct] 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064.  Per the assignment, the ViT/CLIP image encoder is a
STUB: ``input_specs()`` supplies precomputed patch embeddings (CLIP ViT-L/14
gives 1024-dim patch features); we implement the projector + LM decoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    sliding_window=8192,
    vision_dim=1024,       # CLIP ViT-L/14 patch feature dim
    n_patches=576,         # 24x24 patches per image tile
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
