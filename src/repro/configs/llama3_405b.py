"""Llama-3 405B — the memory giant of the assignment.

[arXiv:2407.21783] 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256, rope theta 500000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500000.0,
    sliding_window=8192,   # long_500k decode variant only
    source="arXiv:2407.21783",
)
