"""Whisper-small — encoder-decoder with conv/mel frontend (stub).

[arXiv:2212.04356] 12L (both encoder and decoder) d_model=768 12H (kv=12)
d_ff=3072 vocab=51865.  Per the assignment the mel-spectrogram + conv
feature extractor is a STUB: ``input_specs()`` provides precomputed frame
embeddings (1500 frames = 30 s of audio after the conv stride-2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    enc_seq_len=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm="layernorm",
    activation="gelu",
    rope_theta=0.0,         # whisper uses learned/sinusoidal positions
    max_seq_len=448 * 128,  # structurally allow long decode shapes
    source="arXiv:2212.04356",
)
