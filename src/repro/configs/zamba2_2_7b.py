"""Zamba2-2.7B — Mamba2 backbone + shared attention block (hybrid).

[arXiv:2411.15242] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 (Mamba2/SSD).  Zamba2 interleaves a *shared* full-attention
block (one set of weights, re-applied) every 6 Mamba2 layers; we model that
with ``hybrid_attn_every=6`` and a single shared attention+MLP param group.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    rope_theta=10000.0,
    hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2, head_dim=64, chunk=64),
    source="arXiv:2411.15242",
)
