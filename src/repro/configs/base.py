"""Configuration dataclasses for the repro framework.

A single ``ModelConfig`` covers every assigned architecture family
(dense / moe / ssm / hybrid / encdec / vlm).  Architecture files under
``repro/configs/`` instantiate it with the exact published hyperparameters
(source cited in each file) and a ``reduced()`` helper returns the smoke-test
variant (2 layers, d_model<=512, <=4 experts) mandated by the spec.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

Family = str  # "dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style).

    K/V are compressed into a ``kv_lora_rank``-dim latent that is what gets
    cached at decode time; a decoupled RoPE key of ``rope_head_dim`` is
    cached alongside.  Queries may also be low-rank (``q_lora_rank``).
    """
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 => full-rank queries
    rope_head_dim: int = 64         # decoupled rope key dim (shared across heads)
    nope_head_dim: int = 128        # per-head non-rope dim
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0       # always-on experts (DeepSeek style)
    expert_d_ff: int = 0            # 0 => use model d_ff
    router_aux_coef: float = 0.01   # load-balance loss coefficient
    router_jitter: float = 0.0
    capacity_factor: float = 1.25   # >= n_experts/top_k => never drops


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    version: int = 1                # 1 => Mamba1 selective scan, 2 => Mamba2/SSD
    n_heads: int = 0                # Mamba2 heads (0 => d_inner//head_dim)
    head_dim: int = 64              # Mamba2 head dim
    chunk: int = 64                 # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // n_heads
    max_seq_len: int = 131072
    rope_theta: float = 500000.0
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    activation: str = "silu"        # "silu" (SwiGLU) | "gelu" (plain MLP)
    tie_embeddings: bool = False
    sliding_window: int = 0         # 0 => full causal attention
    # --- family-specific sub-configs -------------------------------------
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): apply the shared attention block every k-th layer
    hybrid_attn_every: int = 0      # 0 => no interleaved attention
    # enc-dec (whisper): encoder depth + frontend stub shape
    n_enc_layers: int = 0
    enc_seq_len: int = 0            # precomputed frame embeddings length
    # vlm (phi-3-vision): stub vision frontend shape
    vision_dim: int = 0             # patch embedding dim fed to the projector
    n_patches: int = 0
    # numerics
    dtype: str = "bfloat16"
    source: str = ""                # citation (hf:/arXiv: per assignment)
    # runtime (set by the step builders, not by configs): mesh axes for
    # per-shard local MoE routing — see models/moe.py::moe_forward
    moe_dispatch_axes: Tuple[str, ...] = ()
    # mesh axis the expert buffer is pinned to ("" => unpinned)
    moe_expert_axis: str = ""

    # ----------------------------------------------------------------- #
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # convenience ------------------------------------------------------ #
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode available (native state or sliding window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (used for rooflines and 6ND FLOPs)."""
        c = self
        d, v = c.d_model, c.vocab_size
        emb = v * d * (1 if c.tie_embeddings else 2)
        per_layer = 0
        # attention params
        if c.family != "ssm":
            if c.mla is not None:
                m = c.mla
                qdim = m.nope_head_dim + m.rope_head_dim
                q_in = m.q_lora_rank or d
                per_attn = (
                    (d * m.q_lora_rank if m.q_lora_rank else 0)
                    + q_in * c.n_heads * qdim
                    + d * (m.kv_lora_rank + m.rope_head_dim)
                    + m.kv_lora_rank * c.n_heads * (m.nope_head_dim + m.v_head_dim)
                    + c.n_heads * m.v_head_dim * d
                )
            else:
                hd = c.head_dim
                per_attn = d * (c.n_heads * hd) + 2 * d * (c.n_kv_heads * hd) \
                    + (c.n_heads * hd) * d
        else:
            per_attn = 0
        # mlp params
        if c.family == "moe":
            assert c.moe is not None
            eff = c.moe.expert_d_ff or c.d_ff
            n_e = c.moe.n_experts + c.moe.n_shared_experts
            per_mlp = n_e * 3 * d * eff + d * c.moe.n_experts  # + router
        elif c.family == "ssm":
            per_mlp = 0
        else:
            mult = 3 if c.activation == "silu" else 2
            per_mlp = mult * d * c.d_ff
        # ssm params
        per_ssm = 0
        if c.family in ("ssm", "hybrid"):
            assert c.ssm is not None
            di, ds = c.ssm.expand * d, c.ssm.d_state
            per_ssm = 2 * d * di + c.ssm.d_conv * di + di * ds * 2 + di * 2 + di * d
            if c.ssm.version == 2:
                nh = c.ssm.n_heads or di // c.ssm.head_dim
                per_ssm = 2 * d * di + c.ssm.d_conv * di + di * 2 * ds + nh * 2 + di * d
        if c.family == "ssm":
            layer_total = c.n_layers * (per_ssm + 2 * d)
        elif c.family == "hybrid":
            n_attn = c.n_layers // max(c.hybrid_attn_every, 1) if c.hybrid_attn_every else 0
            shared_attn = per_attn + 3 * d * c.d_ff  # one shared attn+mlp block
            layer_total = c.n_layers * (per_ssm + 2 * d) + shared_attn + n_attn * d
        else:
            layer_total = c.n_layers * (per_attn + per_mlp + 2 * d)
        enc = 0
        if c.family == "encdec":
            # encoder layers + decoder cross-attention
            enc_layer = 4 * d * d + (3 if c.activation == "silu" else 2) * d * c.d_ff + 2 * d
            enc = c.n_enc_layers * enc_layer + c.n_layers * 4 * d * d
        vlm = 0
        if c.family == "vlm":
            vlm = c.vision_dim * d + d * d  # 2-layer projector
        return emb + layer_total + enc + vlm + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        c, m = self, self.moe
        eff = m.expert_d_ff or c.d_ff
        total = self.param_count()
        inactive = (m.n_experts - m.top_k) * 3 * c.d_model * eff * c.n_layers
        return total - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) or 4
        kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else n_heads
        kw = dict(
            n_layers=2, d_model=d, n_heads=n_heads,
            n_kv_heads=max(1, kv if kv <= n_heads else n_heads),
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=d // n_heads if self.family != "ssm" else 0,
            max_seq_len=1024,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                  rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
        if self.moe is not None:
            # no-drop capacity so forward/prefill/decode agree exactly
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2,
                                n_shared_experts=min(self.moe.n_shared_experts, 1),
                                expert_d_ff=min(self.moe.expert_d_ff or 256, 256),
                                capacity_factor=2.0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=8, n_heads=0, head_dim=32, chunk=16)
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
        if self.family == "encdec":
            kw["n_enc_layers"] = 2
            kw["enc_seq_len"] = 32
        if self.family == "vlm":
            kw["vision_dim"] = 64
            kw["n_patches"] = 8
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # "cosine" | "linear" | "constant"
    seed: int = 0
    microbatches: int = 4           # pipeline microbatches (pipeshard)
    remat: bool = True              # per-layer activation checkpointing
    zero_opt_state: bool = False    # shard optimizer state over data axes
    grad_accum: int = 1             # sequential microbatches per step (cuts
    #   activation memory ~grad_accum x at zero extra collective volume)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    plan: str = "shard"             # any repro.core.plans.PLANS key

    def __post_init__(self):
        # validate against the plan registry instead of a hand-kept
        # literal list (lazy import: core.plans imports this module)
        from repro.core.plans import get_plan
        get_plan(self.plan)


def cfg_summary(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    a = cfg.active_param_count()
    s = f"{cfg.name} [{cfg.family}] {cfg.n_layers}L d={cfg.d_model} params={n/1e9:.2f}B"
    if a != n:
        s += f" (active {a/1e9:.2f}B)"
    return s
