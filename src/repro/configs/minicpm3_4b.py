"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention.

[hf:openbmb/MiniCPM3-4B] 62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448.
MiniCPM3 uses MLA (DeepSeek-V2 style) with q_lora_rank=768, kv_lora_rank=256.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,
    rope_theta=10000.0,
    sliding_window=8192,   # long_500k decode variant (see DESIGN.md)
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  rope_head_dim=32, nope_head_dim=64, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)
