"""DeepSeek-V2 236B — MLA (kv_lora=512) + MoE 160 routed top-6 + 2 shared.

[arXiv:2405.04434] 60L d_model=5120 128H (kv=128 — MLA heads) expert
d_ff=1536 vocab=102400.  DeepSeek-V2's first layer is a dense FFN; we fold
it into a uniform MoE stack (deviation noted in DESIGN.md §4) so the layer
stack is scan/pipeline-uniform.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    rope_theta=10000.0,
    sliding_window=8192,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2, expert_d_ff=1536),
    source="arXiv:2405.04434",
)
