"""Phi-3.5-MoE — 42B total / 6.6B active, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct] 32L d_model=4096 32H (GQA kv=8)
expert d_ff=6400 vocab=32064, MoE 16 experts top-2 (no shared experts).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    rope_theta=10000.0,
    sliding_window=8192,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=0, expert_d_ff=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
