"""Architecture config registry.

``get_config(arch_id)`` resolves any assigned architecture id (and the
paper's own gpt2 variants) to its ``ModelConfig``.
"""
from repro.configs.base import (
    INPUT_SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    cfg_summary,
)

from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.phi3_vision_4_2b import CONFIG as _phi3v
from repro.configs.phi35_moe_42b import CONFIG as _phi35moe
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.llama3_405b import CONFIG as _llama405
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4mini
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.gpt2 import GPT2_LARGE, GPT2_LARGE_REDUCED, GPT2_MEDIUM

ARCH_CONFIGS = {
    c.name: c
    for c in [
        _minicpm3, _phi3v, _phi35moe, _falcon_mamba, _zamba2,
        _llama405, _phi4mini, _whisper, _dsv2, _llama32,
        GPT2_MEDIUM, GPT2_LARGE, GPT2_LARGE_REDUCED,
    ]
}

# The ten assigned architectures (excludes the paper's gpt2 models).
ASSIGNED_ARCHS = [
    "minicpm3-4b", "phi-3-vision-4.2b", "phi3.5-moe-42b-a6.6b",
    "falcon-mamba-7b", "zamba2-2.7b", "llama3-405b", "phi4-mini-3.8b",
    "whisper-small", "deepseek-v2-236b", "llama3.2-3b",
]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCH_CONFIGS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCH_CONFIGS)}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}"
        ) from None


__all__ = [
    "ARCH_CONFIGS", "ASSIGNED_ARCHS", "INPUT_SHAPES",
    "MLAConfig", "ModelConfig", "MoEConfig", "RunConfig", "ShapeConfig",
    "SSMConfig", "TrainConfig", "cfg_summary", "get_config", "get_shape",
]
