"""GPT-2 medium / large — the paper's own models (Section III-B).

gpt2m: n_ctx=1024 n_embd=1024 n_head=16 n_layer=24.
gpt2L: n_ctx=1024 n_embd=1280 n_head=20 n_layer=30.
gpt2l: the paper's reduced-memory variant of gpt2L with n_layer=26.
GPT-2 uses learned positions + LayerNorm + GELU; we keep that faithful.
"""
from repro.configs.base import ModelConfig

_COMMON = dict(
    family="dense",
    vocab_size=50257,
    norm="layernorm",
    activation="gelu",
    rope_theta=0.0,        # learned positions, GPT-2 style
    max_seq_len=1024,
    tie_embeddings=True,
)

GPT2_MEDIUM = ModelConfig(
    name="gpt2m", n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, source="paper §III-B (GPT-2 medium)", **_COMMON)

GPT2_LARGE = ModelConfig(
    name="gpt2L", n_layers=30, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, source="paper §III-B (GPT-2 large)", **_COMMON)

GPT2_LARGE_REDUCED = ModelConfig(
    name="gpt2l", n_layers=26, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, source="paper §III-B (gpt2l, n_layer=26)", **_COMMON)
