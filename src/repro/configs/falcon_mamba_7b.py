"""Falcon-Mamba-7B — pure Mamba1 (attention-free SSM).

[arXiv:2410.05355] 64L d_model=4096, d_ff=0 (no MLP; Mamba block is the whole
layer), vocab=65024, ssm_state=16, expand=2 (d_inner=8192), conv kernel 4.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    source="arXiv:2410.05355",
)
