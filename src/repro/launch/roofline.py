"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds:

    compute    = executed_FLOPs / (chips × peak_FLOP/s)
    memory     = HBM_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw
                 (ICI; multi-pod runs price at DCN)

Sources:
  * collective bytes — parsed from the optimized HLO with while-loop trip
    counts applied (launch/hlo_parse.py); raw ``cost_analysis`` counts loop
    bodies once, which would silently drop ~n_layers× of the traffic;
  * executed FLOPs / HBM bytes — closed-form per-step estimates
    (launch/analytic.py) for the same reason, cross-checked against the raw
    ``cost_analysis()`` numbers which are also recorded;
  * per-device memory footprint — ``compiled.memory_analysis()``
    (argument + output + temp), the "does it fit 16 GB HBM" check.

MODEL_FLOPS = 6·N_active·D; useful_flops_fraction = MODEL_FLOPS /
executed_FLOPs exposes remat + full-block-attention waste.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.launch.hlo_parse import collective_bytes_with_trips
from repro.launch.mesh import (DCN_BW_PER_HOST, HBM_BW, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    plan: str
    flops_total: float                  # executed, all chips (analytic)
    hbm_bytes_per_device: float         # analytic stream estimate
    collective_bytes_per_device: float  # HLO-parsed, trip-aware (intra-pod)
    collective_breakdown: Dict[str, float]
    dcn_bytes_per_device: float         # pod-crossing collective bytes
    model_flops: float
    n_devices: int
    memory_per_device_bytes: float      # compiled.memory_analysis footprint
    hlo_flops_raw: float                # cost_analysis (loop bodies once)
    hlo_bytes_raw: float
    crosses_pod: bool = False
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW_PER_LINK

    @property
    def compute_s(self) -> float:
        return self.flops_total / (self.n_devices * self.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        """Intra-pod bytes at ICI bandwidth + pod-crossing bytes at DCN."""
        return self.collective_bytes_per_device / self.ici_bw \
            + self.dcn_bytes_per_device / DCN_BW_PER_HOST

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / self.flops_total if self.flops_total else 0.0

    @property
    def fits_hbm(self) -> bool:
        return self.memory_per_device_bytes <= 16e9   # v5e: 16 GB

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_fraction=self.useful_flops_fraction,
                 fits_hbm=self.fits_hbm)
        return d


def from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                  plan: str, analytic, n_devices: int,
                  crosses_pod: bool = False,
                  hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returned [dict]
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    pod_size = n_devices // 2 if crosses_pod else 0
    coll = collective_bytes_with_trips(text, pod_size=pod_size)
    breakdown = {k: v for k, v in coll.items() if not k.startswith("_")}
    dcn = sum(coll.get("_crossing", {}).values())  # type: ignore[arg-type]
    mem = compiled.memory_analysis()
    mem_bytes = 0.0
    for attr in ("argument_size_in_bytes", "temp_size_in_bytes",
                 "output_size_in_bytes"):
        mem_bytes += float(getattr(mem, attr, 0) or 0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, plan=plan,
        flops_total=analytic.flops_total,
        hbm_bytes_per_device=analytic.hbm_bytes_per_device,
        collective_bytes_per_device=float(sum(breakdown.values())),
        collective_breakdown=dict(
            breakdown, crossing=coll.get("_crossing", {})),
        dcn_bytes_per_device=float(dcn),
        model_flops=analytic.model_flops,
        n_devices=n_devices,
        memory_per_device_bytes=mem_bytes,
        hlo_flops_raw=float(cost.get("flops", 0.0)),
        hlo_bytes_raw=float(cost.get("bytes accessed", 0.0)),
        crosses_pod=crosses_pod,
    )
