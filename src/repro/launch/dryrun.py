import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count at first
# init, and the production meshes below need 512 placeholder devices.

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers AND compiles, and extract the roofline terms.

For each combination this builds the plan-sharded step (train_step for
train_4k, prefill_step for prefill_32k, serve_step for decode shapes —
ONE token against a seq_len KV cache), lowers it against
ShapeDtypeStruct inputs (zero allocation), compiles for the 16x16
single-pod mesh (and the 2x16x16 multi-pod mesh with --multi-pod), prints
``compiled.memory_analysis()`` / ``cost_analysis()`` and writes the
roofline JSON consumed by benchmarks/ and EXPERIMENTS.md.
"""
import argparse
import json
import sys
import time
import traceback


def skip_reason(cfg, shape) -> str:
    """Documented skips (DESIGN.md §4)."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return ("whisper-small: full-attention enc-dec decoder; 500k-token "
                    "audio transcripts out of scope (DESIGN.md §4)")
        if not cfg.supports_long_context:
            return f"{cfg.name}: no sub-quadratic attention variant"
    return ""


def build_step(model, plan, mesh, cfg, shape, tcfg):
    """Returns (jitted fn, example args pytree of ShapeDtypeStructs,
    analytic cost record for the roofline)."""
    import jax
    import jax.numpy as jnp

    from repro.core.steps import (build_prefill_step, build_serve_step,
                                  build_train_step)
    from repro.launch.analytic import analytic_cost, plan_degrees
    from repro.models.model import cast_params
    from repro.models.registry import input_specs
    from repro.optim import init_adamw

    dt = jnp.dtype(cfg.dtype)
    p_shapes = jax.eval_shape(
        lambda: cast_params(model.init(jax.random.key(0)), dt))
    batch = input_specs(cfg, shape)
    n_dev = mesh.devices.size
    dp, tp, zdeg = plan_degrees(plan, mesh, shape.global_batch)

    if shape.kind == "train":
        o_shapes = jax.eval_shape(init_adamw, p_shapes)
        step, sh = build_train_step(model, plan, mesh, tcfg,
                                    params_shapes=p_shapes,
                                    batch_shapes=batch)
        args = (p_shapes, o_shapes, batch)
        cost = analytic_cost(cfg, shape, n_devices=n_dev, dp=dp, tp=tp,
                             zero_deg=zdeg, remat=tcfg.remat)
    elif shape.kind == "prefill":
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        step, sh = build_prefill_step(model, plan, mesh,
                                      params_shapes=p_shapes,
                                      batch_shapes=batch,
                                      cache_shapes=c_shapes,
                                      batch_size=shape.global_batch)
        args = (p_shapes, batch, c_shapes)
        cost = analytic_cost(cfg, shape, n_devices=n_dev, dp=dp, tp=tp)
    else:  # decode
        window = 0
        if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            window = cfg.sliding_window
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     window=window))
        step, sh = build_serve_step(model, plan, mesh,
                                    params_shapes=p_shapes,
                                    cache_shapes=c_shapes,
                                    batch_size=shape.global_batch,
                                    window=window)
        args = (p_shapes, c_shapes, batch["tokens"])
        cost = analytic_cost(cfg, shape, n_devices=n_dev, dp=dp, tp=tp,
                             window=window)
    return step, args, cost


def run_one(arch: str, shape_name: str, plan_name: str, *,
            multi_pod: bool = False, verbose: bool = True,
            grad_accum: int = 1):
    import jax

    from repro.configs import get_config, get_shape
    from repro.configs.base import TrainConfig
    from repro.core.plans import get_plan
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.models import Model

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "plan": plan_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": reason}

    plan = get_plan(plan_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    tcfg = TrainConfig(grad_accum=grad_accum)
    t0 = time.time()
    with jax.set_mesh(mesh):
        step, args, acost = build_step(model, plan, mesh, cfg, shape, tcfg)
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'} ({plan_name}) ---")
        print(f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("memory_analysis:", mem)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax 0.4.x: list per device
            cost = cost[0] if cost else {}
        keys = ("flops", "bytes accessed")
        print("cost_analysis:", {k: cost.get(k) for k in keys})
    roof = rl.from_compiled(
        compiled, arch=arch, shape=shape_name,
        mesh_name="2x16x16" if multi_pod else "16x16", plan=plan_name,
        analytic=acost, n_devices=mesh.devices.size,
        crosses_pod=multi_pod)
    rec = roof.to_dict()
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))
    if verbose:
        print(f"roofline: compute {roof.compute_s * 1e3:.3f} ms | memory "
              f"{roof.memory_s * 1e3:.3f} ms | collective "
              f"{roof.collective_s * 1e3:.3f} ms | dominant {roof.dominant} "
              f"| useful-flops {roof.useful_flops_fraction:.2f} | "
              f"mem/dev {roof.memory_per_device_bytes / 1e9:.2f} GB "
              f"(fits 16GB HBM: {roof.fits_hbm})")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--plan", default=None,
                    help="default: shard_zero for train, shard for serve")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    from repro.configs import get_shape
    plan = args.plan or ("shard_zero"
                         if get_shape(args.shape).kind == "train" else "shard")
    try:
        rec = run_one(args.arch, args.shape, plan, multi_pod=args.multi_pod,
                      grad_accum=args.grad_accum)
    except Exception as e:
        traceback.print_exc()
        rec = {"arch": args.arch, "shape": args.shape, "plan": plan,
               "mesh": "multi" if args.multi_pod else "single",
               "status": "fail", "error": f"{type(e).__name__}: {e}"}
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k in ("arch", "shape", "plan", "status", "dominant",
                               "reason", "error")}))
    return 0 if rec["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
