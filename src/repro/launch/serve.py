"""Serving launcher: batched prefill + decode for any registered arch,
fixed-batch by default, continuous batching with ``--continuous``.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch falcon-mamba-7b --reduced --batch 4 --gen 32

    PYTHONPATH=src python -m repro.launch.serve --reduced --continuous \
        --trace 12x8..32 --batch 3 --gen 8
"""
import argparse
import os


def parse_trace(spec: str, max_prompt: int):
    """``<n>x<lo>..<hi>`` — n requests with prompt lengths uniform in
    [lo, hi] (deterministic, seed 0).  Plain ``<n>`` uses 8..max_prompt."""
    body = spec
    lo, hi = 8, max_prompt
    if "x" in spec:
        body, rng_part = spec.split("x", 1)
        try:
            lo, hi = (int(v) for v in rng_part.split("..", 1))
        except ValueError:
            raise SystemExit(
                f"bad --trace {spec!r}: want <n>x<lo>..<hi> or <n>")
    try:
        n = int(body)
    except ValueError:
        raise SystemExit(f"bad --trace {spec!r}: want <n>x<lo>..<hi> or <n>")
    if not (n >= 1 and 1 <= lo <= hi <= max_prompt):
        raise SystemExit(
            f"bad --trace {spec!r}: need n >= 1 and "
            f"1 <= lo <= hi <= {max_prompt}")
    return n, lo, hi


def main() -> None:
    from repro.core.plans import PLANS

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan", default="shard", choices=sorted(PLANS),
                    help="registered parallelism plan (core/plans.py)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--batch", type=int, default=4,
                    help="fixed batch rows / continuous decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window cache (long-context decode)")
    ap.add_argument("--kv-dtype", default="fp32", choices=("fp32", "int8"),
                    help="int8: quantized KV cache + int8-KV decode kernel")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching "
                         "(greedy; --batch = slot count)")
    ap.add_argument("--trace", default=None, metavar="N[xLO..HI]",
                    help="continuous request trace: N prompts with "
                         "lengths uniform in [LO, HI] (default "
                         "2x the slot count over 8..--prompt-len)")
    args = ap.parse_args()
    if args.trace and not args.continuous:
        ap.error("--trace only applies with --continuous")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.plans import get_plan
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.serve import ContinuousEngine, Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_host_mesh(shape, axes)
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen + 8
    header = (f"{cfg.name} [{cfg.family}] plan={args.plan} "
              f"batch={args.batch} kv={args.kv_dtype}")

    if args.continuous:
        n, lo, hi = parse_trace(args.trace or f"{2 * args.batch}",
                                args.prompt_len)
        prompts = [np.asarray(
            rng.integers(4, min(cfg.vocab_size, 400),
                         (int(rng.integers(lo, hi + 1)),)), np.int32)
            for _ in range(n)]
        eng = ContinuousEngine(model, get_plan(args.plan), mesh,
                               slots=args.batch, max_len=max_len,
                               kv_dtype=args.kv_dtype)
        res = eng.run(params,
                      [Request(i, p) for i, p in enumerate(prompts)],
                      max_new=args.gen)
        st = res["stats"]
        lens = sorted(len(p) for p in prompts)
        print(f"{header} continuous slots={args.batch}")
        print(f"{n} requests (prompt lens {lens[0]}..{lens[-1]}) | "
              f"{st.n_tokens} tokens in {st.total_s:.2f}s | "
              f"{st.tokens_per_s:.1f} tok/s | "
              f"occupancy {st.mean_occupancy:.2f}/{args.batch} | "
              f"TTFT p50 "
              f"{np.percentile(sorted(st.ttft_s.values()), 50):.3f}s")
        return

    batch = {"tokens": np.asarray(
        rng.integers(4, min(cfg.vocab_size, 400),
                     (args.batch, args.prompt_len)), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim))
            * 0.02, np.float32)
    if cfg.family == "encdec":
        batch["frames"] = np.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq_len, cfg.d_model))
            * 0.02, np.float32)

    eng = Engine(model, get_plan(args.plan), mesh, batch_size=args.batch,
                 max_len=max_len, window=args.window,
                 temperature=args.temperature, kv_dtype=args.kv_dtype)
    out = eng.generate(params, batch, n_tokens=args.gen)
    s = out["stats"]
    print(header)
    print(f"prefill {s.prefill_s * 1e3:.0f} ms | decode "
          f"{s.steps_per_s:.1f} steps/s "
          f"({s.tokens_per_s:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
