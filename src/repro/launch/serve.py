"""Serving launcher: batched prefill + decode for any registered arch.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch falcon-mamba-7b --reduced --batch 4 --gen 32
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan", default="shard")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window cache (long-context decode)")
    ap.add_argument("--kv-dtype", default="fp32", choices=("fp32", "int8"),
                    help="int8: quantized KV cache + int8-KV decode kernel")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.plans import get_plan
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.serve import Engine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_host_mesh(shape, axes)
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(
        rng.integers(4, min(cfg.vocab_size, 400),
                     (args.batch, args.prompt_len)), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim))
            * 0.02, np.float32)
    if cfg.family == "encdec":
        batch["frames"] = np.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq_len, cfg.d_model))
            * 0.02, np.float32)

    eng = Engine(model, get_plan(args.plan), mesh, batch_size=args.batch,
                 max_len=args.prompt_len + args.gen + 8, window=args.window,
                 temperature=args.temperature, kv_dtype=args.kv_dtype)
    out = eng.generate(params, batch, n_tokens=args.gen)
    s = out["stats"]
    print(f"{cfg.name} [{cfg.family}] plan={args.plan} batch={args.batch} "
          f"kv={args.kv_dtype}")
    print(f"prefill {s.prefill_s * 1e3:.0f} ms | decode "
          f"{s.tokens_per_s:.1f} steps/s "
          f"({s.tokens_per_s * args.batch:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
