"""Training launcher: pretrain any registered architecture under any plan.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-3b --reduced --plan shard_zero \
        --devices 8 --mesh 2,2,2 --steps 100

On a real TPU slice drop --devices (jax discovers the topology) and pass
--mesh to match it; --reduced serves the smoke variant for CPU runs.
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant")
    ap.add_argument("--plan", default="shard_zero",
                    metavar="PLAN",
                    help="execution plan — any repro.core.plans.PLANS "
                         "key (validated against the registry after the "
                         "device-count override, so the choices are "
                         "never a stale hand-kept list)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use real devices)")
    ap.add_argument("--mesh", default="1,1",
                    help="mesh shape, e.g. 2,2,2 for (pod,data,model)")
    ap.add_argument("--stages", type=int, default=2,
                    help="pipeline stages (pipeshard)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--docs", type=int, default=500,
                    help="synthetic corpus size (use --data-dir for real)")
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.core.pipeline import pipeline_mesh
    from repro.core.plans import get_plan
    from repro.data import (Loader, Tokenizer, build_dataset, load_text_dir,
                            synthetic_wikipedia)
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train import model_flops_per_step, train

    texts = list(load_text_dir(args.data_dir)) if args.data_dir else \
        list(synthetic_wikipedia(args.docs, seed=args.seed))
    tok = Tokenizer.train(texts, args.vocab)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                              max_seq_len=max(cfg.max_seq_len, args.seq))
    ds = build_dataset(texts, tok, seq_len=args.seq)
    loader = Loader(ds, global_batch=args.batch, seed=args.seed)

    shape = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    base = make_host_mesh(shape, axes)
    plan = get_plan(args.plan)      # KeyError lists the registry's plans
    mesh = pipeline_mesh(base, args.stages) if plan.pipeline else base

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                       total_steps=args.steps, seed=args.seed,
                       microbatches=args.microbatches)
    model = Model(cfg)
    print(f"{cfg.name} [{cfg.family}] {cfg.param_count() / 1e6:.1f}M params "
          f"| plan={args.plan} mesh={dict(zip(axes, shape))}")
    res = train(model, plan, mesh, tcfg, loader, steps=args.steps,
                log_every=max(args.steps // 10, 1),
                ckpt_dir=args.ckpt_dir)
    flops = model_flops_per_step(cfg, args.batch * args.seq)
    print(f"done: loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"{res.tflops(flops):.4f} TFLOP/s avg")


if __name__ == "__main__":
    main()
