"""Production meshes, and topology→mesh mapping.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the slow (DCN) dimension, the TPU analogue of the paper's
site-to-site WAN links.

``make_topology_mesh`` maps an N-site ``core.topology.Topology`` selection
onto the same axis vocabulary: one pod block per selected site, intra-site
GPUs split over (data, model).  Pipeshard's ``pipeline_mesh`` then absorbs
the pod axis into stages, so a ``core.search`` stage→site assignment lands
each stage on its site's devices (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; only launch/dryrun.py forces
the 512-device host platform.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh as _compat_make_mesh
from repro.core.topology import Topology


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh(shape, axes) -> Mesh:
    """Small explicit meshes for tests (host devices)."""
    return _compat_make_mesh(tuple(shape), tuple(axes))


# --------------------------------------------------------------------- #
# topology sites -> mesh axes
# --------------------------------------------------------------------- #

def topology_mesh_spec(topo: Topology,
                       sites: Optional[Sequence[int]] = None, *,
                       model: int = 1
                       ) -> Tuple[Tuple[int, int, int],
                                  Tuple[str, str, str]]:
    """(shape, axes) of the mesh realizing a site selection: pod = one
    block per site (the slow inter-site dimension), each site's GPUs split
    into (data, model).  Pure function of the topology — unit-testable
    without devices; ``make_topology_mesh`` materializes it."""
    sel = topo.select(sites)
    if not sel:
        raise ValueError("empty site selection")
    per = {len(topo.sites[i].gpus) for i in sel}
    if len(per) != 1:
        raise ValueError(
            f"sites {sel} have unequal GPU counts {sorted(per)}; meshes "
            f"are rectangular — select equal-sized sites per mesh")
    n_per = per.pop()
    if n_per % model != 0:
        raise ValueError(f"model={model} does not divide the {n_per} GPUs "
                         f"per site")
    return (len(sel), n_per // model, model), ("pod", "data", "model")


def make_topology_mesh(topo: Topology,
                       sites: Optional[Sequence[int]] = None, *,
                       model: int = 1, devices=None) -> Mesh:
    """Mesh over `devices` (default: all local) shaped after a topology
    site selection; device blocks follow the order of `sites`."""
    shape, axes = topology_mesh_spec(topo, sites, model=model)
    n = shape[0] * shape[1] * shape[2]
    devs = list(jax.devices()) if devices is None else list(devices)
    if len(devs) < n:
        raise ValueError(f"topology selection needs {n} devices, "
                         f"have {len(devs)}")
    return _compat_make_mesh(shape, axes, devices=devs[:n])


def placement_pipeline_mesh(topo: Topology, placement, *,
                            model: int = 1, devices=None) -> Mesh:
    """Realize a searched pipeline ``core.plans.Placement`` as a staged
    mesh: one pod block per placed site, pod blocks permuted into the
    placement's stage order, and the TFLOP-weighted ``stage_layers``
    (when present) shape-checked against the stage count — the full
    Placement → ``make_topology_mesh`` → ``pipeline_mesh`` wiring of
    DESIGN.md §5 in one call.  Pass the same ``placement.stage_layers``
    to ``core.steps.build_train_step`` / ``core.pipeline
    .make_pipeline_loss`` so the split executes (uneven splits run
    pad-and-masked).

    Args:
        topo: the N-site topology the placement was searched on.
        placement: a ``core.plans.Placement`` (site subset, stage order,
            optional per-stage layer counts).
        model: tensor-parallel degree inside each site.
        devices: explicit device list (default: all local devices).

    Returns:
        A ``(stage, data, model)`` mesh with stage k on the devices of
        the site the search assigned to stage k.
    """
    from repro.core.pipeline import pipeline_mesh
    base = make_topology_mesh(topo, placement.sites, model=model,
                              devices=devices)
    return pipeline_mesh(base, placement.n_stages,
                         stage_order=placement.pod_permutation(),
                         stage_layers=placement.stage_layers,
                         schedule=placement.schedule)


def placement_mesh(topo: Topology, plan, placement, *,
                   model: int = 1, devices=None) -> Mesh:
    """Realize any searched ``core.plans.Placement`` for a plan: the
    one-call Placement → mesh wiring the extended technique pool needs
    (docs/cost-model.md).  Pipeline plans build the staged mesh
    (``placement_pipeline_mesh``); flat plans — data/zero2/shard/
    shard_zero/fsdp winners — get the plain topology mesh over the
    placement's site subset.

    Args:
        topo: the N-site topology the placement was searched on.
        plan: the ``core.plans.Plan`` being launched.
        placement: the searched ``core.plans.Placement``.
        model: tensor-parallel degree inside each site.
        devices: explicit device list (default: all local devices).

    Returns:
        A mesh the plan's shardings apply to directly.
    """
    if plan.pipeline:
        return placement_pipeline_mesh(topo, placement, model=model,
                                       devices=devices)
    return make_topology_mesh(topo, placement.sites, model=model,
                              devices=devices)


# TPU v5e roofline constants (per chip) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s/link
DCN_BW_PER_HOST = 6.25e9      # bytes/s (50 Gbit) — inter-pod "WAN"
