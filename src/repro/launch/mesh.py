"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the slow (DCN) dimension, the TPU analogue of the paper's
site-to-site WAN links.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; only launch/dryrun.py forces
the 512-device host platform.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape, axes) -> Mesh:
    """Small explicit meshes for tests (host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


# TPU v5e roofline constants (per chip) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s/link
DCN_BW_PER_HOST = 6.25e9      # bytes/s (50 Gbit) — inter-pod "WAN"
