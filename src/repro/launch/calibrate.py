"""Calibration launcher: profile this host, fit a measured-rate overlay,
and write it as JSON the search can load (docs/calibration.md §3).

    PYTHONPATH=src python -m repro.launch.calibrate \
        --cluster TACC-TACC --model gpt2m --out calibration.json

Measurement protocol per host (each site runs the same command with its
own ``--site``; link rows need one run per site *pair* with the ring
harness pointed across the real socket):

  1. kernel micro-bench (``repro.calib.microbench.kernel_compute_samples``)
     — Pallas kernels + the jitted fp32 matmul, interpret mode on CPU —
     yields the site's achieved-TFLOPs rows;
  2. ring-collective micro-bench (``host_ring_collective_samples``) —
     the 2(n-1)-exchange decomposition the cost model prices, timed at
     several payload sizes — yields the link's α/β rows;
  3. optionally, ε-epoch Algorithm-1 probes pooled through
     ``RecordingProber`` (``--probe-steps``) — whole-step rows that tie
     the per-component fits together.

``--synthetic NOISE`` replaces the hardware measurements with the
synthetic-ground-truth harness (a pinned slow-A30 truth) so the whole
profile→fit→search loop runs end-to-end on any machine —
``benchmarks/calib_bench.py`` drives the same loop into BENCH_9.json.
"""
import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cluster", default="TACC-TACC",
                    help="paper cluster name (repro.core.costmodel"
                         ".PAPER_CLUSTERS) to calibrate against")
    ap.add_argument("--model", default="gpt2m",
                    help="workload config for step probes and the "
                         "before/after search report")
    ap.add_argument("--site", type=int, default=0,
                    help="which site index this host stands for")
    ap.add_argument("--out", default=None,
                    help="write the fitted calibration JSON here")
    ap.add_argument("--probe-steps", action="store_true",
                    help="pool analytic Algorithm-1 probes as step rows "
                         "(on hardware, wire a LiveProber instead)")
    ap.add_argument("--synthetic", type=float, default=None,
                    metavar="NOISE",
                    help="skip hardware profiling: fit against the "
                         "synthetic slow-A30 ground truth perturbed by "
                         "this multiplicative noise bound")
    ap.add_argument("--iters", type=int, default=2,
                    help="timed iterations per micro-bench point")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np

    from repro.calib.fit import fit_calibration
    from repro.calib.microbench import (RecordingProber,
                                        host_ring_collective_samples,
                                        kernel_compute_samples,
                                        synthetic_measurements)
    from repro.calib.overlay import Calibration, LinkRate
    from repro.configs import get_config
    from repro.core.costmodel import (PAPER_CLUSTERS, as_topology,
                                      paper_workload)
    from repro.core.search import PlanSearch
    from repro.core.selector import CostModelProber

    wl = paper_workload(get_config(args.model))
    topo = as_topology(PAPER_CLUSTERS[args.cluster])
    rng = np.random.default_rng(args.seed)

    if args.synthetic is not None:
        truth = Calibration(
            site_tflops={i: 0.6 * min(
                25.0, Calibration.identity().gpu_tflops(topo, i))
                for i in range(topo.n_sites)},
            links={(0, min(1, topo.n_sites - 1)): LinkRate(22e-3, 2.4)},
            note="synthetic slow ground truth")
        samples = synthetic_measurements(
            topo, truth, rng=rng, noise=args.synthetic, wl=wl,
            step_placements=[("data", (0,), {}),
                             ("zero2", tuple(range(topo.n_sites)), {})])
        print(f"synthetic harness: {len(samples)} samples at "
              f"noise={args.synthetic}")
    else:
        samples = kernel_compute_samples(args.site, iters=args.iters,
                                         seed=args.seed)
        samples += host_ring_collective_samples(
            (args.site, args.site), iters=args.iters)
        print(f"profiled site {args.site}: {len(samples)} samples "
              "(kernel compute + host-ring collective)")
        if args.probe_steps:
            rec = RecordingProber(CostModelProber(wl, topo), wl)
            PlanSearch(wl, topo, probe_fn=rec.probe).search()
            samples += rec.samples
            print(f"pooled {len(rec.samples)} step probes")

    fr = fit_calibration(topo, samples, note=f"{args.cluster} fit")
    cal = fr.calibration
    print(cal.describe(topo))
    print(f"fit residual {fr.residual:.3e} over {fr.n_samples} samples "
          f"({fr.n_iterations} linearization passes)")

    before = PlanSearch(wl, topo).best()
    after = PlanSearch(wl, topo, calibration=cal).best()
    print(f"search winner: {before.candidate.key} "
          f"({before.tflops:.2f} TFLOP/s analytic) -> "
          f"{after.candidate.key} ({after.tflops:.2f} calibrated)")

    if args.out:
        with open(args.out, "w") as f:
            f.write(cal.dumps())
        print(f"wrote {args.out}")
        # round-trip check: the file must load back to the same overlay
        with open(args.out) as f:
            assert Calibration.loads(f.read()) == cal
    return 0


if __name__ == "__main__":
    sys.exit(main())
