"""Optimized-HLO text analysis: collective bytes with while-loop trip
counts and pod-boundary classification.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count, which silently drops ~n_layers× of the collective traffic of a
scanned transformer.  This parser rebuilds the computation graph from the
HLO text: per-computation collective result bytes, while-ops resolved to
their (body, condition) computations, trip counts read from the condition's
integer constant, and totals accumulated recursively from ENTRY.

Each collective is additionally classified as intra-pod (ICI) or
pod-crossing (DCN) by *evaluating* its ``replica_groups`` iota tile
assignment (``[G,N]<=[dims]T(perm)``) or ``source_target_pairs`` against
the pod boundary, so multi-pod rooflines can price the slow axis correctly
— the TPU analogue of the paper's WAN-vs-PCIe distinction.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_BLOCK_START = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[0-9,\]\[\s]*\]?\)?[^=]*?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{}\s]*)\}")


def _groups_cross_pod(line: str, pod_size: int) -> bool:
    """Does this collective's participant set span a pod boundary?"""
    if pod_size <= 0:
        return False
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        g, n, dims_s, perm_s = m.groups()
        dims = [int(x) for x in dims_s.split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if perm_s:
            ids = ids.transpose([int(x) for x in perm_s.split(",")])
        groups = ids.reshape(int(g), int(n))
        pods = groups // pod_size
        return bool(np.any(pods.min(axis=1) != pods.max(axis=1)))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        for grp in re.findall(r"\{([0-9,\s]*)\}", m.group(0)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
        return False
    m = _PAIRS_RE.search(line)
    if m:
        for pair in re.findall(r"\{(\d+),(\d+)\}", m.group(0)):
            if int(pair[0]) // pod_size != int(pair[1]) // pod_size:
                return True
        return False
    # replica_groups={} (all participants) or unknown: conservative
    return True


@dataclass
class Computation:
    name: str
    # (kind, crossing) -> bytes / count
    coll_bytes: Dict[Tuple[str, bool], float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)
    whiles: List[Tuple[str, str]] = field(default_factory=list)
    max_const: int = 0


def _result_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_computations(hlo_text: str, pod_size: int = 0
                       ) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry_name = ""
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _BLOCK_START.match(line)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        if "-done(" not in line:
            cm = _COLL_RE.search(line)
            if cm:
                sig, kind, _ = cm.groups()
                crossing = _groups_cross_pod(line, pod_size)
                key = (kind, crossing)
                cur.coll_bytes[key] = cur.coll_bytes.get(key, 0.0) \
                    + _result_bytes(sig)
                cur.coll_counts[kind] = cur.coll_counts.get(kind, 0) + 1
        wm = _WHILE_RE.search(line)
        if wm:
            cur.whiles.append((wm.group(1), wm.group(2)))
        for c in _CONST_RE.findall(line):
            cur.max_const = max(cur.max_const, int(c))
    return comps, entry_name


def collective_bytes_with_trips(hlo_text: str, pod_size: int = 0
                                ) -> Dict[str, object]:
    """Totals per collective kind (while bodies × trip counts), split into
    intra-pod vs pod-crossing bytes.

    Returns {kind: bytes, ..., "_crossing": {kind: bytes}, "_static_op_counts": {...}}.
    """
    comps, entry = parse_computations(hlo_text, pod_size)
    memo: Dict[str, Dict[Tuple[str, bool], float]] = {}

    def resolve(name: str, depth: int = 0) -> Dict[Tuple[str, bool], float]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out: Dict[Tuple[str, bool], float] = {}
        if comp is None or depth > 16:
            return out
        memo[name] = out
        for k, v in comp.coll_bytes.items():
            out[k] = out.get(k, 0.0) + v
        for cond_name, body_name in comp.whiles:
            cond = comps.get(cond_name)
            trips = max(cond.max_const if cond else 1, 1)
            inner = resolve(body_name, depth + 1)
            for k, v in inner.items():
                out[k] = out.get(k, 0.0) + trips * v
        return out

    totals = resolve(entry) if entry else {}
    local = {k: 0.0 for k in COLLECTIVE_KINDS}
    crossing = {k: 0.0 for k in COLLECTIVE_KINDS}
    for (kind, is_cross), v in totals.items():
        (crossing if is_cross else local)[kind] += v
    counts: Dict[str, int] = {}
    for comp in comps.values():
        for k, v in comp.coll_counts.items():
            counts[k] = counts.get(k, 0) + v
    result: Dict[str, object] = dict(local)
    result["_crossing"] = crossing
    result["_static_op_counts"] = counts
    return result
