"""Pipeline-runtime parity check (used by tests/test_pipeline_uneven.py
and tests/test_pipeline_schedules.py).

Searches a heterogeneous single-GPU-per-site line topology (A30/T4 mix)
with TFLOP-weighted stage balancing, realizes the winning Pipeshard
``Placement`` as a (stage, 1, 1) host-device mesh, and runs the pad-and-
mask pipeline loss (core/pipeline.py) against the unsharded reference
``model.loss`` — under every requested tick-order ``--schedules``
(GPipe / 1F1B / interleaved, docs/schedules.md).  Prints a JSON report:

    {"stage_layers": [...], "splits": {...}, "ref_loss": ...,
     "losses": {...}, "ref_gnorm": ..., "gnorms": {...}, ...}

``losses``/``gnorms``/``auxes`` keys: ``searched`` (the searched,
possibly uneven split), plus — when the layer count divides the chunk
count — ``legacy`` (stage_layers=None equal-block fast path) and
``even`` (the same equal split passed explicitly, which exercises the
gather+mask path; it must be bit-identical to ``legacy``).  Non-GPipe
schedules suffix their keys, e.g. ``searched@1f1b``; schedules reorder
work without changing math, so every entry must equal the reference.

``--carrier bf16`` runs the checks with bf16 inter-stage carriers (the
halved-bytes wire format the cost model's ``carrier_dtype`` knob
prices); the fp32 default is the XLA-CPU-safe baseline.

Must run in its own process: ``--devices`` forces the XLA host platform
device count, which locks at first jax init.  The (stage, 1, 1) meshes
have no non-trivial auto axes, so this runs even on jax 0.4.x where the
partial-auto pipeshard tests must skip (repro.compat.NATIVE_SHARD_MAP).
"""
import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gpus", default="A30,T4",
                    help="one GPU type per site/stage, comma-separated")
    ap.add_argument("--arch", default="gpt2m",
                    help="config name; non-dense families (moe) exercise "
                         "the aux-loss accounting across stages")
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--schedules", default="gpipe",
                    help="comma-separated pipeline schedules to check "
                         "(gpipe, 1f1b, interleaved, interleaved<v>)")
    ap.add_argument("--carrier", default="fp32",
                    choices=("fp32", "bf16"),
                    help="inter-stage activation carrier dtype "
                         "(core.costmodel.CARRIER_DTYPES).  bf16 is the "
                         "halved-bytes carrier the cost model prices "
                         "(docs/cost-model.md); on XLA CPU it trips the "
                         "SPMD partitioner bug make_pipeline_loss "
                         "documents, so it stays opt-in")
    args = ap.parse_args()

    gpus = args.gpus.split(",")
    n_sites = len(gpus)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_sites} "
        + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    carrier_dtype = jnp.bfloat16 if args.carrier == "bf16" else jnp.float32

    from repro.configs import get_config
    from repro.core.costmodel import Workload, parse_schedule
    from repro.core.pipeline import make_pipeline_loss
    from repro.core.search import PlanSearch
    from repro.core.topology import Link, Site, line
    from repro.launch.mesh import placement_pipeline_mesh
    from repro.models import Model

    schedules = args.schedules.split(",")
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              n_layers=args.layers)
    model = Model(cfg)

    topo = line("hetline",
                [Site((g,), name=f"S{i}") for i, g in enumerate(gpus)],
                [Link(20e-3, 3.0)] * (n_sites - 1))
    wl = Workload(cfg, args.seq, args.batch, steps_per_epoch=1,
                  microbatches=args.micro)
    search = PlanSearch(wl, topo, stage_balance="tflops",
                        schedules=tuple(schedules))

    def searched_placement(sched):
        cand = next(c for c in search.candidates()
                    if c.technique == "pipeshard"
                    and c.sites == tuple(range(n_sites))
                    and c.stage_order == tuple(range(n_sites))
                    and c.schedule == sched)
        return search.placement(cand)

    placement = searched_placement(schedules[0])

    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (args.batch, args.seq))
    # ragged/packed-style positions: every example gets its own offset, so
    # reusing microbatch 0's rows for later microbatches would be visible
    positions = np.arange(args.seq)[None] \
        + (np.arange(args.batch)[:, None] % 3)
    batch = {"tokens": jnp.asarray(tokens, jnp.int32),
             "labels": jnp.asarray(tokens, jnp.int32),
             "positions": jnp.asarray(positions, jnp.int32)}
    params = model.init(jax.random.key(0))

    def gnorm(grads):
        return float(jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))))

    # loss from the plain forward (the bit-for-bit comparison), grads from
    # a separate value_and_grad: under remat the forward recomputed inside
    # the vjp can differ from the plain forward by an ulp, so mixing the
    # two would blur the exactness claim.
    ref_loss, ref_metrics = model.loss(params, batch)
    ref_grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)

    losses, gnorms, auxes, split_report = {}, {}, {}, {}
    for sched in schedules:
        sched_placement = searched_placement(sched)
        _, virt = parse_schedule(sched)
        n_chunks = n_sites * virt
        splits = {"searched": sched_placement.stage_layers}
        if args.layers % n_chunks == 0:
            splits["legacy"] = None
            splits["even"] = (args.layers // n_chunks,) * n_chunks
        mesh = placement_pipeline_mesh(topo, sched_placement,
                                       devices=jax.devices())
        with jax.set_mesh(mesh):
            for name, split in splits.items():
                key = name if sched == "gpipe" else f"{name}@{sched}"
                split_report[key] = None if split is None else list(split)
                loss_fn = make_pipeline_loss(model, mesh, args.micro,
                                             stage_layers=split,
                                             schedule=sched,
                                             carrier_dtype=carrier_dtype)
                loss, metrics = jax.jit(loss_fn)(params, batch)
                grads = jax.jit(jax.grad(
                    lambda p: loss_fn(p, batch)[0]))(params)
                losses[key] = float(loss)
                gnorms[key] = gnorm(grads)
                auxes[key] = float(metrics["aux"])

    print(json.dumps({
        "stage_layers": list(placement.stage_layers or ()),
        "splits": split_report,
        "ref_loss": float(ref_loss),
        "losses": losses,
        "ref_gnorm": gnorm(ref_grads),
        "gnorms": gnorms,
        "ref_aux": float(ref_metrics["aux"]),
        "auxes": auxes,
    }))


if __name__ == "__main__":
    main()
