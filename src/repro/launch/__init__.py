"""Launchers: production meshes, the multi-pod dry-run, roofline
extraction, training/serving CLIs, and the plan-equivalence checker."""
