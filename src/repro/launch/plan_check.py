"""Numerical plan-equivalence check (used by tests/test_plans.py).

Runs a tiny model one train step under each plan on a small host-device
mesh and prints the losses as JSON: every registered plan (``--plans
all`` derives the list from ``repro.core.plans.PLANS`` — data, zero2,
shard, shard_zero, pipeshard, fsdp) must compute the same mathematical
update, so losses (and a probe-param norm) must agree.

Must run in its own process: ``--devices`` forces the XLA host platform
device count, which locks at first jax init.
"""
import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--plans", default="all",
                    help="comma-separated repro.core.plans.PLANS keys, or "
                         "'all' for every registered plan")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--steps", type=int, default=2)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.core.pipeline import pipeline_mesh
    from repro.core.plans import PLANS, get_plan
    from repro.core.steps import build_train_step
    from repro.models import Model
    from repro.models.registry import abstractify, input_specs
    from repro.optim import init_adamw

    # "all" derives from the plan registry (imported only after the
    # XLA_FLAGS device-count override above) instead of a hand-kept list
    plan_names = list(PLANS) if args.plans == "all" \
        else args.plans.split(",")

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=args.layers)
    if cfg.hybrid_attn_every:
        cfg = dataclasses.replace(cfg, hybrid_attn_every=max(
            1, args.layers // 4))
    model = Model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10,
                       microbatches=4, remat=True)
    shape = ShapeConfig("t", args.seq, args.batch, "train")
    rng = np.random.default_rng(0)
    batch = input_specs(cfg, shape, abstract=False, rng=rng)

    n = args.devices
    assert n % 4 == 0
    base = jax.make_mesh((n // 4, 2, 2), ("pod", "data", "model"))

    results = {}
    for plan_name in plan_names:
        plan = get_plan(plan_name)
        mesh = pipeline_mesh(base, 2) if plan.pipeline else base
        with jax.set_mesh(mesh):
            params = model.init(jax.random.key(0))
            opt = init_adamw(params)
            p_shapes = abstractify(params)
            b_shapes = abstractify(batch)
            step, sh = build_train_step(model, plan, mesh, tcfg,
                                        params_shapes=p_shapes,
                                        batch_shapes=b_shapes)
            params = jax.device_put(params, sh["params"])
            opt = jax.device_put(opt, sh["opt"])
            b = jax.device_put(batch, sh["batch"])
            losses = []
            for _ in range(args.steps):
                params, opt, metrics = step(params, opt, b)
                losses.append(float(metrics["loss"]))
            # probe: norm of all params after updates
            pnorm = float(jnp.sqrt(sum(
                jnp.sum(jnp.square(x.astype(jnp.float32)))
                for x in jax.tree.leaves(params))))
        results[plan_name] = {"losses": losses, "param_norm": pnorm}
    print(json.dumps(results))


if __name__ == "__main__":
    main()
