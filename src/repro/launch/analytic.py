"""Analytic per-step FLOPs and HBM-byte estimates per (arch × shape × plan).

XLA's cost_analysis undercounts scanned (while-loop) bodies, so the
roofline's compute and memory terms use these closed-form estimates of
what the compiled program actually executes (including remat recompute and
the chunked-attention implementation's full-block scores), while the
collective term comes from the trip-count-aware HLO parse
(launch/hlo_parse.py).  MODEL_FLOPS = 6·N_active·D stays the *useful* work
yardstick — the gap between the two is the remat/full-block waste reported
as ``useful_flops_fraction``.

Per-device traffic depends on the plan: ``dp`` (batch-sharding degree) and
``tp`` (model-axis degree) describe how activations / weights / caches are
spread; ``zero_deg`` how optimizer state is spread.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_heads, qk_dim, v_dim) per attention layer."""
    if cfg.mla is not None:
        m = cfg.mla
        return cfg.n_heads, m.nope_head_dim + m.rope_head_dim, m.v_head_dim
    return cfg.n_heads, cfg.head_dim, cfg.head_dim


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // max(cfg.hybrid_attn_every, 1)
    if cfg.family == "encdec":
        return cfg.n_layers * 2 + cfg.n_enc_layers   # self + cross + enc
    return cfg.n_layers


@dataclass
class AnalyticCost:
    flops_total: float          # executed FLOPs for the whole step, all chips
    hbm_bytes_per_device: float
    model_flops: float          # useful 6·N_active·D (or fwd equivalents)


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig, *, n_devices: int,
                  dp: int = 1, tp: int = 1, zero_deg: int = 1,
                  remat: bool = True, window: int = 0) -> AnalyticCost:
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.active_param_count()
    n_params = cfg.param_count()
    H, dqk, dv = _attn_dims(cfg)
    La = _n_attn_layers(cfg)
    Ls = cfg.n_layers if cfg.family in ("ssm", "hybrid") else 0
    ds = cfg.ssm.d_state if cfg.ssm else 0
    di = (cfg.ssm.expand * cfg.d_model) if cfg.ssm else 0
    d = cfg.d_model
    dp = max(dp, 1)
    tp = max(tp, 1)

    def act_traffic(tokens: float, passes: float) -> float:
        """Residual stream (batch-sharded) + tp-sharded hidden streams."""
        d_ff_eff = cfg.d_ff
        if cfg.family == "moe" and cfg.moe:
            eff = cfg.moe.expert_d_ff or cfg.d_ff
            d_ff_eff = eff * (cfg.moe.top_k + cfg.moe.n_shared_experts)
        per_layer = d * 2 * 6 + (2 * d_ff_eff + H * (dqk + dv)) / tp * 2 * 2
        return tokens / dp * per_layer * cfg.n_layers * passes / 2

    if shape.kind == "train":
        tokens = B * S
        mult = 8.0 if remat else 6.0         # fwd+bwd(+remat fwd)
        param_flops = mult / 6.0 * 6.0 * n_active * tokens
        # chunked attention computes full (non-causal-skipped) blocks:
        attn_flops = (4.0 if remat else 3.0) * 2 * B * S * S * H \
            * (dqk + dv) / 2 * La
        ssm_flops = (4.0 if remat else 3.0) * 8 * B * S * di * ds * Ls
        flops = param_flops + attn_flops + ssm_flops
        model_flops = 6.0 * n_active * tokens
        param_traffic = n_params / tp * 2 * 3      # bf16, fwd+bwd+remat
        opt_traffic = n_params / max(zero_deg * tp, 1) * 24  # fp32 m,v rw + g
        hbm = param_traffic + opt_traffic + act_traffic(tokens, 3 if remat
                                                        else 2)
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens \
            + 2 * B * S * S * H * (dqk + dv) / 2 * La \
            + 2 * B * S * di * ds * Ls
        model_flops = 2.0 * n_active * tokens
        hbm = n_params / tp * 2 + act_traffic(tokens, 1)
    else:  # decode: ONE token, cache length = min(S, window or S)
        cache_len = min(S, window) if window else S
        flops = 2.0 * n_active * B \
            + 2 * B * cache_len * H * (dqk + dv) * La \
            + 8 * B * di * ds * Ls
        model_flops = 2.0 * n_active * B
        if cfg.mla is not None:
            kv_row = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
            layers_cached = cfg.n_layers
        else:
            kv_row = 2 * cfg.n_kv_heads * cfg.head_dim
            layers_cached = La if cfg.family != "encdec" else cfg.n_layers
        # decode caches are sharded over batch (dp) AND cache-seq (tp)
        cache_local = B * cache_len * kv_row * layers_cached * 2 \
            / (dp * tp)
        state_local = B * di * ds * Ls * 4 / dp
        hbm = n_params / tp * 2 + cache_local + state_local
    return AnalyticCost(flops_total=float(flops),
                        hbm_bytes_per_device=float(hbm),
                        model_flops=float(model_flops))


def placement_degrees(plan, topo, placement, global_batch: int, *,
                      model: int = 1) -> Tuple[int, int, int]:
    """(dp, tp, zero_deg) for a plan *placed on topology sites* — the
    device-free twin of ``plan_degrees`` for ``core.search`` candidates:
    degrees come from the (pod, data, model) shape the placement's sites
    map to (launch/mesh.topology_mesh_spec), so the analytic roofline can
    price a searched plan before any mesh exists.  The placement's
    ``stage_order``/``stage_layers`` do not change the degrees (they
    permute pod blocks and re-slice — pad-and-mask at runtime — the
    layer stack, not the axis sizes), so any ``core.plans.Placement``
    is accepted as-is.  Extended-pool winners price the same way:
    ``shard_zero``/``fsdp`` placements get their ZeRO degree from the
    pod×data pool of the selected sites (docs/cost-model.md)."""
    from repro.launch.mesh import topology_mesh_spec
    (pod, data, m), _ = topology_mesh_spec(topo, placement.sites,
                                           model=model)
    sizes = {"pod": pod, "data": data, "model": m}
    cand = ("pod", "data") if (plan.shards_weights or plan.pipeline) \
        else ("pod", "data", "model")
    dp = 1
    for a in cand:
        if global_batch > 0 and global_batch % (dp * sizes[a]) == 0:
            dp *= sizes[a]
    tp = m if (plan.shards_weights or plan.pipeline) else 1
    zdeg = pod * data if plan.zero_sharding else 1
    return max(dp, 1), max(tp, 1), max(zdeg, 1)


def plan_degrees(plan, mesh, global_batch: int) -> Tuple[int, int, int]:
    """(dp, tp, zero_deg) for a plan on a mesh."""
    axes = plan.batch_axes(mesh, global_batch)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1) if (plan.shards_weights or plan.pipeline) \
        else 1
    zdeg = 1
    if plan.zero_sharding:
        for a in plan.mesh_axes(mesh)["data"]:
            zdeg *= mesh.shape[a]
    return max(dp, 1), max(tp, 1), max(zdeg, 1)