"""Cross-plan reshard / chaos-recovery parity check (subprocess JSON
checker, used by tests/test_reshard.py and benchmarks/chaos_bench.py).

Two modes, one JSON report on stdout:

  * **place** (default): train a few steps under a SOURCE plan on a line
    topology of single-GPU sites, checkpoint, then ``reshard_checkpoint``
    onto a DESTINATION (plan x placement x stage_layers) layout.  Checks
    (docs/elasticity.md):
      - every resharded leaf — params AND AdamW moments — is bit-exact
        against the host-side reference re-placement
        (``repro.train.reshard.reshard_state``);
      - one further train step under the destination from the resharded
        state produces exactly the loss of a control that restored the
        same checkpoint without the reshard machinery;
      - the source plan's own continuation loss is reported for
        cross-plan comparison.

        PYTHONPATH=src python -m repro.launch.reshard_check \\
            --src-plan zero2 --src-sites 0,1 --dst-plan fsdp --dst-sites 0

  * **chaos** (``--chaos``): the pinned recovery gate — a two-site
    Pipeshard run is killed mid-epoch (``kill_site_at``), replanned onto
    the survivor, resharded, resumed.  Checks the resharded optimizer
    state is bit-exact vs the host reference AND the post-recovery loss
    sequence matches a single-site control started from the same
    checkpoint exactly.

Must run in its own process: ``--devices``/site count forces the XLA
host platform device count, which locks at first jax init.  Pipeline
meshes here are fully manual (stage, 1, 1), so this runs even on
jax 0.4.x (repro.compat.NATIVE_SHARD_MAP).
"""
import argparse
import json
import os
import tempfile


def _sites(spec: str):
    return tuple(int(x) for x in spec.split(",") if x.strip() != "")


def _split(spec):
    return None if not spec else tuple(int(x) for x in spec.split(","))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2m")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=2,
                    help="source-run steps before the checkpoint")
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    # place mode
    ap.add_argument("--src-plan", default="zero2")
    ap.add_argument("--src-sites", default="0,1")
    ap.add_argument("--src-order", default="")
    ap.add_argument("--src-layers", default="",
                    help="source stage_layers, e.g. 2,2 (pipeline only)")
    ap.add_argument("--src-schedule", default="gpipe")
    ap.add_argument("--dst-plan", default="fsdp")
    ap.add_argument("--dst-sites", default="0")
    ap.add_argument("--dst-order", default="")
    ap.add_argument("--dst-layers", default="")
    ap.add_argument("--dst-schedule", default="gpipe")
    # chaos mode
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--kill-step", type=int, default=3)
    ap.add_argument("--dead", default="1")
    ap.add_argument("--total-steps", type=int, default=6)
    ap.add_argument("--ckpt-every", type=int, default=2)
    args = ap.parse_args()

    src_sites, dst_sites = _sites(args.src_sites), _sites(args.dst_sites)
    n_sites = max([2] + [s + 1 for s in src_sites + dst_sites])
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_sites} "
        + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.topology import Link, Site, line
    from repro.data import Loader, Tokenizer, build_dataset, \
        synthetic_wikipedia
    from repro.models import Model

    texts = list(synthetic_wikipedia(60, seed=args.seed))
    tok = Tokenizer.train(texts, 256)
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              n_layers=args.layers,
                              vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=args.seq)
    loader = Loader(ds, global_batch=args.batch, seed=args.seed)
    model = Model(cfg)
    topo = line("elastic-line",
                [Site(("A30",), name=f"V{i + 1}") for i in range(n_sites)],
                [Link(20e-3, 3.0)] * (n_sites - 1))

    def leaves_equal(a, b):
        fa = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(a)]
        fb = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(b)]
        exact = all(x.dtype == y.dtype and np.array_equal(x, y)
                    for x, y in zip(fa, fb))
        diff = max((float(np.max(np.abs(
            x.astype(np.float64) - y.astype(np.float64))))
            if x.size else 0.0) for x, y in zip(fa, fb))
        return exact, diff

    if args.chaos:
        report = run_chaos(args, model, topo, loader, leaves_equal)
    else:
        report = run_place(args, model, topo, loader, leaves_equal,
                           src_sites, dst_sites)
    print(json.dumps(report))


def run_place(args, model, topo, loader, leaves_equal, src_sites,
              dst_sites):
    import jax

    from repro.configs.base import TrainConfig
    from repro.core.plans import Placement, get_plan
    from repro.launch.mesh import placement_mesh
    from repro.train import (reshard_checkpoint, reshard_state,
                             restore_checkpoint, train)
    from repro.train.reshard import state_templates

    def _place(sites, order, layers, schedule):
        return Placement(sites, _sites(order) if order else None,
                         _split(layers), schedule=schedule)

    src_plan = get_plan(args.src_plan)
    dst_plan = get_plan(args.dst_plan)
    src_place = _place(src_sites, args.src_order, args.src_layers,
                       args.src_schedule)
    dst_place = _place(dst_sites, args.dst_order, args.dst_layers,
                       args.dst_schedule)
    # one device per single-GPU site: device block k <-> placement.sites[k]
    devs = list(jax.devices())
    src_mesh = placement_mesh(topo, src_plan, src_place,
                              devices=[devs[i] for i in src_place.sites])
    dst_mesh = placement_mesh(topo, dst_plan, dst_place,
                              devices=[devs[i] for i in dst_place.sites])
    k = args.steps
    tcfg = TrainConfig(warmup_steps=1, total_steps=k + 1, seed=args.seed,
                       microbatches=args.micro)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        src_res = train(model, src_plan, src_mesh, tcfg, loader, steps=k,
                        log_every=0, ckpt_dir=ckpt_dir,
                        stage_layers=src_place.stage_layers,
                        schedule=src_place.schedule)
        ckpt = os.path.join(ckpt_dir, f"step_{k:08d}")

        # resharded restore vs host-side reference re-placement
        params_r, opt_r, step0 = reshard_checkpoint(
            ckpt, model, dst_plan, dst_mesh, placement=dst_place)
        p_like, o_like = state_templates(model)
        params_h, opt_h, _ = restore_checkpoint(ckpt, p_like, o_like)
        with jax.set_mesh(dst_mesh):
            params_ref, opt_ref = reshard_state(
                params_h, opt_h, dst_plan, model.cfg, dst_mesh)
        p_exact, p_diff = leaves_equal(params_r, params_ref)
        o_exact, o_diff = leaves_equal(opt_r, opt_ref)
        h_exact, _ = leaves_equal(params_r, params_h)

        # one further step under dst: resharded vs unresharded control.
        # Each train() donates its state buffers, and when src and dst
        # shardings coincide (e.g. a pure stage-order change) device_put
        # aliases the restored arrays — so every reuse gets a fresh
        # host copy.
        import numpy as np

        def host_copy(tree):
            return jax.tree.map(lambda x: np.array(x), tree)

        def one_step(params, opt):
            res = train(model, dst_plan, dst_mesh, tcfg, loader,
                        steps=k + 1, start_step=k, params=params,
                        opt_state=opt, log_every=0,
                        stage_layers=dst_place.stage_layers,
                        schedule=dst_place.schedule)
            return res.losses

        loss_resharded = one_step(params_r, opt_r)
        loss_control = one_step(host_copy(params_h),
                                host_copy(opt_h))
        # the source plan's own continuation (cross-plan comparison)
        src_cont = train(model, src_plan, src_mesh, tcfg, loader,
                         steps=k + 1, start_step=k,
                         params=host_copy(params_h),
                         opt_state=host_copy(opt_h), log_every=0,
                         stage_layers=src_place.stage_layers,
                         schedule=src_place.schedule)
    return {
        "mode": "place", "step": step0,
        "src": f"{args.src_plan}@{src_sites}",
        "dst": f"{args.dst_plan}@{dst_sites}",
        "params_bitexact": p_exact, "opt_bitexact": o_exact,
        "host_bitexact": h_exact,
        "max_param_diff": p_diff, "max_opt_diff": o_diff,
        "loss_resharded": loss_resharded, "loss_control": loss_control,
        "loss_src_continue": src_cont.losses,
        "src_losses": src_res.losses,
    }


def run_chaos(args, model, topo, loader, leaves_equal):
    import jax

    from repro.configs.base import TrainConfig
    from repro.core.plans import Placement, get_plan
    from repro.launch.mesh import placement_mesh
    from repro.train import (kill_site_at, reshard_checkpoint,
                             reshard_state, restore_checkpoint, train,
                             train_elastic)
    from repro.train.replan import placement_devices, site_device_blocks
    from repro.train.reshard import state_templates

    dead = _sites(args.dead)
    total = args.total_steps
    tcfg = TrainConfig(warmup_steps=1, total_steps=total, seed=args.seed,
                       microbatches=args.micro)
    placement = Placement((0, 1))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        run = train_elastic(
            model, topo, "pipeshard", placement, tcfg, loader,
            steps=total, ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every,
            on_step_failure=kill_site_at(args.kill_step, dead),
            log_every=0, log_fn=lambda s: None)
        rp = run.replan
        ckpt = os.path.join(ckpt_dir, f"step_{run.resumed_from:08d}")
        plan_c = get_plan(rp.technique)
        blocks = site_device_blocks(topo)
        mesh_c = placement_mesh(rp.topology, plan_c, rp.placement,
                                devices=placement_devices(
                                    blocks, rp.sites_old))
        # bit-exactness of the resharded state vs the host reference
        params_r, opt_r, _ = reshard_checkpoint(
            ckpt, model, plan_c, mesh_c, placement=rp.placement)
        p_like, o_like = state_templates(model)
        params_h, opt_h, _ = restore_checkpoint(ckpt, p_like, o_like)
        with jax.set_mesh(mesh_c):
            params_ref, opt_ref = reshard_state(
                params_h, opt_h, plan_c, model.cfg, mesh_c)
        p_exact, p_diff = leaves_equal(params_r, params_ref)
        o_exact, o_diff = leaves_equal(opt_r, opt_ref)
        # single-site control from the same checkpoint: the post-recovery
        # loss sequence must match it exactly
        control = train(model, plan_c, mesh_c, tcfg, loader, steps=total,
                        start_step=run.resumed_from, params=params_h,
                        opt_state=opt_h, log_every=0,
                        stage_layers=rp.placement.stage_layers,
                        schedule=rp.placement.schedule)
    return {
        "mode": "chaos", "failed": run.failed,
        "kill_step": args.kill_step, "dead": list(dead),
        "technique": rp.technique, "sites_old": list(rp.sites_old),
        "resumed_from": run.resumed_from, "steps_lost": run.steps_lost,
        "params_bitexact": p_exact, "opt_bitexact": o_exact,
        "max_param_diff": p_diff, "max_opt_diff": o_diff,
        "losses_pre": run.pre.losses, "losses_post": run.result.losses,
        "losses_control": control.losses,
        "search_s": run.search_s, "reshard_s": run.reshard_s,
        "recovery_s": run.recovery_s,
    }


if __name__ == "__main__":
    main()
