"""Elastic re-planning launcher: recover a run after sites die.

Two modes (docs/elasticity.md):

  * recovery (default): an existing checkpoint + a degraded topology —
    re-run the plan search over the survivors, reshard the checkpoint
    onto the winner, resume to --steps:

        PYTHONPATH=src python -m repro.launch.replan \\
            --ckpt-dir /tmp/run --gpus "A30,A30;T4,T4" --dead 1 \\
            --arch gpt2 --reduced --devices 4 --steps 20

  * chaos demo (--kill-step K): self-contained end-to-end drill — train
    from scratch on the full topology, kill --dead at step K through the
    injection hook, replan, reshard, resume.  What
    ``benchmarks/chaos_bench.py`` runs as a subprocess.

The last stdout line is a JSON summary (technique, surviving sites,
steps lost, recovery seconds) for scripted consumers.
"""
import argparse
import json
import os


def parse_gpus(spec: str):
    """``"A30,A30;T4,T4"`` -> per-site GPU tuples (';' between sites)."""
    sites = [tuple(g.strip() for g in s.split(",") if g.strip())
             for s in spec.split(";") if s.strip()]
    if not sites:
        raise ValueError(f"empty --gpus spec {spec!r}")
    return sites


def build_cli_topology(kind: str, gpus: str, lat_ms: float,
                       wan_gbps: float):
    """An N-site topology from CLI args (full / ring / line / hub)."""
    from repro.core.topology import (Link, Site, fully_connected, hub,
                                     line, ring)
    site_gpus = parse_gpus(gpus)
    sites = [Site(g, name=f"V{i + 1}") for i, g in enumerate(site_gpus)]
    edge = Link(lat_ms * 1e-3, wan_gbps)
    name = f"{kind}{len(sites)}"
    if kind == "full":
        return fully_connected(name, sites, edge)
    if kind == "ring":
        return ring(name, sites, [edge] * len(sites))
    if kind == "line":
        return line(name, sites, [edge] * (len(sites) - 1))
    if kind == "hub":
        return hub(name, sites[0], sites[1:], edge)
    raise ValueError(f"unknown --kind {kind!r}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--gpus", default="A30,A30;T4,T4",
                    help="per-site GPUs: ';' between sites, ',' within")
    ap.add_argument("--kind", default="full",
                    choices=("full", "ring", "line", "hub"))
    ap.add_argument("--latency-ms", type=float, default=20.2)
    ap.add_argument("--wan-gbps", type=float, default=3.0)
    ap.add_argument("--dead", default="1",
                    help="comma-separated dead site indices (0-based)")
    ap.add_argument("--kill-step", type=int, default=-1,
                    help=">= 0: chaos-demo mode — train from scratch and "
                         "inject the failure at this step")
    ap.add_argument("--plan", default="auto",
                    help="initial plan for the chaos demo ('auto' = "
                         "search the full topology)")
    ap.add_argument("--arch", default="gpt2m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use real devices)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--docs", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import dataclasses
    import time

    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.core.costmodel import Workload
    from repro.core.plans import Placement, get_plan
    from repro.core.search import PlanSearch
    from repro.data import Loader, Tokenizer, build_dataset, \
        synthetic_wikipedia
    from repro.launch.mesh import placement_mesh
    from repro.models import Model
    from repro.train import (kill_site_at, latest_checkpoint, replan,
                             reshard_checkpoint, train, train_elastic)
    from repro.train.replan import placement_devices, site_device_blocks

    topo = build_cli_topology(args.kind, args.gpus, args.latency_ms,
                              args.wan_gbps)
    dead = tuple(int(x) for x in args.dead.split(",") if x.strip())

    texts = list(synthetic_wikipedia(args.docs, seed=args.seed))
    tok = Tokenizer.train(texts, args.vocab)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                              max_seq_len=max(cfg.max_seq_len, args.seq))
    ds = build_dataset(texts, tok, seq_len=args.seq)
    loader = Loader(ds, global_batch=args.batch, seed=args.seed)
    tcfg = TrainConfig(warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, seed=args.seed,
                       microbatches=args.microbatches)
    model = Model(cfg)
    wl = Workload(cfg, args.seq, args.batch, steps_per_epoch=args.steps,
                  microbatches=args.microbatches)

    print(f"{cfg.name} {cfg.param_count() / 1e6:.1f}M params on "
          f"{topo.name}: {topo.describe()}")

    if args.kill_step >= 0:
        # chaos-demo mode: full run with an injected failure
        if args.plan == "auto":
            search = PlanSearch(wl, topo, stage_balance="tflops")
            top = search.best()
            if top is None:
                raise SystemExit("no feasible plan on the full topology")
            technique = top.candidate.technique
            placement = search.placement(top.candidate)
        else:
            technique = args.plan
            placement = Placement(tuple(range(topo.n_sites)))
        print(f"initial plan: {technique}@{placement.sites}")
        run = train_elastic(
            model, topo, technique, placement, tcfg, loader,
            steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            on_step_failure=kill_site_at(args.kill_step, dead))
        summary = {
            "mode": "chaos", "failed": run.failed,
            "technique": run.replan.technique if run.replan else technique,
            "sites_old": list(run.replan.sites_old) if run.replan
            else list(placement.sites),
            "resumed_from": run.resumed_from,
            "steps_lost": run.steps_lost,
            "search_s": run.search_s, "reshard_s": run.reshard_s,
            "recovery_s": run.recovery_s,
            "final_loss": run.result.losses[-1] if run.result.losses
            else None,
        }
    else:
        # recovery mode: resume an existing checkpoint on the survivors
        ckpt = latest_checkpoint(args.ckpt_dir)
        if ckpt is None:
            raise SystemExit(f"no complete checkpoint in {args.ckpt_dir}")
        t0 = time.perf_counter()
        rp = replan(topo, dead, wl)
        blocks = site_device_blocks(topo)
        plan2 = get_plan(rp.technique)
        mesh2 = placement_mesh(rp.topology, plan2, rp.placement,
                               devices=placement_devices(
                                   blocks, rp.sites_old))
        t1 = time.perf_counter()
        params, opt, step0 = reshard_checkpoint(
            ckpt, model, plan2, mesh2, placement=rp.placement)
        reshard_s = time.perf_counter() - t1
        print(f"replanned: {rp.technique} on original sites "
              f"{rp.sites_old} ({rp.tflops:.2f} model-TFLOP/s); "
              f"resuming at step {step0}")
        res = train(model, plan2, mesh2, tcfg, loader, steps=args.steps,
                    start_step=step0, params=params, opt_state=opt,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    stage_layers=rp.placement.stage_layers,
                    schedule=rp.placement.schedule,
                    log_every=max(args.steps // 10, 1))
        summary = {
            "mode": "recovery", "technique": rp.technique,
            "sites_old": list(rp.sites_old), "resumed_from": step0,
            "search_s": rp.search_s, "reshard_s": reshard_s,
            "recovery_s": time.perf_counter() - t0,
            "final_loss": res.losses[-1] if res.losses else None,
        }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
