"""CLI driver: ``python -m repro.analysis`` (see docs/static-analysis.md).

Runs the four passes, matches findings against the checked-in baseline
(``tools/analysis_baseline.json``), prints text or JSON, and exits 1
when any finding is not baselined (stale baseline entries count as
findings too, so the baseline cannot rot).

    PYTHONPATH=src python -m repro.analysis                 # text
    PYTHONPATH=src python -m repro.analysis --format json   # CI mode
    PYTHONPATH=src python -m repro.analysis --passes schedlint,planlint
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import (Baseline, Finding, PASSES, repo_root,
                            run_passes)


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="device-free static analysis of the repro codebase")
    ap.add_argument("--root", default=None,
                    help="repo root (default: the checkout this package "
                         "was imported from)")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)}")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: "
                         "tools/analysis_baseline.json under --root; "
                         "'none' disables)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root or repo_root())
    if args.baseline == "none":
        baseline = Baseline([])
    else:
        baseline = Baseline.load(
            args.baseline
            or os.path.join(root, "tools", "analysis_baseline.json"))

    results = run_passes(root, [p for p in args.passes.split(",") if p])
    all_findings: List[Finding] = [f for r in results for f in r.findings]
    new, accepted, stale = baseline.split(all_findings)
    new += stale

    report = {
        "root": root,
        "passes": {
            r.name: {"findings": len(r.findings), "stats": r.stats}
            for r in results},
        "findings": [
            dict(f.to_dict(), baselined=baseline.match(f) is not None)
            for f in all_findings] + [
            dict(f.to_dict(), baselined=False) for f in stale],
        "summary": {"total": len(all_findings) + len(stale),
                    "new": len(new), "baselined": len(accepted),
                    "stale_baseline": len(stale)},
        "exit_code": 1 if new else 0,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
    if args.format == "json":
        json.dump(report, sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        for r in results:
            stats = " ".join(f"{k}={v}" for k, v in sorted(
                r.stats.items()))
            print(f"[{r.name}] {len(r.findings)} finding(s); {stats}")
        for f in new:
            print(f.render())
        for f in accepted:
            e = baseline.match(f)
            print(f"{f.render()}  (baselined: {e['justification']})")
        s = report["summary"]
        print(f"{s['total']} finding(s): {s['new']} new, "
              f"{s['baselined']} baselined, {s['stale_baseline']} stale "
              f"baseline entr{'y' if s['stale_baseline'] == 1 else 'ies'}")
    return report["exit_code"]


if __name__ == "__main__":
    sys.exit(main())
