"""Convention lint: repo invariants the other passes don't own.

  * CONV001 — unit-suffix discipline in ``core/costmodel.py`` and the
    ``repro.calib`` calibration stack (overlay / fit / microbench).  The
    cost model's names carry units (``latency_s``, ``bytes_total``,
    ``mem_gb``, ``effective_gbps``); adding or subtracting two
    quantities with *different* known units without a conversion is a
    sign error waiting to happen.  A small abstract interpreter infers
    a unit for every expression: suffixed names are their unit,
    multiplying by a unitless factor keeps the unit, and any division
    or unit x unit product counts as a conversion (result unknown) —
    only an Add/Sub of two *known, different* units is flagged, so
    ``bytes / gbps + latency_s`` stays legal and ``bytes + latency_s``
    does not.
  * CONV002 — overbroad ``except`` that swallows: a bare /
    ``Exception`` / ``BaseException`` handler that never re-raises and
    just passes or returns ``None`` (the PR-3 probe bug class, where a
    swallowed error was indistinguishable from an infeasible plan).
    Handlers that re-raise, or that report and continue, are fine.
  * CONV003 — registry reachability: every ``TECHNIQUE_SPECS`` key must
    appear in the docs (README/DESIGN/docs/*.md) and in the test suite;
    an undocumented or untested technique is unreachable to users.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Tuple

from repro.analysis import Finding, PassResult

#: name suffix -> unit token (also ``bytes_*`` prefixes, see _unit_of)
UNIT_SUFFIXES = {"_s": "s", "_ms": "ms", "_bytes": "bytes", "_gb": "gb",
                 "_gbps": "gbps", "_tflops": "tflops"}
NONE, UNKNOWN = "", "?"

#: files under the CONV001 unit-algebra lint: the cost model and the
#: calibration stack that prices against it (overlay rates, fitter
#: design rows, micro-bench timings — all carry unit-suffixed names),
#: plus the serving placement pass built on those prices
_COST_RELS = (
    os.path.join("src", "repro", "core", "costmodel.py"),
    os.path.join("src", "repro", "calib", "overlay.py"),
    os.path.join("src", "repro", "calib", "fit.py"),
    os.path.join("src", "repro", "calib", "microbench.py"),
    os.path.join("src", "repro", "serve", "placement.py"),
)


def _unit_of_name(name: str) -> str:
    for suf, unit in UNIT_SUFFIXES.items():
        if name.endswith(suf):
            return unit
    if name.startswith("bytes_") or name == "bytes":
        return "bytes"
    return NONE


def _expr_unit(node: ast.AST, problems: List[Tuple[int, str]]) -> str:
    """Unit of an expression: '' unitless, '?' unknown/converted, or a
    unit token.  Appends (lineno, message) for mixed Add/Sub."""
    if isinstance(node, ast.Constant):
        return NONE
    if isinstance(node, ast.Name):
        return _unit_of_name(node.id)
    if isinstance(node, ast.Attribute):
        return _unit_of_name(node.attr)
    if isinstance(node, ast.UnaryOp):
        return _expr_unit(node.operand, problems)
    if isinstance(node, ast.BinOp):
        lu = _expr_unit(node.left, problems)
        ru = _expr_unit(node.right, problems)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if lu not in (NONE, UNKNOWN) and ru not in (NONE, UNKNOWN) \
                    and lu != ru:
                op = "+" if isinstance(node.op, ast.Add) else "-"
                problems.append((
                    node.lineno,
                    f"mixes units: [{lu}] {op} [{ru}] without a "
                    f"conversion"))
                return UNKNOWN
            if lu == ru:
                return lu
            return lu if ru == NONE else ru if lu == NONE else UNKNOWN
        if isinstance(node.op, ast.Mult):
            if lu == NONE:
                return ru
            if ru == NONE:
                return lu
            return UNKNOWN               # unit x unit: a conversion
        # Div / Pow / Mod / FloorDiv: always a conversion
        if lu == NONE and ru == NONE:
            return NONE
        return UNKNOWN
    if isinstance(node, (ast.Call, ast.Subscript, ast.IfExp)):
        return UNKNOWN
    return UNKNOWN


def check_units(tree: ast.AST) -> List[Tuple[int, str]]:
    """CONV001 core: all mixed-unit Add/Sub sites in a module AST."""
    problems: List[Tuple[int, str]] = []
    seen = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and id(node) not in seen:
            for sub in ast.walk(node):
                seen.add(id(sub))
            _expr_unit(node, problems)
    return problems


_BROAD = ("Exception", "BaseException")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    if isinstance(t, ast.Name):
        return t.id in _BROAD
    if isinstance(t, ast.Attribute):
        return t.attr in _BROAD
    if isinstance(t, ast.Tuple):
        return any(_is_broad(ast.ExceptHandler(type=e))
                   for e in t.elts)
    return False


def _swallows(handler: ast.ExceptHandler) -> Optional[str]:
    """Why this handler swallows, or None if it doesn't."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return None
    body = handler.body
    if all(isinstance(s, ast.Pass) for s in body):
        return "the handler is just `pass`"
    for node in ast.walk(handler):
        if isinstance(node, ast.Return):
            if node.value is None or (
                    isinstance(node.value, ast.Constant)
                    and node.value.value is None):
                return "the handler returns None"
    return None


def check_excepts(tree: ast.AST) -> List[Tuple[int, str]]:
    """CONV002 core: swallowing broad handlers in a module AST."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and _is_broad(node):
            why = _swallows(node)
            if why:
                name = "bare except" if node.type is None else \
                    "except " + ast.dump(node.type) if not isinstance(
                        node.type, ast.Name) else f"except {node.type.id}"
                out.append((node.lineno,
                            f"{name} swallows the error: {why} — an "
                            f"error becomes indistinguishable from a "
                            f"legitimate None"))
    return out


def _iter_py(root: str, rel_dir: str):
    base = os.path.join(root, rel_dir)
    for dirpath, _, files in os.walk(base):
        for fn in sorted(files):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                yield path, os.path.relpath(path, root).replace(
                    os.sep, "/")


def check_reachability(root: str) -> List[Finding]:
    """CONV003: every registered technique appears in docs and tests."""
    from repro.core.costmodel import TECHNIQUE_SPECS
    doc_files = [os.path.join(root, "README.md"),
                 os.path.join(root, "DESIGN.md")]
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        doc_files += [os.path.join(docs_dir, f)
                      for f in sorted(os.listdir(docs_dir))
                      if f.endswith(".md")]
    doc_text = ""
    for p in doc_files:
        if os.path.exists(p):
            with open(p) as f:
                doc_text += f.read()
    test_text = ""
    for path, _ in _iter_py(root, "tests"):
        with open(path) as f:
            test_text += f.read()
    out = []
    for tech in sorted(TECHNIQUE_SPECS):
        missing = [w for w, text in (("docs", doc_text),
                                     ("tests", test_text))
                   if tech not in text]
        if missing:
            out.append(Finding(
                "CONV003", "error", "src/repro/core/costmodel.py", 1,
                f"technique {tech!r} is registered but unreachable "
                f"from {' and '.join(missing)}"))
    return out


def run(root: str) -> PassResult:
    res = PassResult("conventions")
    # CONV001: the unit algebra of the cost model + calibration stack
    n_exprs = 0
    for rel in _COST_RELS:
        cost_path = os.path.join(root, rel)
        if not os.path.exists(cost_path):
            continue
        with open(cost_path) as f:
            tree = ast.parse(f.read(), filename=cost_path)
        n_exprs += sum(isinstance(n, ast.BinOp) for n in ast.walk(tree))
        for lineno, msg in check_units(tree):
            res.findings.append(Finding(
                "CONV001", "error", rel.replace(os.sep, "/"),
                lineno, msg))
    # CONV002: swallowing handlers anywhere in src/
    n_handlers = 0
    for path, rel in _iter_py(root, "src"):
        with open(path) as f:
            try:
                tree = ast.parse(f.read(), filename=path)
            except SyntaxError:
                continue
        n_handlers += sum(isinstance(n, ast.ExceptHandler)
                          for n in ast.walk(tree))
        for lineno, msg in check_excepts(tree):
            res.findings.append(Finding("CONV002", "error", rel,
                                        lineno, msg))
    res.findings.extend(check_reachability(root))
    res.stats = {"binops_checked": n_exprs,
                 "handlers_checked": n_handlers,
                 "techniques_checked": len(
                     __import__("repro.core.costmodel",
                                fromlist=["TECHNIQUE_SPECS"])
                     .TECHNIQUE_SPECS)}
    return res
