"""Schedule race detector: exhaustive dependency-soundness check of
``core.pipeline.schedule_tables`` over a (schedule x S x m x v) grid.

The scheduled pipeline runner executes the tables literally: at tick t
every stage runs (or idles) the forward slot ``active/chunk/mb[s, t]``
says, and consumes whatever its ring predecessor's ppermute delivered
at the start of the tick (``arr_*[s, t]``).  The tables are therefore a
complete static description of the dataflow, and every race the runner
could hit is decidable by walking them:

  * SCHED001 — completeness: each of the ``S*v*m`` work items runs
    exactly once, so warm-up and drain cover every microbatch and the
    last stage banks all ``m`` final-chunk outputs.
  * SCHED002 — slot validity: chunk in ``[0, v)``, microbatch in
    ``[0, m)`` on every active slot (an array slot can only hold one
    item, so "two chunks in one tick" surfaces as a SCHED001 miss).
  * SCHED003 — dependency soundness: every consume (chunk c > 0) has a
    matching arrival at or before its tick, whose producer ran
    *strictly earlier*; the arrival is unique up to consumption (no
    inbox clobber).
  * SCHED004 — send/receive pairing: every valid arrival maps back to a
    real, non-banked predecessor slot with the ring chunk-increment
    applied (``banked_slot`` is the single source of truth); every
    non-banked send lands as a valid arrival one tick later (nothing
    falls off the end of the table).
  * SCHED005 — tick-count formulas: GPipe ``T == m+S-1``, 1F1B
    ``T == 2m+S-2``, interleaved ``T >= m-1 + S*v`` (ring critical
    path).

``check_tables`` is pure (tables in, problems out) so tests can feed it
deliberately corrupted tables; ``run`` sweeps the acceptance grid
S in 1..4, m in 1..8, v in 1..3.
"""
from __future__ import annotations

import inspect
from typing import Dict, List, Tuple

import numpy as np

from repro.analysis import Finding, PassResult
from repro.core.pipeline import banked_slot, schedule_tables
from repro.core.costmodel import parse_schedule

#: the verified-for-all-small-sizes guarantee (ISSUE 8 acceptance grid).
GRID_SCHEDULES = ("gpipe", "1f1b", "interleaved2", "interleaved3")
GRID_S = range(1, 5)
GRID_M = range(1, 9)


def check_tables(tables: Dict[str, np.ndarray], schedule: str,
                 n_stages: int, n_micro: int) -> List[Tuple[str, str]]:
    """Verify one table set; returns (rule, problem) pairs, [] if sound."""
    kind, virt = parse_schedule(schedule)
    S, m = n_stages, n_micro
    active, chunk, mb = tables["active"], tables["chunk"], tables["mb"]
    arr_valid = tables["arr_valid"]
    arr_chunk, arr_mb = tables["arr_chunk"], tables["arr_mb"]
    T = active.shape[1]
    where = f"{schedule} S={S} m={m}"
    problems: List[Tuple[str, str]] = []

    def bad(rule: str, msg: str) -> None:
        problems.append((rule, f"{where}: {msg}"))

    # SCHED002 + SCHED001: every item (global chunk c, microbatch i)
    # runs exactly once, on the stage the ring assigns it.
    runs: Dict[Tuple[int, int], Tuple[int, int]] = {}
    for s in range(S):
        for t in range(T):
            if not active[s, t]:
                continue
            k, i = int(chunk[s, t]), int(mb[s, t])
            if not (0 <= k < virt and 0 <= i < m):
                bad("SCHED002", f"stage {s} tick {t} runs out-of-range "
                                f"slot (chunk {k}, mb {i})")
                continue
            c = k * S + s
            if (c, i) in runs:
                bad("SCHED001", f"item (chunk {c}, mb {i}) runs twice: "
                                f"stage/tick {runs[(c, i)]} and ({s}, {t})")
            runs[(c, i)] = (s, t)
    for c in range(S * virt):
        for i in range(m):
            if (c, i) not in runs:
                bad("SCHED001", f"item (chunk {c}, mb {i}) never runs — "
                                f"warm-up/drain incomplete")

    # SCHED004 (receive side): every valid arrival pairs with a real,
    # non-banked send from the ring predecessor one tick earlier.
    for s in range(S):
        prev = (s - 1) % S
        for t in range(T):
            if not arr_valid[s, t]:
                continue
            if t == 0:
                bad("SCHED004", f"stage {s} receives at tick 0 — nothing "
                                f"was sent yet")
                continue
            if not active[prev, t - 1]:
                bad("SCHED004", f"stage {s} tick {t} arrival has no "
                                f"producing slot on stage {prev} at "
                                f"tick {t - 1}")
                continue
            kp, ip = int(chunk[prev, t - 1]), int(mb[prev, t - 1])
            if banked_slot(prev, kp, S, virt):
                bad("SCHED004", f"stage {s} tick {t} arrival claims a "
                                f"banked send (stage {prev} chunk {kp})")
                continue
            k_exp = kp + (1 if prev == S - 1 else 0)
            if int(arr_chunk[s, t]) != k_exp or int(arr_mb[s, t]) != ip:
                bad("SCHED004", f"stage {s} tick {t} arrival labelled "
                                f"(chunk {int(arr_chunk[s, t])}, mb "
                                f"{int(arr_mb[s, t])}) but predecessor "
                                f"sent (chunk {k_exp}, mb {ip})")
    # SCHED004 (send side): every non-banked send lands somewhere.
    for s in range(S):
        nxt = (s + 1) % S
        for t in range(T):
            if not active[s, t] or banked_slot(s, int(chunk[s, t]),
                                               S, virt):
                continue
            if t + 1 >= T or not arr_valid[nxt, t + 1]:
                bad("SCHED004", f"stage {s} tick {t} send of (chunk "
                                f"{int(chunk[s, t])}, mb "
                                f"{int(mb[s, t])}) never received by "
                                f"stage {nxt} — lost at the table edge")

    # SCHED003: every consume has a strictly-earlier matching produce,
    # delivered exactly once before it is consumed.
    for (c, i), (s, t) in sorted(runs.items()):
        if c == 0:
            continue                        # reads the real microbatch
        k = c // S
        arrivals = [ta for ta in range(T)
                    if arr_valid[s, ta] and int(arr_chunk[s, ta]) == k
                    and int(arr_mb[s, ta]) == i]
        early = [ta for ta in arrivals if ta <= t]
        if not early:
            bad("SCHED003", f"item (chunk {c}, mb {i}) consumed at "
                            f"stage {s} tick {t} but its input never "
                            f"arrives by then (race)")
            continue
        if len(early) > 1:
            bad("SCHED004", f"item (chunk {c}, mb {i}) delivered "
                            f"{len(early)} times to stage {s} before "
                            f"its consume at tick {t} — inbox clobber")
        ta = early[0]
        # the arrival at ta was sent at ta-1; receive-side SCHED004
        # already ties it to a real producer slot, so the produce tick
        # is ta-1 <= t-1 < t: strictly earlier by construction.  Guard
        # against the degenerate self-receive anyway.
        if ta - 1 >= t:
            bad("SCHED003", f"item (chunk {c}, mb {i}) produced at tick "
                            f"{ta - 1} but consumed at tick {t}")

    # SCHED005: tick-count formulas / critical-path lower bound.
    if kind == "gpipe" and T != m + S - 1:
        bad("SCHED005", f"gpipe T={T}, expected m+S-1={m + S - 1}")
    elif kind == "1f1b" and T != 2 * m + S - 2:
        bad("SCHED005", f"1f1b T={T}, expected 2m+S-2={2 * m + S - 2}")
    elif T < m - 1 + S * virt:
        bad("SCHED005", f"T={T} beats the ring critical path "
                        f"m-1+S*v={m - 1 + S * virt} — impossible")
    return problems


def run(root: str) -> PassResult:
    res = PassResult("schedlint")
    line = inspect.getsourcelines(schedule_tables)[1]
    cells = items = 0
    for schedule in GRID_SCHEDULES:
        for S in GRID_S:
            for m in GRID_M:
                tables = schedule_tables(schedule, S, m)
                cells += 1
                items += S * parse_schedule(schedule)[1] * m
                for rule, msg in check_tables(tables, schedule, S, m):
                    res.findings.append(Finding(
                        rule, "error", "src/repro/core/pipeline.py",
                        line, msg))
    res.stats = {"cells_checked": cells, "items_verified": items,
                 "schedules": len(GRID_SCHEDULES)}
    return res
