"""Donation-aliasing checker: flags reads of a buffer after it was
passed to a ``jax.jit(..., donate_argnums=...)`` callable — the PR-7
``reshard_check`` bug class, where ``device_put`` aliased a restored
checkpoint into a donating ``train()`` call and the "control" run then
read deleted arrays.

An AST pass over ``src/`` (no imports, no execution) with three layers:

  1. **Donating callables** — ``jax.jit(..., donate_argnums=(i, ...))``
     bindings, and *donating factories*: functions that return such a
     callable (possibly inside a tuple), e.g. ``core.steps
     .build_train_step`` → element 0 donates args (0, 1).  Callers that
     unpack the factory result inherit the donation signature.
  2. **Donating wrappers** — a function that passes one of its own
     formal parameters (or an alias of it — ``y = x`` and
     ``y = jax.device_put(x, ...)`` both alias: ``device_put`` may
     return the input buffer when shardings coincide) into a donated
     position donates that parameter itself.  ``train(... params=...)``
     is the canonical wrapper; its call sites are checked like any
     jitted call.  Promotion iterates to a fixpoint across modules.
  3. **Read-after-donation** — at every donating call, each donated
     argument is resolved to its root bindings; a later load of a root
     that the call's own assignment did not rebind is DON001.  A
     donating call inside a loop whose donated root is never re-stored
     in that loop donates a dead buffer on the second iteration — also
     DON001.  Sanctioned fresh-copy idioms (``np.array`` /
     ``np.asarray`` / ``jnp.copy`` / a ``host_copy`` helper /
     ``copy.deepcopy``) break the alias chain.

Rules: DON001 read-after-donation, DON002 one buffer in both a donated
and a non-donated slot of the same call, DON003 non-literal
``donate_argnums`` (unverifiable — warning).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import Finding, PassResult

#: calls that provably return fresh buffers (alias chain breakers)
FRESH_CALLS = {"array", "asarray", "copy", "deepcopy", "host_copy",
               "zeros_like", "ones_like"}
#: calls that may alias their first argument (the PR-7 lesson)
ALIAS_CALLS = {"device_put"}


@dataclass(frozen=True)
class DonSig:
    """Donation signature of a callable: positional indices and (for
    wrappers, whose signatures we know) parameter names donated."""
    argnums: Tuple[int, ...] = ()
    argnames: Tuple[str, ...] = ()
    params: Tuple[str, ...] = ()    # wrapper formals, for kwarg mapping


@dataclass
class Registry:
    """Cross-module fixpoint state, keyed by qualified function name."""
    #: factory qname -> {return position (None = bare) -> DonSig}
    factories: Dict[str, Dict[Optional[int], DonSig]] = \
        field(default_factory=dict)
    #: wrapper qname -> DonSig (argnames filled, params known)
    wrappers: Dict[str, DonSig] = field(default_factory=dict)
    #: module qual -> _Module, for resolving package re-exports
    modules: Dict[str, "_Module"] = field(default_factory=dict)

    def canon(self, qname: Optional[str]) -> Optional[str]:
        """Follow re-export chains (``repro.train.train`` ->
        ``repro.train.loop.train``) to the defining module."""
        for _ in range(8):
            if qname is None:
                return None
            head, _, tail = qname.rpartition(".")
            mod = self.modules.get(head)
            if mod is None or tail not in mod.import_map \
                    or mod.import_map[tail] == qname:
                return qname
            qname = mod.import_map[tail]
        return qname


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain, e.g. ``self._cache0``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(call: ast.Call) -> Optional[str]:
    """Trailing name of the called expression (``jax.jit`` -> ``jit``)."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _literal_argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            return tuple(e.value for e in v.elts)
        return None                      # present but not a literal
    return ()                            # no donation at all


def _is_jit_call(call: ast.Call) -> bool:
    return _call_name(call) == "jit"


@dataclass
class _Event:
    """One donating call inside a function body."""
    lineno: int
    stmt_idx: int
    callee: str
    roots: Set[str]                      # donated arg roots
    other_roots: Set[str]                # non-donated arg roots
    rebound: Set[str]                    # names the same stmt assigns
    loops: Tuple[int, ...]               # enclosing loop ids


@dataclass
class _Access:
    stmt_idx: int
    lineno: int
    name: str
    kind: str                            # "load" | "store"
    loops: Tuple[int, ...]


class _FuncWalker:
    """Linearizes one function body: alias map, donating-callable
    bindings, donation events, and name accesses in source order."""

    def __init__(self, module: "_Module", reg: Registry,
                 func: ast.FunctionDef):
        self.module, self.reg, self.func = module, reg, func
        self.aliases: Dict[str, str] = {}
        self.donating_vars: Dict[str, DonSig] = {}
        self.events: List[_Event] = []
        self.accesses: List[_Access] = []
        self.non_literal: List[int] = []
        self.idx = 0

    # -- roots ------------------------------------------------------- #
    def _root(self, name: str) -> str:
        seen = set()
        while name in self.aliases and name not in seen:
            seen.add(name)
            name = self.aliases[name]
        return name

    def _expr_roots(self, node: ast.AST) -> Set[str]:
        """Root bindings an argument expression may alias."""
        if isinstance(node, ast.Name):
            return {self._root(node.id)}
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            return {self._root(chain)} if chain else set()
        if isinstance(node, (ast.Tuple, ast.List)):
            out: Set[str] = set()
            for e in node.elts:
                out |= self._expr_roots(e)
            return out
        if isinstance(node, ast.Call):
            cname = _call_name(node)
            if cname in ALIAS_CALLS and node.args:
                return self._expr_roots(node.args[0])
            return set()                 # fresh (or unknown) result
        return set()

    # -- statement walk ---------------------------------------------- #
    def walk(self) -> None:
        self._walk_body(self.func.body, ())

    def _walk_body(self, body: Sequence[ast.stmt],
                   loops: Tuple[int, ...]) -> None:
        for stmt in body:
            self.idx += 1
            self._statement(stmt, loops)
            for child_body, child_loops in _sub_bodies(stmt, loops):
                self._walk_body(child_body, child_loops)

    def _statement(self, stmt: ast.stmt, loops: Tuple[int, ...]) -> None:
        idx = self.idx
        targets = _target_names(stmt)
        # only the statement's own expressions: bodies of compound
        # statements are walked (and indexed) by _walk_body, so a
        # try/for header must not pre-record its children's loads
        exprs = _own_exprs(stmt)
        # donation events before bindings: the call reads old state
        for e in exprs:
            for call in _calls_in(e):
                self._maybe_event(call, idx, targets, loops)
        self._bindings(stmt, targets)
        self._record_accesses(exprs, stmt, idx, targets, loops)

    def _record_accesses(self, exprs, stmt: ast.stmt, idx: int,
                         targets: Set[str],
                         loops: Tuple[int, ...]) -> None:
        own = set()
        for e in exprs:
            for node in ast.walk(e):
                if isinstance(node, ast.Lambda):
                    continue
                name = None
                if isinstance(node, ast.Attribute):
                    name = _attr_chain(node)
                elif isinstance(node, ast.Name):
                    name = node.id
                if name is None or name in own:
                    continue
                own.add(name)
                kind = "store" if name in targets else "load"
                self.accesses.append(_Access(
                    idx, getattr(node, "lineno", stmt.lineno),
                    self._root(name), kind, loops))
        for t in targets:
            if t not in own:
                self.accesses.append(_Access(
                    idx, stmt.lineno, t, "store", loops))

    def _bindings(self, stmt: ast.stmt, targets: Set[str]) -> None:
        if not isinstance(stmt, ast.Assign) or not targets:
            return
        value = stmt.value
        tnodes = stmt.targets[0]
        # step = jax.jit(..., donate_argnums=...)
        if isinstance(value, ast.Call) and _is_jit_call(value):
            nums = _literal_argnums(value)
            if nums is None:
                self.non_literal.append(value.lineno)
            elif nums and isinstance(tnodes, ast.Name):
                self.donating_vars[tnodes.id] = DonSig(argnums=nums)
            return
        # step_fn, sh = build_train_step(...)
        if isinstance(value, ast.Call):
            qname = self.reg.canon(self.module.resolve_call(value))
            rets = self.reg.factories.get(qname or "")
            if rets:
                if isinstance(tnodes, ast.Name) and None in rets:
                    self.donating_vars[tnodes.id] = rets[None]
                elif isinstance(tnodes, (ast.Tuple, ast.List)):
                    for pos, el in enumerate(tnodes.elts):
                        if isinstance(el, ast.Name) and pos in rets:
                            self.donating_vars[el.id] = rets[pos]
                return
        # aliases: y = x / y = jax.device_put(x, ...)
        src: Optional[str] = None
        if isinstance(value, ast.Name):
            src = value.id
        elif isinstance(value, ast.Attribute):
            src = _attr_chain(value)
        elif isinstance(value, ast.Call) \
                and _call_name(value) in ALIAS_CALLS and value.args:
            a0 = value.args[0]
            src = a0.id if isinstance(a0, ast.Name) else \
                _attr_chain(a0) if isinstance(a0, ast.Attribute) else None
        if src is not None and isinstance(tnodes, ast.Name):
            if self._root(src) != tnodes.id:
                self.aliases[tnodes.id] = self._root(src)
            return
        # fresh (unconditional) binding severs an earlier alias
        if isinstance(tnodes, ast.Name):
            self.aliases.pop(tnodes.id, None)

    def _maybe_event(self, call: ast.Call, idx: int, targets: Set[str],
                     loops: Tuple[int, ...]) -> None:
        sig: Optional[DonSig] = None
        callee = ""
        if isinstance(call.func, ast.Name) \
                and call.func.id in self.donating_vars:
            sig, callee = self.donating_vars[call.func.id], call.func.id
        else:
            qname = self.reg.canon(self.module.resolve_call(call))
            if qname and qname in self.reg.wrappers:
                sig, callee = self.reg.wrappers[qname], qname
        if sig is None:
            if isinstance(call.func, ast.Name) or \
                    isinstance(call.func, ast.Attribute):
                pass
            return
        donated: Set[str] = set()
        other: Set[str] = set()
        pos_names = sig.params
        for i, arg in enumerate(call.args):
            roots = self._expr_roots(arg)
            is_donated = i in sig.argnums or (
                i < len(pos_names) and pos_names[i] in sig.argnames)
            (donated if is_donated else other).update(roots)
        for kw in call.keywords:
            if kw.arg is None:
                continue
            roots = self._expr_roots(kw.value)
            (donated if kw.arg in sig.argnames else other).update(roots)
        if donated:
            self.events.append(_Event(call.lineno, idx, callee, donated,
                                      other, set(targets), loops))


def _own_exprs(stmt: ast.stmt) -> List[ast.AST]:
    """The expression nodes a statement itself evaluates — compound
    statements contribute only their headers (bodies are separate
    statements); nested function/class defs are opaque (their bodies
    are analyzed as functions in their own right)."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.target, stmt.iter]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        out: List[ast.AST] = []
        for item in stmt.items:
            out.append(item.context_expr)
            if item.optional_vars is not None:
                out.append(item.optional_vars)
        return out
    if isinstance(stmt, (ast.Try, ast.FunctionDef,
                         ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    return [stmt]


def _sub_bodies(stmt: ast.stmt, loops: Tuple[int, ...]):
    """(body, loop-stack) pairs for a compound statement's children."""
    if isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
        inner = loops + (id(stmt),)
        yield stmt.body, inner
        yield stmt.orelse, loops
    elif isinstance(stmt, ast.If):
        yield stmt.body, loops
        yield stmt.orelse, loops
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        yield stmt.body, loops
    elif isinstance(stmt, ast.Try):
        yield stmt.body, loops
        for h in stmt.handlers:
            yield h.body, loops
        yield stmt.orelse, loops
        yield stmt.finalbody, loops


def _target_names(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    tnodes: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        tnodes = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) \
            and stmt.target is not None:
        tnodes = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        tnodes = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        tnodes = [i.optional_vars for i in stmt.items
                  if i.optional_vars is not None]
    for t in tnodes:
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            out |= {e.id for e in t.elts if isinstance(e, ast.Name)}
        elif isinstance(t, ast.Attribute):
            chain = _attr_chain(t)
            if chain:
                out.add(chain)
    return out


def _calls_in(stmt: ast.stmt):
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call):
            yield node


class _Module:
    """One parsed file: import map + function defs."""

    def __init__(self, path: str, rel: str, qual: str, tree: ast.Module):
        self.path, self.rel, self.qual, self.tree = path, rel, qual, tree
        self.import_map: Dict[str, str] = {}
        self.local_funcs: Dict[str, str] = {}
        for node in tree.body:
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.import_map[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
            elif isinstance(node, ast.Import):
                for a in node.names:
                    self.import_map[a.asname or a.name] = a.name
        for func in self.functions():
            self.local_funcs[func.name] = f"{qual}.{func.name}"
        # function-local imports (the launch CLIs import inside main)
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.import_map.setdefault(
                        a.asname or a.name, f"{node.module}.{a.name}")

    def functions(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.FunctionDef):
                yield node

    def resolve_call(self, call: ast.Call) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Name):
            return self.local_funcs.get(f.id) or \
                self.import_map.get(f.id)
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            mod = self.import_map.get(f.value.id)
            if mod:
                return f"{mod}.{f.attr}"
        return None


def _load_modules(root: str,
                  rel_dirs: Sequence[str]) -> List[_Module]:
    mods = []
    for rel_dir in rel_dirs:
        base = os.path.join(root, rel_dir)
        for dirpath, _, files in os.walk(base):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                mod_rel = os.path.relpath(path, base)
                qual = mod_rel[:-3].replace(os.sep, ".")
                if qual.endswith(".__init__"):
                    qual = qual[: -len(".__init__")]
                with open(path) as f:
                    try:
                        tree = ast.parse(f.read(), filename=path)
                    except SyntaxError:
                        continue
                mods.append(_Module(path, rel, qual, tree))
    return mods


def _scan_function(module: _Module, reg: Registry,
                   func: ast.FunctionDef) -> _FuncWalker:
    w = _FuncWalker(module, reg, func)
    w.walk()
    return w


def _promote(module: _Module, reg: Registry, func: ast.FunctionDef,
             w: _FuncWalker) -> bool:
    """Factory + wrapper promotion; returns True when the registry grew."""
    changed = False
    qname = module.local_funcs.get(func.name,
                                   f"{module.qual}.{func.name}")
    # factory: returns a donating callable (possibly inside a tuple)
    rets: Dict[Optional[int], DonSig] = {}
    for node in ast.walk(func):
        if not isinstance(node, ast.Return) or node.value is None:
            continue
        v = node.value
        def _sig_of(e):
            if isinstance(e, ast.Name):
                return w.donating_vars.get(e.id)
            if isinstance(e, ast.Call) and _is_jit_call(e):
                nums = _literal_argnums(e)
                return DonSig(argnums=nums) if nums else None
            return None
        if isinstance(v, ast.Tuple):
            for pos, e in enumerate(v.elts):
                sig = _sig_of(e)
                if sig:
                    rets[pos] = sig
        else:
            sig = _sig_of(v)
            if sig:
                rets[None] = sig
    if rets and reg.factories.get(qname) != rets:
        reg.factories[qname] = rets
        changed = True
    # wrapper: a formal parameter reaches a donated position
    formals = [a.arg for a in (func.args.posonlyargs + func.args.args
                               + func.args.kwonlyargs)]
    donated_formals = [p for p in formals
                       if any(p in ev.roots for ev in w.events)]
    if donated_formals:
        sig = DonSig(argnums=tuple(
            i for i, p in enumerate(formals) if p in donated_formals),
            argnames=tuple(donated_formals), params=tuple(formals))
        if reg.wrappers.get(qname) != sig:
            reg.wrappers[qname] = sig
            changed = True
    return changed


def check_function(module: _Module, reg: Registry,
                   func: ast.FunctionDef) -> List[Finding]:
    """Emit DON001/DON002 findings for one function body."""
    w = _scan_function(module, reg, func)
    findings: List[Finding] = []
    for ev in w.events:
        live = {r for r in ev.roots if r not in ev.rebound}
        for root in sorted(live & ev.other_roots):
            findings.append(Finding(
                "DON002", "error", module.rel, ev.lineno,
                f"{root!r} is passed to both a donated and a "
                f"non-donated argument of {ev.callee}() — the "
                f"non-donated view reads a deleted buffer"))
        for root in sorted(live):
            hit = _read_after(w, ev, root)
            if hit is not None:
                findings.append(Finding(
                    "DON001", "error", module.rel, hit[0],
                    f"{root!r} is read after being donated to "
                    f"{ev.callee}() at line {ev.lineno} — {hit[1]}; "
                    f"donation deletes the caller's buffer (take a "
                    f"fresh host copy first)"))
    for lineno in w.non_literal:
        findings.append(Finding(
            "DON003", "warning", module.rel, lineno,
            f"donate_argnums of this jax.jit call is not a literal — "
            f"the donation contract cannot be statically checked"))
    return findings


def _read_after(w: _FuncWalker, ev: _Event,
                root: str) -> Optional[Tuple[int, str]]:
    # linear scan: a load after the event, before any re-store
    for acc in w.accesses:
        if acc.stmt_idx <= ev.stmt_idx or acc.name != root:
            continue
        if acc.kind == "store":
            break
        return (acc.lineno, "read reaches the donated buffer")
    # loop rule: donated in a loop that never re-stores the root —
    # iteration k+1 re-donates (and re-reads) the deleted buffer
    if ev.loops:
        loop_id = ev.loops[-1]
        stored = any(acc.kind == "store" and acc.name == root
                     and loop_id in acc.loops for acc in w.accesses)
        if not stored:
            return (ev.lineno, "the enclosing loop never rebinds it, "
                               "so the next iteration donates a dead "
                               "buffer")
    return None


def analyze(root: str,
            rel_dirs: Sequence[str] = ("src",)) -> Tuple[List[Finding],
                                                         Dict[str, int]]:
    mods = _load_modules(root, rel_dirs)
    reg = Registry(modules={m.qual: m for m in mods})
    for _ in range(3):                   # factory/wrapper fixpoint
        changed = False
        for mod in mods:
            for func in mod.functions():
                w = _scan_function(mod, reg, func)
                changed |= _promote(mod, reg, func, w)
        if not changed:
            break
    findings: List[Finding] = []
    n_funcs = 0
    for mod in mods:
        for func in mod.functions():
            n_funcs += 1
            findings.extend(check_function(mod, reg, func))
    stats = {"modules": len(mods), "functions": n_funcs,
             "donating_factories": len(reg.factories),
             "donating_wrappers": len(reg.wrappers)}
    return findings, stats


def run(root: str) -> PassResult:
    findings, stats = analyze(root)
    return PassResult("donatecheck", findings, stats)
