"""Static plan verifier: abstract-trace every (technique x placement x
schedule x wire_dtype) the registry can express — no GPUs.

The whole launch path is re-derived device-free: ``jax.eval_shape``
produces the abstract params / optimizer / batch pytrees,
``core.plans.MeshSpec`` stands in for the mesh (plans consult only axis
names and sizes), and ``PlanSearch`` enumerates exactly the candidate
space ``search()`` would score.  What the real launch would build is
therefore checked — not a simplification of it:

  * PLAN001 — ``PLANS`` / ``TECHNIQUE_SPECS`` drift: a technique priced
    but not executable, or vice versa.
  * PLAN002 — sharding consistency: every param / optimizer / batch
    PartitionSpec a plan emits names only mesh axes, never reuses an
    axis within one spec, and divides its dimension exactly (the rule
    engine is supposed to fall back to replication otherwise).
  * PLAN003 — unpartitionable stage splits: ``validate_stages`` must
    accept every searched pipeline placement's ``stage_layers`` for its
    schedule's chunk count.
  * PLAN004 — memory-envelope drift: for every candidate the scorer
    calls feasible, ``technique_state_bytes`` + overhead must fit the
    ``memory_envelope_gb`` the cost model assumes (and the scorer's own
    ``StepCost`` must agree with both exports).
  * PLAN005 — abstract contract of the training step: ``eval_shape`` of
    ``model.loss`` yields a float32 scalar plus scalar metrics, and
    AdamW state mirrors the param tree.

Scenario A is a paper-style two-site slice (2 GPUs per site, so model
axis 1 and 2); scenario B a heterogeneous 3-site line of single-GPU
sites with a 7-layer stack and TFLOP-weighted stage balance — the
non-divisible splits and uneven chunk quotas are exactly where stage
arithmetic breaks first.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis import Finding, PassResult
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.costmodel import (ALL_TECHNIQUES, SCHEDULES, TECHNIQUE_SPECS,
                                  WIRE_DTYPES, Workload,
                                  memory_envelope_gb,
                                  technique_state_bytes)
from repro.core.pipeline import validate_stages
from repro.core.plans import MeshSpec, PLANS, get_plan
from repro.core.search import PlanSearch
from repro.core.topology import Link, Site, line
from repro.launch.mesh import topology_mesh_spec
from repro.models import Model
from repro.models.registry import input_specs
from repro.optim import init_adamw

try:                                    # PartitionSpec entries
    from jax.sharding import PartitionSpec as P
except ImportError:                     # pragma: no cover
    P = None

_PLANS_FILE = "src/repro/core/plans.py"
_COST_FILE = "src/repro/core/costmodel.py"


@dataclasses.dataclass
class Scenario:
    name: str
    topo: object
    wl: Workload
    model_axes: Tuple[int, ...]
    stage_balance: str = "even"


def _scenarios() -> List[Scenario]:
    cfg_a = dataclasses.replace(get_config("gpt2m").reduced(), n_layers=4)
    topo_a = line("planlint-2site",
                  [Site(("RTX", "RTX"), name="V1"),
                   Site(("T4", "T4"), name="V2")],
                  [Link(20e-3, 3.0)])
    wl_a = Workload(cfg_a, seq_len=32, global_batch=8, steps_per_epoch=2,
                    microbatches=4)
    # heterogeneous line of single-GPU sites, 7 layers: non-divisible
    # stacks + TFLOP-weighted chunk quotas
    cfg_b = dataclasses.replace(get_config("gpt2m").reduced(), n_layers=7)
    topo_b = line("planlint-line3",
                  [Site(("A30",), name="V1"), Site(("T4",), name="V2"),
                   Site(("T4",), name="V3")],
                  [Link(20e-3, 3.0), Link(5e-3, 10.0)])
    wl_b = Workload(cfg_b, seq_len=32, global_batch=8, steps_per_epoch=2,
                    microbatches=4)
    return [Scenario("2site", topo_a, wl_a, (1, 2)),
            Scenario("line3", topo_b, wl_b, (1,), "tflops")]


def check_registry(priced, executable) -> List[Tuple[str, str, str]]:
    """PLAN001 core: (rule-file, direction, message) for each name on
    one side of the priced/executable registries only.  Pure so tests
    can feed drifted fakes."""
    priced, executable = set(priced), set(executable)
    out = []
    for t in sorted(priced - executable):
        out.append((_COST_FILE, "priced-only",
                    f"technique {t!r} is priced by TECHNIQUE_SPECS but "
                    f"has no executable plan in PLANS"))
    for t in sorted(executable - priced):
        out.append((_PLANS_FILE, "executable-only",
                    f"plan {t!r} is executable but TECHNIQUE_SPECS "
                    f"does not price it"))
    return out


def check_specs(shapes, specs, mesh: MeshSpec,
                what: str) -> List[str]:
    """PLAN002 core: every spec names known axes, never reuses one, and
    divides its dimension.  Pure (shapes + specs + mesh in, problems
    out) so tests can feed deliberately broken specs."""
    problems: List[str] = []
    axis_size = mesh.shape
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    if len(flat_shapes) != len(flat_specs):
        return [f"{what}: {len(flat_shapes)} leaves but "
                f"{len(flat_specs)} specs"]
    for leaf, spec in zip(flat_shapes, flat_specs):
        if not isinstance(spec, P):
            problems.append(f"{what}: non-PartitionSpec leaf {spec!r}")
            continue
        used: List[str] = []
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                if a not in axis_size:
                    problems.append(
                        f"{what}: spec {spec} names axis {a!r} not on "
                        f"mesh {mesh.axes}")
                    continue
                used.append(a)
                size *= axis_size[a]
            if dim >= len(leaf.shape):
                problems.append(
                    f"{what}: spec {spec} has more entries than leaf "
                    f"rank {len(leaf.shape)}")
            elif size > 1 and leaf.shape[dim] % size != 0:
                problems.append(
                    f"{what}: dim {dim} of shape {tuple(leaf.shape)} "
                    f"not divisible by {size} ({spec} on {mesh.axes})")
        if len(used) != len(set(used)):
            problems.append(
                f"{what}: spec {spec} reuses a mesh axis")
    return problems


def _abstract_state(model: Model, wl: Workload):
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt = jax.eval_shape(init_adamw, params)
    shape = ShapeConfig("planlint", wl.seq_len, wl.global_batch, "train")
    batch = input_specs(model.cfg, shape, abstract=True)
    return params, opt, batch


def _check_contract(model: Model, params, opt, batch,
                    where: str) -> List[str]:
    """PLAN005: the abstract training-step contract."""
    problems = []
    loss, metrics = jax.eval_shape(
        lambda p, b: model.loss(p, b, remat=False), params, batch)
    if loss.shape != () or loss.dtype != np.float32:
        problems.append(f"{where}: loss traces to "
                        f"{loss.dtype}{loss.shape}, expected float32 "
                        f"scalar")
    for k, v in metrics.items():
        if v.shape != ():
            problems.append(f"{where}: metric {k!r} traces to shape "
                            f"{v.shape}, expected scalar")
    p_leaves = jax.tree.leaves(params)
    for name, tree in (("m", opt.m), ("v", opt.v)):
        o_leaves = jax.tree.leaves(tree)
        if [(l.shape, l.dtype) for l in o_leaves] != \
                [(l.shape, l.dtype) for l in p_leaves]:
            problems.append(f"{where}: AdamW {name} tree does not "
                            f"mirror the param tree")
    return problems


def _candidate_mesh(plan, place, topo, sites,
                    model_axis: int) -> Optional[MeshSpec]:
    shape, axes = topology_mesh_spec(topo, sites, model=model_axis)
    if plan.pipeline:
        # pipeline_mesh: the stage axis absorbs the pod axis (one pod
        # block per placed site)
        return MeshSpec.of((place.n_stages,) + shape[1:],
                           ("stage",) + axes[1:])
    return MeshSpec.of(shape, axes)


def run(root: str) -> PassResult:
    res = PassResult("planlint")

    def add(rule: str, file: str, line_no: int, msg: str,
            severity: str = "error") -> None:
        res.findings.append(Finding(rule, severity, file, line_no, msg))

    # PLAN001: registry drift
    for file, _, msg in check_registry(TECHNIQUE_SPECS, PLANS):
        add("PLAN001", file, 1, msg)

    n_cand = n_spec_checks = n_split_checks = 0
    for scen in _scenarios():
        model = Model(scen.wl.cfg)
        params, opt, batch = _abstract_state(model, scen.wl)
        for msg in _check_contract(model, params, opt, batch, scen.name):
            add("PLAN005", _COST_FILE, 1, msg)

        search = PlanSearch(scen.wl, scen.topo,
                            techniques=ALL_TECHNIQUES,
                            schedules=SCHEDULES,
                            wire_dtypes=WIRE_DTYPES,
                            stage_balance=scen.stage_balance)
        seen_spec: set = set()
        seen_split: set = set()
        for sc in search.search(prune=False):
            cand = sc.candidate
            n_cand += 1
            place = search.placement(cand)
            plan = get_plan(cand.technique)
            cost = search.step_cost(cand)

            # PLAN004: envelope / feasibility consistency
            env = memory_envelope_gb(scen.topo, cand.sites)
            if abs(cost.mem_available_gb - env) > 1e-9:
                add("PLAN004", _COST_FILE, 1,
                    f"{scen.name} {cand.key}: StepCost envelope "
                    f"{cost.mem_available_gb} != memory_envelope_gb "
                    f"{env}")
            if sc.tflops:
                state_gb = technique_state_bytes(
                    cand.technique, scen.wl, scen.topo,
                    cand.sites) / 1e9
                if state_gb + scen.wl.OVERHEAD_GB > env + 1e-6:
                    add("PLAN004", _COST_FILE, 1,
                        f"{scen.name} {cand.key}: feasible per the "
                        f"scorer but technique_state_bytes "
                        f"({state_gb:.2f} GB) + overhead exceeds the "
                        f"{env:.2f} GB site envelope")
                if not cost.fits:
                    add("PLAN004", _COST_FILE, 1,
                        f"{scen.name} {cand.key}: scorer returned "
                        f"TFLOP/s for a placement whose StepCost "
                        f"does not fit")

            # PLAN003: stage split must partition the stack
            if plan.pipeline:
                key = (cand.sites, cand.schedule, place.stage_layers)
                if key not in seen_split:
                    seen_split.add(key)
                    n_split_checks += 1
                    try:
                        validate_stages(scen.wl.cfg, params["layers"],
                                        place.n_stages,
                                        place.stage_layers,
                                        schedule=place.schedule)
                    except ValueError as e:
                        add("PLAN003", _PLANS_FILE, 1,
                            f"{scen.name} {cand.key}: searched "
                            f"placement rejected by validate_stages: "
                            f"{e}")

            # PLAN002: shardings for every mesh variant
            for mv in scen.model_axes:
                key = (cand.technique, cand.sites, cand.schedule, mv)
                if key in seen_spec:
                    continue
                seen_spec.add(key)
                mesh = _candidate_mesh(plan, place, scen.topo,
                                       cand.sites, mv)
                trees = (
                    ("params", params,
                     plan.param_specs(params, scen.wl.cfg, mesh)),
                    ("opt", params,
                     plan.opt_specs(params, scen.wl.cfg, mesh)),
                    ("batch", batch, plan.batch_spec(batch, mesh)),
                )
                for what, shapes, specs in trees:
                    n_spec_checks += 1
                    for msg in check_specs(
                            shapes, specs, mesh,
                            f"{scen.name} {cand.key} model={mv} "
                            f"{what}"):
                        add("PLAN002", _PLANS_FILE, 1, msg)
    res.stats = {"candidates": n_cand, "spec_trees": n_spec_checks,
                 "stage_splits": n_split_checks,
                 "techniques": len(TECHNIQUE_SPECS)}
    return res
