"""Device-free static analysis for the repro codebase.

Four passes, one CLI (``python -m repro.analysis``), structured JSON
findings, and a checked-in baseline for accepted findings
(``tools/analysis_baseline.json``):

  * ``planlint``     — abstract-traces every (technique x placement x
    schedule x wire_dtype) the cost-model registry can express, via
    ``jax.eval_shape`` and the plans' own sharding rules, on a
    device-free ``MeshSpec``.  No GPUs touched.
  * ``schedlint``    — exhaustively verifies ``core.pipeline
    .schedule_tables`` dependency soundness over a
    (schedule x S x m x v) grid.
  * ``donatecheck``  — AST pass flagging reads of a buffer after it was
    passed to a ``jax.jit(..., donate_argnums=...)`` callable (the PR-7
    ``reshard_check`` bug class).
  * ``conventions``  — repo-invariant lint: unit-suffix discipline in
    the cost model, no swallowing ``except`` handlers (the PR-3 probe
    bug class), every registered technique reachable from docs+tests.

Each pass is a function ``run(root) -> PassResult``; findings carry a
stable rule id, severity, ``file:line`` and a message.  The driver in
``__main__`` matches findings against the baseline and exits non-zero
when any finding is not baselined (docs/static-analysis.md).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Finding", "PassResult", "Baseline", "PASSES", "run_passes",
           "repo_root", "RULES"]

#: rule id -> one-line description (docs/static-analysis.md mirrors this;
#: conventions.CONV003 checks the techniques half of the docs contract).
RULES: Dict[str, str] = {
    "PLAN001": "PLANS / TECHNIQUE_SPECS drift (priced but not "
               "executable, or vice versa)",
    "PLAN002": "plan sharding inconsistent with the mesh (unknown axis, "
               "axis reuse, or non-divisible dimension)",
    "PLAN003": "unpartitionable stage split (validate_stages rejects "
               "the searched placement)",
    "PLAN004": "technique_state_bytes exceeds the site memory envelope "
               "the cost model assumes for a feasible placement",
    "PLAN005": "abstract loss/optimizer trace broken (eval_shape "
               "disagrees with the declared contract)",
    "SCHED001": "schedule table incomplete (an item never runs, runs "
                "twice, or warm-up/drain is cut short)",
    "SCHED002": "slot out of range (chunk/microbatch index invalid for "
                "the stage)",
    "SCHED003": "dependency race (a consume slot without a "
                "strictly-earlier matching produce)",
    "SCHED004": "ring send/receive mismatch (orphan arrival, lost "
                "non-banked send, or clobbered inbox)",
    "SCHED005": "tick-count formula violated for the schedule",
    "DON001": "donated buffer read after the donating call",
    "DON002": "same buffer passed to a donated and a non-donated "
              "argument of one call",
    "DON003": "donate_argnums not statically checkable (non-literal)",
    "CONV001": "unit-suffix mixing (_s/_bytes/_gb added without a "
               "conversion)",
    "CONV002": "overbroad except swallows the error and falls through",
    "CONV003": "registered technique unreachable from docs or tests",
    "BASE001": "baseline entry matches no current finding (stale)",
}

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One analysis finding: stable rule id, severity, location, text."""
    rule: str
    severity: str
    file: str          # repo-relative posix path
    line: int
    message: str

    def to_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "severity": self.severity,
                "file": self.file, "line": self.line,
                "message": self.message}

    def render(self) -> str:
        return (f"{self.file}:{self.line}: {self.severity} "
                f"[{self.rule}] {self.message}")


@dataclass
class PassResult:
    """Findings plus what-was-checked counters (fed into BENCH_8)."""
    name: str
    findings: List[Finding] = field(default_factory=list)
    stats: Dict[str, int] = field(default_factory=dict)


@dataclass
class Baseline:
    """Accepted findings: list of {rule, file, match, justification}.

    A finding is baselined when an entry's rule and file match exactly
    and ``match`` is a substring of the message.  Entries that match
    nothing are themselves reported (BASE001) so the baseline cannot
    rot.
    """
    entries: List[Dict[str, str]] = field(default_factory=list)
    path: str = ""

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([], path)
        with open(path) as f:
            data = json.load(f)
        entries = data.get("accepted", [])
        for e in entries:
            for k in ("rule", "file", "match", "justification"):
                if not isinstance(e.get(k), str) or not e[k].strip():
                    raise ValueError(
                        f"baseline entry {e!r} needs non-empty string "
                        f"fields rule/file/match/justification")
        return cls(entries, path)

    def match(self, f: Finding) -> Optional[Dict[str, str]]:
        for e in self.entries:
            if (e["rule"] == f.rule and e["file"] == f.file
                    and e["match"] in f.message):
                return e
        return None

    def split(self, findings: List[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[Finding]]:
        """(new, accepted, stale-baseline-findings)."""
        new, accepted = [], []
        used: List[int] = []
        for f in findings:
            e = self.match(f)
            if e is None:
                new.append(f)
            else:
                accepted.append(f)
                used.append(self.entries.index(e))
        stale = [
            Finding("BASE001", "error",
                    os.path.relpath(self.path) if self.path else
                    "tools/analysis_baseline.json", 1,
                    f"stale baseline entry {e['rule']} for {e['file']} "
                    f"(match {e['match']!r}) — no current finding "
                    f"matches; delete it")
            for i, e in enumerate(self.entries) if i not in used]
        return new, accepted, stale


def repo_root() -> str:
    """The repo checkout this package was imported from."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _pass_runners() -> Dict[str, Callable[[str], PassResult]]:
    from repro.analysis import (conventions, donatecheck, planlint,
                                schedlint)
    return {"planlint": planlint.run, "schedlint": schedlint.run,
            "donatecheck": donatecheck.run, "conventions": conventions.run}


#: pass name -> runner, in report order.
PASSES = ("planlint", "schedlint", "donatecheck", "conventions")


def run_passes(root: Optional[str] = None,
               passes: Optional[List[str]] = None) -> List[PassResult]:
    root = root or repo_root()
    runners = _pass_runners()
    out = []
    for name in passes or PASSES:
        if name not in runners:
            raise KeyError(f"unknown pass {name!r}; have {sorted(runners)}")
        out.append(runners[name](root))
    return out
