"""Reproduction of "Performance of Small Language Model Pretraining on
FABRIC: An Empirical Study" grown toward a production-scale jax system.

Importing any ``repro`` package installs the jax version-compat shims
(repro.compat) so the modern-API codebase also runs on jax 0.4.x.
"""
from repro import compat as _compat  # noqa: F401  (installs jax shims)

_compat.install()
