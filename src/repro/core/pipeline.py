"""Pipeshard: inter-operator (pipeline) parallelism over a ``stage`` mesh
axis, combined with intra-operator (Shard) parallelism inside each stage.

This is the TPU-native mapping of Alpa's pipeshard plans (paper §III-B):

  * the layer stack (already stacked ``[L, ...]`` for ``lax.scan``) is cut
    into ``n_stages`` contiguous slices by sharding the stack axis over the
    ``stage`` mesh axis with a partial-manual ``jax.shard_map``;
  * the global batch is split into microbatches; the classic GPipe schedule
    runs ``n_micro + n_stages - 1`` ticks, each stage processing microbatch
    ``t - stage_id`` and handing activations to its successor with
    ``jax.lax.ppermute`` — the point-to-point communication that makes the
    paper's Pipeshard latency-tolerant (Table II);
  * inside the body, the ``data``/``model`` mesh axes stay *auto*, so GSPMD
    still applies the Shard rules (tensor parallelism) per stage;
  * embedding / head / loss run outside the manual region in auto-SPMD land
    and the backward schedule falls out of differentiating through the scan
    and the ppermute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import NATIVE_SHARD_MAP
from repro.core.plans import Plan, STAGE_AXIS


def pipeline_mesh(devices_mesh: Mesh, n_stages: int,
                  stage_order=None, stage_layers=None) -> Mesh:
    """Reshape a (pod?, data, model) mesh into (stage, data, model).

    The stage axis absorbs the pod axis first (inter-stage point-to-point is
    exactly the traffic that tolerates the slow inter-pod link — the paper's
    geo-distributed finding), then splits the data axis if more stages are
    requested.

    ``stage_order``: permutation of the pod blocks (one block per site, see
    ``core.plans.Placement.pod_permutation``) giving the stage→site
    assignment from the plan search — stage k runs on pod block
    ``stage_order[k]``, so the pipeline crosses the topology's links in
    the order the search priced, not in raw site numbering.

    ``stage_layers``: per-stage layer counts from the TFLOP-weighted
    balancer (``core.plans.Placement.stage_layers``).  The device mesh
    itself does not depend on how layers are split, so this only
    validates the split's shape (one positive entry per stage); the
    split — even or uneven — is realized by ``make_pipeline_loss``
    (pad-and-mask, see ``validate_stages``).
    """
    if stage_layers is not None:
        layers = tuple(stage_layers)
        if len(layers) != n_stages:
            raise ValueError(
                f"stage_layers {layers} has {len(layers)} entries for "
                f"n_stages={n_stages}")
        if any(l < 1 for l in layers):
            raise ValueError(f"every stage needs >= 1 layer, "
                             f"got {layers}")
    names = devices_mesh.axis_names
    shape = dict(zip(names, devices_mesh.devices.shape))
    pod = shape.get("pod", 1)
    data = shape.get("data", 1)
    model = shape.get("model", 1)
    if n_stages % pod != 0 and pod % n_stages != 0:
        raise ValueError(f"n_stages={n_stages} incompatible with pod={pod}")
    rest = n_stages // pod if n_stages >= pod else 1
    if data % rest != 0:
        raise ValueError(
            f"cannot split data={data} into {rest} pipeline sub-stages")
    devices = devices_mesh.devices
    if stage_order is not None:
        order = tuple(stage_order)
        if sorted(order) != list(range(pod)):
            raise ValueError(
                f"stage_order {order} is not a permutation of the "
                f"{pod} pod blocks")
        if "pod" in names:
            import numpy as np
            devices = np.take(devices, order, axis=names.index("pod"))
        elif order != (0,):
            raise ValueError("stage_order given but mesh has no pod axis")
    devs = devices.reshape(n_stages, (pod * data) // n_stages, model)
    return jax.sharding.Mesh(devs, (STAGE_AXIS, "data", "model"))


def stack_length(cfg, stack) -> int:
    leaf = jax.tree.leaves(stack)[0]
    return leaf.shape[0]


def validate_stages(cfg, stack, n_stages: int,
                    stage_layers=None) -> Optional[tuple]:
    """Check the layer stack can be cut into ``n_stages`` pipeline slices.

    Args:
        cfg: model config (names the stack in error messages).
        stack: the stacked ``[L, ...]`` layer params (groups for hybrid).
        n_stages: number of pipeline stages.
        stage_layers: optional per-stage layer counts (a TFLOP-weighted
            split from ``core.costmodel.balanced_stage_layers``).  Must
            partition the stack; *uneven* splits are fine — they execute
            via the pad-and-mask stage construction in
            ``make_pipeline_loss`` (docs/topology-and-search.md
            §Balancing).

    Returns:
        The normalized per-stage split as a tuple when ``stage_layers``
        is given, else ``None`` (the equal-block fast path).
    """
    L = stack_length(cfg, stack)
    if stage_layers is not None:
        layers = tuple(int(l) for l in stage_layers)
        if len(layers) != n_stages or sum(layers) != L \
                or any(l < 1 for l in layers):
            raise ValueError(
                f"{cfg.name}: stage_layers {layers} does not partition the "
                f"{L}-entry stack into {n_stages} stages")
        return layers
    if L % n_stages != 0:
        raise ValueError(
            f"{cfg.name}: stack length {L} (groups for hybrid) not divisible "
            f"by n_stages={n_stages} — pick a divisor or pass an explicit "
            f"stage_layers split (see DESIGN.md §4)")
    return None


def make_pipeline_loss(model, mesh: Mesh, n_micro: int, *,
                       remat: bool = True, carrier_dtype=jnp.float32,
                       stage_layers=None):
    """Build loss(params, batch) running the stacked layers as a GPipe
    pipeline over the mesh's ``stage`` axis.

    ``stage_layers``: optional per-stage layer counts from a
    ``core.plans.Placement`` — validated against the stack (see
    ``validate_stages``).  Uneven splits execute via pad-and-mask: every
    stage's layer slice is gathered and padded to ``max(stage_layers)``
    and the padded slots are identity-masked inside ``model.run_stack``
    (zero aux, activations pass through unchanged), so a TFLOP-weighted
    heterogeneous split runs with the same equal-block stage sharding.

    ``carrier_dtype``: dtype of the inter-stage activation carriers (scan
    state / ppermute payload / bank buffer).  Defaults to fp32 because the
    XLA *CPU* SPMD partitioner CHECK-fails ("Invalid binary instruction
    opcode copy") when transposing the pipeline with bf16 carriers; the
    stage compute itself still runs in the model dtype.  On real TPU this
    can be set to bf16 to halve inter-stage ppermute bytes.
    """
    cfg = model.cfg
    n_stages = mesh.shape[STAGE_AXIS]
    # Manual axes of the pipeline region.  The stage axis always is; on
    # jax 0.4.x — whose SPMD partitioner CHECK-fails on partial-auto
    # shard_map (repro.compat.NATIVE_SHARD_MAP, docs/architecture.md) —
    # size-1 auto axes are promoted to manual so a degenerate
    # (stage, 1, 1) mesh compiles as a fully-manual region, which that
    # partitioner handles fine.  A size-1 axis is unsharded either way,
    # so the promotion never changes semantics.
    manual = {STAGE_AXIS}
    if not NATIVE_SHARD_MAP:
        manual |= {a for a in mesh.axis_names
                   if a != STAGE_AXIS and mesh.shape[a] == 1}

    def loss_fn(params, batch):
        x, positions, _ = model._embed_inputs(params, batch)
        enc_out = model._encode(params, batch) if cfg.family == "encdec" \
            else None
        B, S, d = x.shape
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        xm = x.reshape(n_micro, mb, S, d).astype(carrier_dtype)
        xm = jax.lax.with_sharding_constraint(
            xm, P(None, "data", None, None))
        # every microbatch keeps its own position rows (packed/ragged
        # batches have per-example positions, so slicing the first
        # microbatch's rows for all of them would be wrong)
        pos_m = positions.reshape(n_micro, mb, S)
        enc_mb = jnp.zeros((), x.dtype) if enc_out is None else \
            enc_out.reshape(n_micro, mb, *enc_out.shape[1:])
        stack = params["layers"]
        split = validate_stages(cfg, stack, n_stages, stage_layers)
        layer_valid = None
        if split is not None:
            # per-stage gather realizing Placement.stage_layers: stage s
            # gets its own contiguous slice, padded to the longest stage
            # by repeating its last layer; padded slots are masked to
            # identity (and zero aux) inside run_stack, so the where()
            # never sees uninitialized params.
            max_l = max(split)
            offs = np.concatenate(([0], np.cumsum(split)))
            idx = np.concatenate([
                offs[s] + np.minimum(np.arange(max_l), split[s] - 1)
                for s in range(n_stages)]).astype(np.int32)
            stack = jax.tree.map(
                lambda leaf: jnp.take(leaf, jnp.asarray(idx), axis=0),
                stack)
            layer_valid = jnp.asarray(np.concatenate(
                [np.arange(max_l) < split[s] for s in range(n_stages)]))
        shared = params.get("shared")
        if shared is None:
            shared = jnp.zeros(())

        # in_specs: only the manual (stage) axis is mentioned; data/model
        # sharding of the same arrays stays in auto-SPMD land.
        stack_spec = jax.tree.map(lambda _: P(STAGE_AXIS), stack)
        mask_args = () if layer_valid is None else (layer_valid,)
        mask_specs = () if layer_valid is None else (P(STAGE_AXIS),)
        # stage id as a stage-sharded input rather than lax.axis_index:
        # axis_index lowers to partition-id, which the jax-0.4.x SPMD
        # partitioner rejects inside partial-auto shard_map regions.
        stage_ids = jnp.arange(n_stages, dtype=jnp.int32)

        @partial(jax.shard_map, mesh=mesh, axis_names=manual,
                 in_specs=(P(STAGE_AXIS), stack_spec, *mask_specs,
                           P(), P(), P(), P()),
                 out_specs=P(STAGE_AXIS), check_vma=False)
        def run_pipeline(stage_ids, stack_local, *rest):
            if layer_valid is None:
                valid_local = None
                xm, pos_m, enc_mb, shared = rest
            else:
                valid_local, xm, pos_m, enc_mb, shared = rest
            stage = stage_ids[0]
            T = n_micro + n_stages - 1
            state0 = jnp.zeros_like(xm[0])
            buf0 = jnp.zeros_like(xm)

            def run_stage(inp, pos, mb_idx):
                kwargs = {}
                if cfg.family == "encdec":
                    kwargs["enc_out"] = enc_mb[mb_idx]
                out, aux = model.run_stack(
                    stack_local, inp.astype(model.compute_dtype), pos,
                    shared=(shared if cfg.family == "hybrid" else None),
                    remat=remat, layer_valid=valid_local, **kwargs)
                return out.astype(carrier_dtype), aux.astype(jnp.float32)

            def tick(carry, t):
                state, buf = carry
                mb_idx = jnp.clip(t - stage, 0, n_micro - 1)
                # a stage only holds a real microbatch for the ticks
                # t in [stage, stage + n_micro): warm-up and drain ticks
                # skip the stack entirely instead of burning a full
                # forward on a stale microbatch and polluting the aux sum
                active = jnp.logical_and(t >= stage, t - stage < n_micro)
                inp = jnp.where(stage == 0, xm[mb_idx], state)
                out, aux = jax.lax.cond(
                    active,
                    lambda op: run_stage(*op),
                    lambda op: (op[0], jnp.float32(0.0)),
                    (inp, pos_m[mb_idx], mb_idx))
                # last stage banks its finished microbatch t-(S-1)
                done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                valid = (t - (n_stages - 1) >= 0)
                slot = jax.lax.dynamic_update_index_in_dim(
                    buf, out.astype(buf.dtype), done_idx, 0)
                buf = jnp.where(valid, slot, buf)
                # hand activations to the next stage (p2p, ring)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                state = jax.lax.ppermute(out, STAGE_AXIS, perm)
                return (state, buf), aux

            (_, buf), auxs = jax.lax.scan(
                tick, (state0, buf0), jnp.arange(T))
            # leading (length-1 per shard) stage axis; caller slices [-1]
            return buf[None], jnp.sum(auxs)[None]

        buf_staged, aux_staged = run_pipeline(stage_ids, stack, *mask_args,
                                              xm, pos_m, enc_mb, shared)
        hidden = buf_staged[-1].reshape(B, S, d).astype(model.compute_dtype)
        # every stage owns distinct layers, so the model's aux (MoE
        # load-balance) sums over stages; each stage accumulated one
        # batch-invariant aux per microbatch, so the microbatch mean is
        # what matches the reference full-batch aux
        aux = jnp.sum(aux_staged) / n_micro
        logits = model._head(params, hidden)
        from repro.models.model import lm_loss
        return lm_loss(cfg, logits, batch, aux)

    return loss_fn
