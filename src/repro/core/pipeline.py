"""Pipeshard: inter-operator (pipeline) parallelism over a ``stage`` mesh
axis, combined with intra-operator (Shard) parallelism inside each stage.

This is the TPU-native mapping of Alpa's pipeshard plans (paper §III-B):

  * the layer stack (already stacked ``[L, ...]`` for ``lax.scan``) is cut
    into ``n_stages`` contiguous slices by sharding the stack axis over the
    ``stage`` mesh axis with a partial-manual ``jax.shard_map``;
  * the global batch is split into microbatches; the classic GPipe schedule
    runs ``n_micro + n_stages - 1`` ticks, each stage processing microbatch
    ``t - stage_id`` and handing activations to its successor with
    ``jax.lax.ppermute`` — the point-to-point communication that makes the
    paper's Pipeshard latency-tolerant (Table II);
  * inside the body, the ``data``/``model`` mesh axes stay *auto*, so GSPMD
    still applies the Shard rules (tensor parallelism) per stage;
  * embedding / head / loss run outside the manual region in auto-SPMD land
    and the backward schedule falls out of differentiating through the scan
    and the ppermute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.plans import Plan, STAGE_AXIS


def pipeline_mesh(devices_mesh: Mesh, n_stages: int,
                  stage_order=None, stage_layers=None) -> Mesh:
    """Reshape a (pod?, data, model) mesh into (stage, data, model).

    The stage axis absorbs the pod axis first (inter-stage point-to-point is
    exactly the traffic that tolerates the slow inter-pod link — the paper's
    geo-distributed finding), then splits the data axis if more stages are
    requested.

    ``stage_order``: permutation of the pod blocks (one block per site, see
    ``core.plans.Placement.pod_permutation``) giving the stage→site
    assignment from the plan search — stage k runs on pod block
    ``stage_order[k]``, so the pipeline crosses the topology's links in
    the order the search priced, not in raw site numbering.

    ``stage_layers``: per-stage layer counts from the TFLOP-weighted
    balancer (``core.plans.Placement.stage_layers``).  The device mesh
    itself does not depend on how layers are split, so this only
    validates the split's shape (one positive entry per stage); the
    split is realized by ``make_pipeline_loss``/``validate_stages``.
    """
    if stage_layers is not None:
        layers = tuple(stage_layers)
        if len(layers) != n_stages:
            raise ValueError(
                f"stage_layers {layers} has {len(layers)} entries for "
                f"n_stages={n_stages}")
        if any(l < 1 for l in layers):
            raise ValueError(f"every stage needs >= 1 layer, "
                             f"got {layers}")
    names = devices_mesh.axis_names
    shape = dict(zip(names, devices_mesh.devices.shape))
    pod = shape.get("pod", 1)
    data = shape.get("data", 1)
    model = shape.get("model", 1)
    if n_stages % pod != 0 and pod % n_stages != 0:
        raise ValueError(f"n_stages={n_stages} incompatible with pod={pod}")
    rest = n_stages // pod if n_stages >= pod else 1
    if data % rest != 0:
        raise ValueError(
            f"cannot split data={data} into {rest} pipeline sub-stages")
    devices = devices_mesh.devices
    if stage_order is not None:
        order = tuple(stage_order)
        if sorted(order) != list(range(pod)):
            raise ValueError(
                f"stage_order {order} is not a permutation of the "
                f"{pod} pod blocks")
        if "pod" in names:
            import numpy as np
            devices = np.take(devices, order, axis=names.index("pod"))
        elif order != (0,):
            raise ValueError("stage_order given but mesh has no pod axis")
    devs = devices.reshape(n_stages, (pod * data) // n_stages, model)
    return jax.sharding.Mesh(devs, (STAGE_AXIS, "data", "model"))


def stack_length(cfg, stack) -> int:
    leaf = jax.tree.leaves(stack)[0]
    return leaf.shape[0]


def validate_stages(cfg, stack, n_stages: int, stage_layers=None) -> None:
    """Check the layer stack can be cut into ``n_stages`` pipeline slices.

    Args:
        cfg: model config (names the stack in error messages).
        stack: the stacked ``[L, ...]`` layer params (groups for hybrid).
        n_stages: number of pipeline stages.
        stage_layers: optional per-stage layer counts (a TFLOP-weighted
            split from ``core.costmodel.balanced_stage_layers``).  Must
            partition the stack; an *uneven* split is additionally
            rejected here because the shard_map stack sharding realizes
            equal blocks only (docs/topology-and-search.md §Balancing).
    """
    L = stack_length(cfg, stack)
    if stage_layers is not None:
        layers = tuple(stage_layers)
        if len(layers) != n_stages or sum(layers) != L \
                or any(l < 1 for l in layers):
            raise ValueError(
                f"{cfg.name}: stage_layers {layers} does not partition the "
                f"{L}-entry stack into {n_stages} stages")
        if len(set(layers)) != 1:
            raise NotImplementedError(
                f"{cfg.name}: uneven stage_layers {layers} — the GPipe "
                f"runtime shards the stack in equal blocks per stage; "
                f"TFLOP-weighted splits are priced analytically "
                f"(core/costmodel.py) but not yet realized at runtime "
                f"(docs/topology-and-search.md §Balancing)")
    if L % n_stages != 0:
        raise ValueError(
            f"{cfg.name}: stack length {L} (groups for hybrid) not divisible "
            f"by n_stages={n_stages} — pick a divisor (see DESIGN.md §4)")


def make_pipeline_loss(model, mesh: Mesh, n_micro: int, *,
                       remat: bool = True, carrier_dtype=jnp.float32,
                       stage_layers=None):
    """Build loss(params, batch) running the stacked layers as a GPipe
    pipeline over the mesh's ``stage`` axis.

    ``stage_layers``: optional per-stage layer counts from a
    ``core.plans.Placement`` — validated against the stack (see
    ``validate_stages``; uneven splits are analytic-only today).

    ``carrier_dtype``: dtype of the inter-stage activation carriers (scan
    state / ppermute payload / bank buffer).  Defaults to fp32 because the
    XLA *CPU* SPMD partitioner CHECK-fails ("Invalid binary instruction
    opcode copy") when transposing the pipeline with bf16 carriers; the
    stage compute itself still runs in the model dtype.  On real TPU this
    can be set to bf16 to halve inter-stage ppermute bytes.
    """
    cfg = model.cfg
    n_stages = mesh.shape[STAGE_AXIS]

    def loss_fn(params, batch):
        x, positions, _ = model._embed_inputs(params, batch)
        enc_out = model._encode(params, batch) if cfg.family == "encdec" \
            else None
        B, S, d = x.shape
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        xm = x.reshape(n_micro, mb, S, d).astype(carrier_dtype)
        xm = jax.lax.with_sharding_constraint(
            xm, P(None, "data", None, None))
        pos_mb = positions[:mb]
        enc_mb = jnp.zeros((), x.dtype) if enc_out is None else \
            enc_out.reshape(n_micro, mb, *enc_out.shape[1:])
        stack = params["layers"]
        validate_stages(cfg, stack, n_stages, stage_layers)
        shared = params.get("shared")
        if shared is None:
            shared = jnp.zeros(())

        # in_specs: only the manual (stage) axis is mentioned; data/model
        # sharding of the same arrays stays in auto-SPMD land.
        stack_spec = jax.tree.map(lambda _: P(STAGE_AXIS), stack)
        # stage id as a stage-sharded input rather than lax.axis_index:
        # axis_index lowers to partition-id, which the jax-0.4.x SPMD
        # partitioner rejects inside partial-auto shard_map regions.
        stage_ids = jnp.arange(n_stages, dtype=jnp.int32)

        @partial(jax.shard_map, mesh=mesh, axis_names={STAGE_AXIS},
                 in_specs=(P(STAGE_AXIS), stack_spec, P(), P(), P(), P()),
                 out_specs=P(STAGE_AXIS), check_vma=False)
        def run_pipeline(stage_ids, stack_local, xm, pos_mb, enc_mb, shared):
            stage = stage_ids[0]
            T = n_micro + n_stages - 1
            state0 = jnp.zeros_like(xm[0])
            buf0 = jnp.zeros_like(xm)

            def tick(carry, t):
                state, buf = carry
                mb_idx = jnp.clip(t - stage, 0, n_micro - 1)
                inp = jnp.where(stage == 0, xm[jnp.clip(t, 0, n_micro - 1)],
                                state)
                kwargs = {}
                if cfg.family == "encdec":
                    kwargs["enc_out"] = enc_mb[mb_idx]
                out, aux = model.run_stack(
                    stack_local, inp.astype(model.compute_dtype), pos_mb,
                    shared=(shared if cfg.family == "hybrid" else None),
                    remat=remat, **kwargs)
                out = out.astype(carrier_dtype)
                # last stage banks its finished microbatch t-(S-1)
                done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                valid = (t - (n_stages - 1) >= 0)
                slot = jax.lax.dynamic_update_index_in_dim(
                    buf, out.astype(buf.dtype), done_idx, 0)
                buf = jnp.where(valid, slot, buf)
                # hand activations to the next stage (p2p, ring)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                state = jax.lax.ppermute(out, STAGE_AXIS, perm)
                return (state, buf), aux

            (_, buf), auxs = jax.lax.scan(
                tick, (state0, buf0), jnp.arange(T))
            # leading (length-1 per shard) stage axis; caller slices [-1]
            return buf[None], jnp.sum(auxs)[None]

        buf_staged, aux_staged = run_pipeline(stage_ids, stack, xm, pos_mb,
                                              enc_mb, shared)
        hidden = buf_staged[-1].reshape(B, S, d).astype(model.compute_dtype)
        aux = aux_staged[-1]
        logits = model._head(params, hidden)
        from repro.models.model import lm_loss
        return lm_loss(cfg, logits, batch, aux)

    return loss_fn
