"""Pipeshard: inter-operator (pipeline) parallelism over a ``stage`` mesh
axis, combined with intra-operator (Shard) parallelism inside each stage.

This is the TPU-native mapping of Alpa's pipeshard plans (paper §III-B):

  * the layer stack (already stacked ``[L, ...]`` for ``lax.scan``) is cut
    into ``n_stages`` contiguous slices by sharding the stack axis over the
    ``stage`` mesh axis with a partial-manual ``jax.shard_map``;
  * the global batch is split into microbatches; the classic GPipe schedule
    runs ``n_micro + n_stages - 1`` ticks, each stage processing microbatch
    ``t - stage_id`` and handing activations to its successor with
    ``jax.lax.ppermute`` — the point-to-point communication that makes the
    paper's Pipeshard latency-tolerant (Table II);
  * inside the body, the ``data``/``model`` mesh axes stay *auto*, so GSPMD
    still applies the Shard rules (tensor parallelism) per stage;
  * embedding / head / loss run outside the manual region in auto-SPMD land
    and the backward schedule falls out of differentiating through the scan
    and the ppermute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import NATIVE_SHARD_MAP
from repro.core.costmodel import parse_schedule
from repro.core.plans import Plan, STAGE_AXIS


def pipeline_mesh(devices_mesh: Mesh, n_stages: int,
                  stage_order=None, stage_layers=None,
                  schedule: str = "gpipe") -> Mesh:
    """Reshape a (pod?, data, model) mesh into (stage, data, model).

    The stage axis absorbs the pod axis first (inter-stage point-to-point is
    exactly the traffic that tolerates the slow inter-pod link — the paper's
    geo-distributed finding), then splits the data axis if more stages are
    requested.

    Args:
        devices_mesh: the (pod?, data, model) source mesh.
        n_stages: pipeline stages to carve out of (pod x data).
        stage_order: permutation of the pod blocks (one block per site,
            see ``core.plans.Placement.pod_permutation``) giving the
            stage→site assignment from the plan search — stage k runs on
            pod block ``stage_order[k]``, so the pipeline crosses the
            topology's links in the order the search priced, not in raw
            site numbering.
        stage_layers: per-stage layer counts from the TFLOP-weighted
            balancer (``core.plans.Placement.stage_layers``).  The device
            mesh itself does not depend on how layers are split, so this
            only validates the split's shape (one positive entry per
            stage — per *chunk* under an interleaved ``schedule``); the
            split — even or uneven — is realized by
            ``make_pipeline_loss`` (pad-and-mask, see
            ``validate_stages``).
        schedule: pipeline tick-order schedule the split belongs to
            (``core.costmodel.SCHEDULES``); interleaved schedules expect
            ``n_stages * v`` chunk entries in ``stage_layers``.  The
            device mesh itself is schedule-independent.

    Returns:
        A ``(stage, data, model)`` mesh.
    """
    _, virt = parse_schedule(schedule)
    if stage_layers is not None:
        layers = tuple(stage_layers)
        if len(layers) != n_stages * virt:
            raise ValueError(
                f"stage_layers {layers} has {len(layers)} entries for "
                f"n_stages={n_stages} x {virt} virtual ({schedule})")
        if any(l < 1 for l in layers):
            raise ValueError(f"every stage needs >= 1 layer, "
                             f"got {layers}")
    names = devices_mesh.axis_names
    shape = dict(zip(names, devices_mesh.devices.shape))
    pod = shape.get("pod", 1)
    data = shape.get("data", 1)
    model = shape.get("model", 1)
    if n_stages % pod != 0 and pod % n_stages != 0:
        raise ValueError(f"n_stages={n_stages} incompatible with pod={pod}")
    rest = n_stages // pod if n_stages >= pod else 1
    if data % rest != 0:
        raise ValueError(
            f"cannot split data={data} into {rest} pipeline sub-stages")
    devices = devices_mesh.devices
    if stage_order is not None:
        order = tuple(stage_order)
        if sorted(order) != list(range(pod)):
            raise ValueError(
                f"stage_order {order} is not a permutation of the "
                f"{pod} pod blocks")
        if "pod" in names:
            import numpy as np
            devices = np.take(devices, order, axis=names.index("pod"))
        elif order != (0,):
            raise ValueError("stage_order given but mesh has no pod axis")
    devs = devices.reshape(n_stages, (pod * data) // n_stages, model)
    return jax.sharding.Mesh(devs, (STAGE_AXIS, "data", "model"))


def stack_length(cfg, stack) -> int:
    """Length of the stacked layer axis (scan *groups* for hybrid).

    Args:
        cfg: model config (unused; kept for signature stability).
        stack: the stacked ``[L, ...]`` layer params pytree.

    Returns:
        The leading-axis length of the stack's leaves.
    """
    leaf = jax.tree.leaves(stack)[0]
    return leaf.shape[0]


def validate_stages(cfg, stack, n_stages: int,
                    stage_layers=None,
                    schedule: str = "gpipe") -> Optional[tuple]:
    """Check the layer stack can be cut into the schedule's chunks.

    GPipe/1F1B cut the stack into ``n_stages`` contiguous slices; an
    interleaved schedule with v virtual stages per device cuts it into
    ``n_stages * v`` chunks (chunk c running on stage ``c % n_stages``).

    Args:
        cfg: model config (names the stack in error messages).
        stack: the stacked ``[L, ...]`` layer params (groups for hybrid).
        n_stages: number of pipeline stages.
        stage_layers: optional per-chunk layer counts (a TFLOP-weighted
            split from ``core.costmodel.balanced_stage_layers``).  Must
            partition the stack; *uneven* splits are fine — they execute
            via the pad-and-mask stage construction in
            ``make_pipeline_loss`` (docs/topology-and-search.md
            §Balancing).
        schedule: pipeline tick-order schedule
            (``core.costmodel.SCHEDULES``) — fixes the chunk count.

    Returns:
        The normalized per-chunk split as a tuple when ``stage_layers``
        is given, else ``None`` for the single-chunk equal-block fast
        path (GPipe/1F1B even split) or the explicit even per-chunk
        tuple for interleaved schedules (whose chunks are non-contiguous
        on a stage, so they always take the gather path).
    """
    _, virt = parse_schedule(schedule)
    n_chunks = n_stages * virt
    L = stack_length(cfg, stack)
    if stage_layers is not None:
        layers = tuple(int(l) for l in stage_layers)
        if len(layers) != n_chunks or sum(layers) != L \
                or any(l < 1 for l in layers):
            raise ValueError(
                f"{cfg.name}: stage_layers {layers} does not partition the "
                f"{L}-entry stack into {n_chunks} {schedule} chunks")
        return layers
    if L % n_chunks != 0:
        raise ValueError(
            f"{cfg.name}: stack length {L} (groups for hybrid) not divisible "
            f"by {n_chunks} ({n_stages} stages, {schedule}) — pick a divisor "
            f"or pass an explicit stage_layers split (see DESIGN.md §4)")
    return None if virt == 1 else (L // n_chunks,) * n_chunks


def stage_gather_index(split, n_stages: int, virt: int = 1):
    """Gather index + validity mask realizing a per-chunk layer split.

    This is THE pad-and-mask convention: stage s holds its chunks
    (chunk ``c = k * n_stages + s``, ``k < virt``) back to back, each
    padded to the longest chunk by repeating its last layer; padded
    slots are identity-masked via the validity mask.  Both the pipeline
    runtime (``make_pipeline_loss``) and cross-plan checkpoint
    resharding (``repro.train.reshard.stage_view``) apply exactly this
    index, so a resharded layout is bit-for-bit what the runtime would
    have gathered.

    Args:
        split: per-chunk layer counts (``n_stages * virt`` entries, each
            >= 1, summing to the stack length).
        n_stages: pipeline stages.
        virt: virtual stages per device (interleaved schedules).

    Returns:
        ``(idx, layer_valid)`` numpy arrays of length
        ``n_stages * virt * max(split)``: the stack-row gather index in
        stage-major chunk order, and whether each padded slot holds a
        real (unrepeated) layer.
    """
    split = tuple(int(l) for l in split)
    if len(split) != n_stages * virt:
        raise ValueError(f"split {split} has {len(split)} entries for "
                         f"{n_stages} stages x {virt} virtual")
    max_l = max(split)
    offs = np.concatenate(([0], np.cumsum(split)))
    chunk_of = [k * n_stages + s
                for s in range(n_stages) for k in range(virt)]
    idx = np.concatenate([
        offs[c] + np.minimum(np.arange(max_l), split[c] - 1)
        for c in chunk_of]).astype(np.int32)
    layer_valid = np.concatenate(
        [np.arange(max_l) < split[c] for c in chunk_of])
    return idx, layer_valid


def banked_slot(stage: int, chunk: int, n_stages: int,
                virt: int = 1) -> bool:
    """Whether ``stage``'s output for local ``chunk`` is banked (kept as
    a finished microbatch) instead of sent on the ring — true only for
    the last stage's last chunk.  Shared by ``schedule_tables``'s
    arrival construction and the schedule race detector
    (``repro.analysis.schedlint``) so both sides agree on which sends
    must pair with receives.
    """
    return stage == n_stages - 1 and chunk == virt - 1


def schedule_tables(schedule: str, n_stages: int,
                    n_micro: int) -> Dict[str, np.ndarray]:
    """Static forward-slot tables driving the scheduled pipeline runner.

    Every schedule is a tick order: at tick t, stage s either runs the
    forward of one (chunk, microbatch) work item or idles (a slot the
    real schedule spends on a backward, which reverse-mode AD replays
    for us when the loss is differentiated — see docs/schedules.md).
    The tables are plain numpy (shape ``[n_stages, T]``), computed once
    at trace time:

      * GPipe: ``T = m + S - 1`` — stage s runs microbatch ``t - s``.
      * 1F1B (PipeDream-Flush): ``T = 2m + S - 2`` — stage s warms up
        with ``S - s`` forwards, then alternates forward/backward
        slots: forward i lands at ``t = s + i + max(0, i - (S-1-s))``.
      * interleaved: greedy list scheduling of the ``v * m`` per-stage
        work items (chunk c of microbatch i is ready one tick after
        chunk c-1 finished on the previous ring stage), priority
        ``(i + c, c)`` — earliest wave first, earlier chunk on ties.

    Args:
        schedule: schedule name (``core.costmodel.parse_schedule``).
        n_stages: pipeline stages S.
        n_micro: microbatches m.

    Returns:
        Dict of ``[S, T]`` arrays: ``active`` (bool — stage runs a
        forward this tick), ``chunk``/``mb`` (int32 — the local chunk
        index and microbatch of that forward), and the arrival tables
        ``arr_valid``/``arr_chunk``/``arr_mb`` describing the payload
        each stage's ppermute delivered at the *start* of tick t (sent
        by its ring predecessor at t-1): whether it is real, and which
        (local chunk, microbatch) inbox slot it fills.
    """
    kind, virt = parse_schedule(schedule)
    T_MAX = 1 << 30                         # "never done" sentinel
    S, m = n_stages, n_micro
    if kind == "gpipe":
        T = m + S - 1
        slots = [{s: (0, t - s) for s in range(S) if 0 <= t - s < m}
                 for t in range(T)]
    elif kind == "1f1b":
        T = 2 * m + S - 2
        slots = [dict() for _ in range(T)]
        for s in range(S):
            for i in range(m):
                t = s + i + max(0, i - (S - 1 - s))
                slots[t][s] = (0, i)
    else:                                   # interleaved, v >= 2
        done: Dict[tuple, int] = {}
        pending = {s: [(k, i) for k in range(virt) for i in range(m)]
                   for s in range(S)}
        slots = []
        t, left = 0, S * virt * m
        while left:
            row = {}
            for s in range(S):
                ready = []
                for k, i in pending[s]:
                    c = k * S + s
                    if c == 0 or done.get((c - 1, i), T_MAX) < t:
                        ready.append((i + c, c, k, i))
                if ready:
                    _, c, k, i = min(ready)
                    row[s] = (k, i)
                    done[(c, i)] = t
                    pending[s].remove((k, i))
                    left -= 1
            slots.append(row)
            t += 1
        T = len(slots)
    active = np.zeros((S, T), bool)
    chunk = np.zeros((S, T), np.int32)
    mb = np.zeros((S, T), np.int32)
    for t, row in enumerate(slots):
        for s, (k, i) in row.items():
            active[s, t], chunk[s, t], mb[s, t] = True, k, i
    # arrivals: what stage s's ppermute hands it at tick t is whatever
    # its ring predecessor computed (and did not bank) at tick t-1
    arr_valid = np.zeros((S, T), bool)
    arr_chunk = np.zeros((S, T), np.int32)
    arr_mb = np.zeros((S, T), np.int32)
    for s in range(S):
        prev = (s - 1) % S
        for t in range(1, T):
            if not active[prev, t - 1]:
                continue
            k, i = int(chunk[prev, t - 1]), int(mb[prev, t - 1])
            if banked_slot(prev, k, S, virt):
                continue                    # last chunk: banked, not sent
            arr_valid[s, t] = True
            arr_chunk[s, t] = k + (1 if prev == S - 1 else 0)
            arr_mb[s, t] = i
    return {"active": active, "chunk": chunk, "mb": mb,
            "arr_valid": arr_valid, "arr_chunk": arr_chunk,
            "arr_mb": arr_mb}


def make_pipeline_loss(model, mesh: Mesh, n_micro: int, *,
                       remat: bool = True, carrier_dtype=jnp.float32,
                       stage_layers=None, schedule: str = "gpipe"):
    """Build loss(params, batch) running the stacked layers as a
    pipelined forward over the mesh's ``stage`` axis.

    Schedules reorder work; they must not change math — every schedule
    runs the same layers on the same microbatches and the losses/grads
    agree bit-for-bit with the GPipe path and the unsharded reference
    (``tests/test_pipeline_schedules.py``).

    Args:
        model: the ``repro.models.Model`` whose stacked layers run
            staged; embedding/head/loss stay outside the manual region.
        mesh: a ``(stage, data, model)`` mesh from ``pipeline_mesh``.
        n_micro: microbatches the global batch is split into.
        remat: checkpoint each layer block (activation rematerialization).
        carrier_dtype: dtype of the inter-stage activation carriers
            (scan state / ppermute payload / bank buffer).  Defaults to
            fp32 because the XLA *CPU* SPMD partitioner CHECK-fails
            ("Invalid binary instruction opcode copy") when transposing
            the pipeline with bf16 carriers; the stage compute itself
            still runs in the model dtype.  On real TPU this can be set
            to bf16 to halve inter-stage ppermute bytes.
        stage_layers: optional per-stage (per-chunk under interleaved)
            layer counts from a ``core.plans.Placement`` — validated
            against the stack (see ``validate_stages``).  Uneven splits
            execute via pad-and-mask: every chunk's layer slice is
            gathered and padded to the longest chunk and the padded
            slots are identity-masked inside ``model.run_stack`` (zero
            aux, activations pass through unchanged), so a
            TFLOP-weighted heterogeneous split runs with the same
            equal-block stage sharding.
        schedule: pipeline tick order (``core.costmodel.SCHEDULES``,
            docs/schedules.md).  ``"gpipe"`` keeps the classic
            ``n_micro + n_stages - 1``-tick path; ``"1f1b"`` and
            ``"interleaved"`` run the generalized scheduled runner —
            the same ppermute ring driven by ``schedule_tables``, with
            a per-(chunk, microbatch) inbox holding activations across
            the slots the real schedule spends on backwards (which
            reverse-mode AD replays here).

    Returns:
        ``loss_fn(params, batch) -> (loss, metrics)``.
    """
    cfg = model.cfg
    n_stages = mesh.shape[STAGE_AXIS]
    kind, virt = parse_schedule(schedule)
    # Manual axes of the pipeline region.  The stage axis always is; on
    # jax 0.4.x — whose SPMD partitioner CHECK-fails on partial-auto
    # shard_map (repro.compat.NATIVE_SHARD_MAP, docs/architecture.md) —
    # size-1 auto axes are promoted to manual so a degenerate
    # (stage, 1, 1) mesh compiles as a fully-manual region, which that
    # partitioner handles fine.  A size-1 axis is unsharded either way,
    # so the promotion never changes semantics.
    manual = {STAGE_AXIS}
    if not NATIVE_SHARD_MAP:
        manual |= {a for a in mesh.axis_names
                   if a != STAGE_AXIS and mesh.shape[a] == 1}

    def loss_fn(params, batch):
        x, positions, _ = model._embed_inputs(params, batch)
        enc_out = model._encode(params, batch) if cfg.family == "encdec" \
            else None
        B, S, d = x.shape
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        xm = x.reshape(n_micro, mb, S, d).astype(carrier_dtype)
        xm = jax.lax.with_sharding_constraint(
            xm, P(None, "data", None, None))
        # every microbatch keeps its own position rows (packed/ragged
        # batches have per-example positions, so slicing the first
        # microbatch's rows for all of them would be wrong)
        pos_m = positions.reshape(n_micro, mb, S)
        enc_mb = jnp.zeros((), x.dtype) if enc_out is None else \
            enc_out.reshape(n_micro, mb, *enc_out.shape[1:])
        stack = params["layers"]
        split = validate_stages(cfg, stack, n_stages, stage_layers,
                                schedule=schedule)
        layer_valid = None
        if split is not None:
            # per-chunk gather realizing Placement.stage_layers
            # (stage_gather_index — the shared pad-and-mask convention):
            # padded slots are masked to identity (and zero aux) inside
            # run_stack, so the where() never sees uninitialized params.
            # virt == 1 is PR 3's per-stage gather unchanged.
            idx, valid = stage_gather_index(split, n_stages, virt)
            stack = jax.tree.map(
                lambda leaf: jnp.take(leaf, jnp.asarray(idx), axis=0),
                stack)
            layer_valid = jnp.asarray(valid)
        shared = params.get("shared")
        if shared is None:
            shared = jnp.zeros(())

        # in_specs: only the manual (stage) axis is mentioned; data/model
        # sharding of the same arrays stays in auto-SPMD land.
        stack_spec = jax.tree.map(lambda _: P(STAGE_AXIS), stack)
        mask_args = () if layer_valid is None else (layer_valid,)
        mask_specs = () if layer_valid is None else (P(STAGE_AXIS),)
        # stage id as a stage-sharded input rather than lax.axis_index:
        # axis_index lowers to partition-id, which the jax-0.4.x SPMD
        # partitioner rejects inside partial-auto shard_map regions.
        stage_ids = jnp.arange(n_stages, dtype=jnp.int32)
        # per-stage local chunk length (layers a single run_stack call
        # scans): the padded chunk under a gather, the equal block else
        chunk_len = max(split) if split is not None \
            else stack_length(cfg, params["layers"]) // n_stages

        @partial(jax.shard_map, mesh=mesh, axis_names=manual,
                 in_specs=(P(STAGE_AXIS), stack_spec, *mask_specs,
                           P(), P(), P(), P()),
                 out_specs=P(STAGE_AXIS), check_vma=False)
        def run_pipeline(stage_ids, stack_local, *rest):
            if layer_valid is None:
                valid_local = None
                xm, pos_m, enc_mb, shared = rest
            else:
                valid_local, xm, pos_m, enc_mb, shared = rest
            stage = stage_ids[0]
            T = n_micro + n_stages - 1
            state0 = jnp.zeros_like(xm[0])
            buf0 = jnp.zeros_like(xm)

            def run_stage(inp, pos, mb_idx):
                kwargs = {}
                if cfg.family == "encdec":
                    kwargs["enc_out"] = enc_mb[mb_idx]
                out, aux = model.run_stack(
                    stack_local, inp.astype(model.compute_dtype), pos,
                    shared=(shared if cfg.family == "hybrid" else None),
                    remat=remat, layer_valid=valid_local, **kwargs)
                return out.astype(carrier_dtype), aux.astype(jnp.float32)

            def tick(carry, t):
                state, buf = carry
                mb_idx = jnp.clip(t - stage, 0, n_micro - 1)
                # a stage only holds a real microbatch for the ticks
                # t in [stage, stage + n_micro): warm-up and drain ticks
                # skip the stack entirely instead of burning a full
                # forward on a stale microbatch and polluting the aux sum
                active = jnp.logical_and(t >= stage, t - stage < n_micro)
                inp = jnp.where(stage == 0, xm[mb_idx], state)
                out, aux = jax.lax.cond(
                    active,
                    lambda op: run_stage(*op),
                    lambda op: (op[0], jnp.float32(0.0)),
                    (inp, pos_m[mb_idx], mb_idx))
                # last stage banks its finished microbatch t-(S-1)
                done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                valid = (t - (n_stages - 1) >= 0)
                slot = jax.lax.dynamic_update_index_in_dim(
                    buf, out.astype(buf.dtype), done_idx, 0)
                buf = jnp.where(valid, slot, buf)
                # hand activations to the next stage (p2p, ring)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                state = jax.lax.ppermute(out, STAGE_AXIS, perm)
                return (state, buf), aux

            (_, buf), auxs = jax.lax.scan(
                tick, (state0, buf0), jnp.arange(T))
            # leading (length-1 per shard) stage axis; caller slices [-1]
            return buf[None], jnp.sum(auxs)[None]

        # 1F1B / interleaved: the generalized scheduled runner.  Same
        # ppermute ring, but the tick order comes from static
        # schedule_tables and arrivals land in a per-(chunk, microbatch)
        # inbox — a stage may consume an activation several ticks after
        # it arrived (the slots the real schedule spends on backwards).
        tables = None
        if not (kind == "gpipe" and virt == 1):
            tables = {name: jnp.asarray(arr) for name, arr in
                      schedule_tables(schedule, n_stages, n_micro).items()}
        tbl_args = () if tables is None else (
            tables["active"], tables["chunk"], tables["mb"],
            tables["arr_valid"], tables["arr_chunk"], tables["arr_mb"])
        tbl_specs = tuple(P(STAGE_AXIS) for _ in tbl_args)

        @partial(jax.shard_map, mesh=mesh, axis_names=manual,
                 in_specs=(P(STAGE_AXIS), stack_spec, *mask_specs,
                           *tbl_specs, P(), P(), P(), P()),
                 out_specs=P(STAGE_AXIS), check_vma=False)
        def run_scheduled(stage_ids, stack_local, *rest):
            if layer_valid is None:
                valid_local = None
            else:
                valid_local, rest = rest[0], rest[1:]
            (active_t, chunk_t, mb_t, arrv_t, arrk_t, arri_t,
             xm, pos_m, enc_mb, shared) = rest
            stage = stage_ids[0]
            # stage-sharded [1, T] table rows -> local [T]
            active_t, chunk_t, mb_t = active_t[0], chunk_t[0], mb_t[0]
            arrv_t, arrk_t, arri_t = arrv_t[0], arrk_t[0], arri_t[0]
            T = active_t.shape[0]
            state0 = jnp.zeros_like(xm[0])
            inbox0 = jnp.zeros((virt,) + xm.shape, xm.dtype)
            buf0 = jnp.zeros_like(xm)

            def run_chunk(inp, pos, mb_idx, k):
                sl = lambda leaf: jax.lax.dynamic_slice_in_dim(
                    leaf, k * chunk_len, chunk_len, 0)
                stack_k = jax.tree.map(sl, stack_local)
                valid_k = None if valid_local is None else sl(valid_local)
                kwargs = {}
                if cfg.family == "encdec":
                    kwargs["enc_out"] = enc_mb[mb_idx]
                out, aux = model.run_stack(
                    stack_k, inp.astype(model.compute_dtype), pos,
                    shared=(shared if cfg.family == "hybrid" else None),
                    remat=remat, layer_valid=valid_k, **kwargs)
                return out.astype(carrier_dtype), aux.astype(jnp.float32)

            def tick(carry, t):
                recv, inbox, buf = carry
                # 1. stash the ppermute payload that arrived this tick
                #    (its (chunk, microbatch) slot is static knowledge —
                #    the arrival tables mirror the sender's slot tables)
                stash = jax.lax.dynamic_update_slice(
                    inbox, recv[None, None].astype(inbox.dtype),
                    (arrk_t[t], arri_t[t]) + (0,) * recv.ndim)
                inbox = jnp.where(arrv_t[t], stash, inbox)
                # 2. this tick's work item, if any
                k, i, active = chunk_t[t], mb_t[t], active_t[t]
                first_chunk = jnp.logical_and(stage == 0, k == 0)
                inbox_in = jax.lax.dynamic_slice(
                    inbox, (k, i) + (0,) * state0.ndim,
                    (1, 1) + state0.shape)[0, 0]
                inp = jnp.where(first_chunk, xm[i], inbox_in)
                out, aux = jax.lax.cond(
                    active,
                    lambda op: run_chunk(*op),
                    lambda op: (op[0], jnp.float32(0.0)),
                    (inp, pos_m[i], i, k))
                # 3. last chunk of the last stage banks its microbatch
                done = jnp.logical_and(
                    active, jnp.logical_and(stage == n_stages - 1,
                                            k == virt - 1))
                slot = jax.lax.dynamic_update_index_in_dim(
                    buf, out.astype(buf.dtype), i, 0)
                buf = jnp.where(done, slot, buf)
                # 4. ring handoff (receivers ignore ticks their arrival
                #    table marks invalid)
                perm = [(a, (a + 1) % n_stages) for a in range(n_stages)]
                recv = jax.lax.ppermute(out, STAGE_AXIS, perm)
                return (recv, inbox, buf), aux

            (_, _, buf), auxs = jax.lax.scan(
                tick, (state0, inbox0, buf0), jnp.arange(T))
            return buf[None], jnp.sum(auxs)[None]

        if tables is None:
            buf_staged, aux_staged = run_pipeline(
                stage_ids, stack, *mask_args, xm, pos_m, enc_mb, shared)
        else:
            buf_staged, aux_staged = run_scheduled(
                stage_ids, stack, *mask_args, *tbl_args,
                xm, pos_m, enc_mb, shared)
        hidden = buf_staged[-1].reshape(B, S, d).astype(model.compute_dtype)
        # every stage owns distinct layers, so the model's aux (MoE
        # load-balance) sums over stages; each stage accumulated one
        # batch-invariant aux per microbatch, so the microbatch mean is
        # what matches the reference full-batch aux
        aux = jnp.sum(aux_staged) / n_micro
        logits = model._head(params, hidden)
        from repro.models.model import lm_loss
        return lm_loss(cfg, logits, batch, aux)

    return loss_fn
