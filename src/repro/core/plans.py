"""The paper's four pretraining techniques as first-class execution plans.

    Data      — pure data parallelism: params replicated, batch split,
                gradient all-reduce (paper §III-A).
    ZeRO2     — data parallelism with gradients + optimizer state sharded
                over the data axes: reduce-scatter grads, shard-local AdamW,
                all-gather updated params (paper §III-B, DeepSpeed ZeRO-2).
    Shard     — Alpa's intra-operator / SPMD parallelism: weights sharded on
                their logical axes over the ``model`` mesh axis, batch over
                the data axes (paper §III-B "Shard").
    Pipeshard — Alpa's combined inter+intra-operator parallelism: the layer
                stack is cut into stages over a ``stage`` mesh axis,
                microbatches are pipelined between stages with ppermute,
                and Shard rules apply inside each stage (paper §III-B).

A plan turns (model params, mesh) into in/out shardings for jit and an
update rule; the same four names are what Algorithm 1 selects between.
The two beyond-paper plans (``shard_zero``, ``fsdp``) are priced by the
technique cost registry too (``core.costmodel.TECHNIQUE_SPECS``,
docs/cost-model.md), so the search can recommend every plan this
module can execute.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import sharding as shardlib
from repro.core.sharding import AxisMap

# Mesh axis vocabulary: production meshes use ("pod",)? + ("data", "model");
# Pipeshard views reshape to ("stage", "data", "model").
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"
STAGE_AXIS = "stage"


@dataclass(frozen=True)
class MeshSpec:
    """Device-free stand-in for a ``jax.sharding.Mesh``: axis names and
    sizes only, no devices.

    Every ``Plan`` spec method (``param_specs`` / ``opt_specs`` /
    ``batch_spec`` / ``cache_spec``) consults only ``mesh.axis_names``
    and ``mesh.shape``, so the static plan verifier
    (``repro.analysis.planlint``) can compute the exact shardings the
    launch layer would build — for every candidate the search emits —
    without constructing a single device.
    """
    axes: Tuple[Tuple[str, int], ...]

    @classmethod
    def of(cls, shape: Sequence[int],
           names: Sequence[str]) -> "MeshSpec":
        if len(shape) != len(names):
            raise ValueError(f"shape {tuple(shape)} vs axis names "
                             f"{tuple(names)}")
        return cls(tuple(zip(names, (int(n) for n in shape))))

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a for a, _ in self.axes)

    @property
    def shape(self) -> Dict[str, int]:
        return dict(self.axes)

    @property
    def size(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n


@dataclass(frozen=True)
class Plan:
    """A hardware-independent execution plan: how params, optimizer
    state, and the batch are sharded over a mesh, keyed by the paper's
    technique names (see ``PLANS`` / ``get_plan``).

    Attributes:
        name: plan name (``PLANS`` key).
        shards_weights: tensor parallelism over the ``model`` axis.
        zero_sharding: grads/opt-state sharded over the data axes.
        pipeline: stage axis + microbatch pipelining (Pipeshard).
        fsdp: params ALSO sharded over the data axes (ZeRO-3; beyond
            the paper).
    """
    name: str
    shards_weights: bool        # tensor parallelism over `model`
    zero_sharding: bool         # grads/opt-state sharded over data axes
    pipeline: bool              # stage axis + microbatch pipelining
    fsdp: bool = False          # params ALSO sharded over the data axes
    #   (ZeRO-3 / FSDP: beyond-paper — the paper's ZeRO2 stops at grads
    #   + optimizer state; this is what a 405B model actually needs)

    # ------------------------------------------------------------- #
    def mesh_axes(self, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
        names = mesh.axis_names
        data = tuple(a for a in names if a in DATA_AXES)
        model = tuple(a for a in names if a == MODEL_AXIS)
        stage = tuple(a for a in names if a == STAGE_AXIS)
        return {"data": data, "model": model, "stage": stage}

    def batch_axes(self, mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
        """Mesh axes the batch dim is split over, greedily folding in axes
        that still divide the batch.  Pure data parallelism also folds in
        the model axis — the paper's Data plan uses *all* GPUs as replicas
        when it can."""
        ax = self.mesh_axes(mesh)
        cand = ax["data"] if (self.shards_weights or self.pipeline) \
            else ax["data"] + ax["model"]
        axes, prod = [], 1
        for a in cand:
            n = mesh.shape[a]
            if global_batch > 0 and global_batch % (prod * n) == 0:
                axes.append(a)
                prod *= n
        return tuple(axes)

    # ------------------------------------------------------------- #
    def axis_map(self, mesh: Mesh) -> AxisMap:
        """logical dim -> mesh axis mapping for parameters."""
        if not self.shards_weights and not self.pipeline:
            return AxisMap()                      # fully replicated params
        # NB deliberately NO head_dim/embed_d secondaries: sharding the
        # contraction dim of q/k or of the unembedding all-reduces every
        # attention score block / the full logits — measured 76 s of
        # collective time per step for llama3.2-3b (EXPERIMENTS.md §Perf).
        # Non-divisible heads/vocab fall back to replication instead.
        m = AxisMap(
            vocab=MODEL_AXIS, heads=MODEL_AXIS, kv_heads=MODEL_AXIS,
            mlp=MODEL_AXIS, expert=MODEL_AXIS, d_inner=MODEL_AXIS,
        )
        if self.pipeline:
            m["__stack__"] = STAGE_AXIS
        return m

    def param_specs(self, params_or_shapes, cfg: ModelConfig, mesh: Mesh):
        specs = shardlib.param_specs(params_or_shapes, self.axis_map(mesh),
                                     cfg.family, dict(mesh.shape))
        if not self.fsdp:
            return specs
        axes = self.mesh_axes(mesh)["data"]
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return jax.tree.map(
            lambda leaf, spec: shardlib.add_fsdp_axis(leaf, spec, axes, size),
            params_or_shapes, specs,
            is_leaf=lambda x: isinstance(x, P))

    def param_shardings(self, params_or_shapes, cfg: ModelConfig, mesh: Mesh):
        return shardlib.named_shardings(
            self.param_specs(params_or_shapes, cfg, mesh), mesh)

    def opt_specs(self, params_or_shapes, cfg: ModelConfig, mesh: Mesh):
        """Optimizer-state (and gradient reduce-scatter) specs.

        FSDP: optimizer state lives exactly on the param shards (grads
        reduce-scatter straight into the update layout — no resharding).
        ZeRO2: params stay replicated/TP-sharded, m/v spread over the data
        axes on the largest divisible dim."""
        if self.fsdp or not self.zero_sharding:
            return self.param_specs(params_or_shapes, cfg, mesh)
        axes = self.mesh_axes(mesh)["data"]
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return shardlib.zero_specs(params_or_shapes, axes, size)

    # ------------------------------------------------------------- #
    def batch_spec(self, batch, mesh: Mesh) -> Any:
        """Input batch shardings: batch dim over the plan's batch axes."""
        def leaf_spec(leaf):
            gb = leaf.shape[0]
            axes = self.batch_axes(mesh, gb)
            if not axes:
                return P()
            return P(axes if len(axes) > 1 else axes[0])
        return jax.tree.map(leaf_spec, batch)

    def batch_shardings(self, batch, mesh: Mesh):
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            self.batch_spec(batch, mesh),
                            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------- #
    def cache_spec(self, cache, cfg: ModelConfig, mesh: Mesh,
                   batch_size: int) -> Any:
        """Decode-cache shardings: batch over data axes; under Shard the
        long (sequence / latent) cache dim goes over `model` so a 32k–500k
        KV cache fits — context-parallel decode.  The batch dim is located
        by size (caches carry layer/group stack prefixes of varying depth)."""
        data = self.mesh_axes(mesh)["data"]
        use_model = self.shards_weights or self.pipeline
        d_ax = data if len(data) > 1 else (data[0] if data else None)
        model_n = mesh.shape.get(MODEL_AXIS, 1)

        def leaf_spec(path, leaf):
            ps = shardlib._path_str(path)
            if leaf.ndim == 0 or ps.endswith("index"):
                return P()
            entries: list = [None] * leaf.ndim
            b_dim = next((i for i, s in enumerate(leaf.shape)
                          if s == batch_size), None)
            if b_dim is not None and d_ax is not None \
                    and batch_size % np.prod([mesh.shape[a] for a in data]) == 0:
                entries[b_dim] = d_ax
            # the long dim right after batch (cache seq / latent rows)
            if use_model and b_dim is not None and leaf.ndim > b_dim + 1 \
                    and leaf.shape[b_dim + 1] >= model_n \
                    and leaf.shape[b_dim + 1] % model_n == 0:
                entries[b_dim + 1] = MODEL_AXIS
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)

        return jax.tree_util.tree_map_with_path(leaf_spec, cache)

    def cache_shardings(self, cache, cfg: ModelConfig, mesh: Mesh,
                        batch_size: int):
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            self.cache_spec(cache, cfg, mesh, batch_size),
                            is_leaf=lambda x: isinstance(x, P))


@dataclass(frozen=True)
class Placement:
    """Where (and how) a plan runs on an N-site topology
    (core/topology.py).

    Produced by ``core.search.PlanSearch`` and consumed by the launch
    layer (``launch.mesh.make_topology_mesh`` +
    ``core.pipeline.pipeline_mesh``); see docs/topology-and-search.md.

    Attributes:
        sites: the participating site subset (topology site indices).
        stage_order: for pipeline plans, the stage→site assignment —
            stages follow this order, not the raw site numbering, so an
            asymmetric-link topology can be crossed in its cheapest order
            (DESIGN.md §5).  ``None`` means stages follow ``sites`` order
            (non-pipeline plans always leave it ``None``).
        stage_layers: for pipeline plans, per-stage layer counts from the
            TFLOP-weighted balancer (``core.costmodel
            .balanced_stage_layers``), in stage order.  ``None`` means the
            even split.  Under an interleaved schedule the entries are
            per virtual-stage *chunk* (``n_stages * v`` of them, chunk c
            running on stage ``c % n_stages``).
        schedule: for pipeline plans, the tick-order schedule the
            runtime executes and the cost model priced —
            ``core.costmodel.SCHEDULES`` (docs/schedules.md).
            Non-pipeline plans keep the ``"gpipe"`` default, which is
            ignored.
    """
    sites: Tuple[int, ...]
    stage_order: Optional[Tuple[int, ...]] = None
    stage_layers: Optional[Tuple[int, ...]] = None
    schedule: str = "gpipe"

    def __post_init__(self):
        from repro.core.costmodel import parse_schedule
        _, v = parse_schedule(self.schedule)   # validates the name too
        if self.stage_order is not None and \
                sorted(self.stage_order) != sorted(self.sites):
            raise ValueError(
                f"stage_order {self.stage_order} is not a permutation "
                f"of sites {self.sites}")
        if self.stage_layers is not None:
            if len(self.stage_layers) != self.n_stages * v:
                raise ValueError(
                    f"stage_layers {self.stage_layers} has "
                    f"{len(self.stage_layers)} entries for "
                    f"{self.n_stages} stages x {v} virtual "
                    f"({self.schedule})")
            if any(l < 1 for l in self.stage_layers):
                raise ValueError(f"every stage needs >= 1 layer, got "
                                 f"{self.stage_layers}")

    @property
    def n_stages(self) -> int:
        """Number of pipeline stages (one per participating site)."""
        return len(self.stage_order or self.sites)

    def pod_permutation(self) -> Tuple[int, ...]:
        """Order of the mesh's pod blocks (one per site, in ``sites``
        order) realizing the stage order — what pipeline_mesh consumes.

        Returns:
            Tuple ``p`` with ``p[k]`` = index into ``sites`` of the site
            that runs stage ``k``.
        """
        if self.stage_order is None:
            return tuple(range(len(self.sites)))
        pos = {s: k for k, s in enumerate(self.sites)}
        return tuple(pos[s] for s in self.stage_order)


PLANS: Dict[str, Plan] = {
    "data": Plan("data", shards_weights=False, zero_sharding=False,
                 pipeline=False),
    "zero2": Plan("zero2", shards_weights=False, zero_sharding=True,
                  pipeline=False),
    "shard": Plan("shard", shards_weights=True, zero_sharding=False,
                  pipeline=False),
    # zero-sharded optimizer states compose with tensor parallelism the same
    # way Alpa's shard plan folds in the ZeRO optimizer (paper §III-B)
    "shard_zero": Plan("shard_zero", shards_weights=True, zero_sharding=True,
                       pipeline=False),
    "pipeshard": Plan("pipeshard", shards_weights=True, zero_sharding=False,
                      pipeline=True),
    # beyond-paper: full FSDP/ZeRO-3 — params sharded over data axes too,
    # gathered per layer inside the scan (memory <-> all-gather tradeoff;
    # what makes llama3-405b trainable on 256 chips, EXPERIMENTS.md §Perf H2)
    "fsdp": Plan("fsdp", shards_weights=True, zero_sharding=True,
                 pipeline=False, fsdp=True),
}


def get_plan(name: str) -> Plan:
    """Look up an execution plan by technique name.

    Args:
        name: a ``PLANS`` key (``data``, ``zero2``, ``shard``,
            ``shard_zero``, ``pipeshard``, ``fsdp``).

    Returns:
        The immutable ``Plan``.

    Raises:
        KeyError: unknown plan name (message lists the options).
    """
    try:
        return PLANS[name]
    except KeyError:
        raise KeyError(f"unknown plan {name!r}; available {sorted(PLANS)}") \
            from None
