"""Algorithm 1 (paper §IV-H): probe-based pretraining technique selection.

Given an SLM M and two VMs (V1, V2), probe each technique for ε epochs and
pick the one maximizing measured training performance (TFLOP/s), with a
user threshold δ controlling how much better Pipeshard-on-everything must
be before it wins over the best single-VM plan; ZeRO2-on-everything is the
memory-pressure fallback.

``select_technique`` is now a thin wrapper over the generalized N-site
``core.search.algorithm1_select`` — the two-VM algorithm is its N=2
special case, and ``core.search.PlanSearch`` explores the full
(technique × site-subset × stage-order) space beyond it (DESIGN.md §5).

Probes are pluggable: ``CostModelProber`` prices them analytically (this is
how benchmarks reproduce the paper's conclusions), while ``LiveProber``
actually runs ε epochs through repro.train.loop — the shape the algorithm
has on a real cluster.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.costmodel import ClusterLike, Workload, as_topology, \
    avg_tflops


class Prober(Protocol):
    def probe(self, technique: str, vms: Optional[List[int]]
              ) -> Optional[float]:
        """Avg TFLOP/s over ε epochs; None/0 on failure (OOM)."""


@dataclass
class CostModelProber:
    wl: Workload
    cluster: ClusterLike              # legacy two-VM Cluster or a Topology

    @property
    def n_sites(self) -> int:
        return as_topology(self.cluster).n_sites

    def probe(self, technique: str, vms: Optional[List[int]]
              ) -> Optional[float]:
        return avg_tflops(technique, self.wl, self.cluster, vms)


@dataclass
class LiveProber:
    """Runs ε epochs of real training per probe (used on live hardware;
    exercised in tests with a tiny model on host devices)."""
    run_fn: Callable[[str, Optional[List[int]]], Optional[float]]
    n_sites: int = 2

    def probe(self, technique, vms):
        try:
            return self.run_fn(technique, vms)
        except Exception:
            return None


@dataclass
class Selection:
    technique: str
    vms: Optional[List[int]]          # None => infeasible everywhere
    probes: Dict[str, Optional[float]]

    @property
    def feasible(self) -> bool:
        return self.vms is not None or self.technique == "none"


def select_technique(prober: Prober, *, delta: float = 0.1) -> Selection:
    """Algorithm 1, lines 1-36 — the N=2 (or prober-declared N) case of
    ``core.search.algorithm1_select``."""
    from repro.core.search import algorithm1_select
    n_sites = getattr(prober, "n_sites", 2)
    return algorithm1_select(prober.probe, n_sites, delta=delta)
