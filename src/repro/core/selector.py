"""Algorithm 1 (paper §IV-H): probe-based pretraining technique selection.

Given an SLM M and two VMs (V1, V2), probe each technique for ε epochs and
pick the one maximizing measured training performance (TFLOP/s), with a
user threshold δ controlling how much better Pipeshard-on-everything must
be before it wins over the best single-VM plan; ZeRO2-on-everything is the
memory-pressure fallback.

Probes are pluggable: ``CostModelProber`` prices them analytically (this is
how benchmarks reproduce the paper's conclusions), while ``LiveProber``
actually runs ε epochs through repro.train.loop — the shape the algorithm
has on a real cluster.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.costmodel import Cluster, Workload, avg_tflops


class Prober(Protocol):
    def probe(self, technique: str, vms: Optional[List[int]]
              ) -> Optional[float]:
        """Avg TFLOP/s over ε epochs; None/0 on failure (OOM)."""


@dataclass
class CostModelProber:
    wl: Workload
    cluster: Cluster

    def probe(self, technique: str, vms: Optional[List[int]]
              ) -> Optional[float]:
        return avg_tflops(technique, self.wl, self.cluster, vms)


@dataclass
class LiveProber:
    """Runs ε epochs of real training per probe (used on live hardware;
    exercised in tests with a tiny model on host devices)."""
    run_fn: Callable[[str, Optional[List[int]]], Optional[float]]

    def probe(self, technique, vms):
        try:
            return self.run_fn(technique, vms)
        except Exception:
            return None


@dataclass
class Selection:
    technique: str
    vms: Optional[List[int]]          # None => infeasible everywhere
    probes: Dict[str, Optional[float]]

    @property
    def feasible(self) -> bool:
        return self.vms is not None or self.technique == "none"


def select_technique(prober: Prober, *, delta: float = 0.1) -> Selection:
    """Algorithm 1, lines 1-36."""
    probes: Dict[str, Optional[float]] = {}

    def run(tech: str, vms: Optional[List[int]], key: str) -> float:
        perf = prober.probe(tech, vms)
        probes[key] = perf
        return perf if perf else 0.0          # line convention: 0 on failure

    # lines 1-2: Pipeshard on V1 ∪ V2
    t_p = run("pipeshard", None, "pipeshard@both")
    # lines 3-10: Data and Shard on each VM separately
    t_d1 = run("data", [0], "data@V1")
    t_s1 = run("shard", [0], "shard@V1")
    t_d2 = run("data", [1], "data@V2")
    t_s2 = run("shard", [1], "shard@V2")
    # line 11
    t_z = max(t_d1, t_d2, t_s1, t_s2)

    # lines 12-13: Pipeshard wins by more than δ
    if t_z > 0 and (t_p - t_z) / t_z > delta:
        return Selection("pipeshard", [0, 1], probes)
    # lines 14-27: a single-VM plan wins by more than δ
    if t_p > 0 and (t_z - t_p) / t_p > delta:
        if max(t_d1, t_s1) >= max(t_d2, t_s2):
            return Selection("data" if t_d1 >= t_s1 else "shard", [0], probes)
        return Selection("data" if t_d2 >= t_s2 else "shard", [1], probes)
    # tie region but something ran: prefer the absolute best measured
    if t_p > 0 or t_z > 0:
        if t_p >= t_z:
            return Selection("pipeshard", [0, 1], probes)
        if max(t_d1, t_s1) >= max(t_d2, t_s2):
            return Selection("data" if t_d1 >= t_s1 else "shard", [0], probes)
        return Selection("data" if t_d2 >= t_s2 else "shard", [1], probes)
    # lines 29-35: ZeRO2 fallback on the whole cluster
    t_z2 = run("zero2", None, "zero2@both")
    if t_z2 > 0:
        return Selection("zero2", [0, 1], probes)
    return Selection("none", None, probes)    # need more GPU memory
