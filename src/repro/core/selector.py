"""Algorithm 1 (paper §IV-H): probe-based pretraining technique selection.

Given an SLM M and two VMs (V1, V2), probe each technique for ε epochs and
pick the one maximizing measured training performance (TFLOP/s), with a
user threshold δ controlling how much better Pipeshard-on-everything must
be before it wins over the best single-VM plan; ZeRO2-on-everything is the
memory-pressure fallback.

``select_technique`` is now a thin wrapper over the generalized N-site
``core.search.algorithm1_select`` — the two-VM algorithm is its N=2
special case, and ``core.search.PlanSearch`` explores the full
(technique × site-subset × stage-order) space beyond it (DESIGN.md §5).

Probes are pluggable: ``CostModelProber`` prices them analytically (this is
how benchmarks reproduce the paper's conclusions), while ``LiveProber``
actually runs ε epochs through repro.train.loop — the shape the algorithm
has on a real cluster.  A probe receives the full ``core.plans.Placement``
(site subset + stage order + per-stage layer split), so a live probe can
realize exactly the candidate the search priced —
``launch.mesh.make_topology_mesh`` → ``core.pipeline.pipeline_mesh
(stage_order=…, stage_layers=…)``.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.costmodel import ClusterLike, Workload, as_topology, \
    avg_tflops
from repro.core.plans import Placement

log = logging.getLogger(__name__)


class Prober(Protocol):
    def probe(self, technique: str, placement: Optional[Placement]
              ) -> Optional[float]:
        """Avg TFLOP/s over ε epochs; None/0 on failure (OOM).
        ``placement=None`` means all sites in default order."""


@dataclass
class CostModelProber:
    wl: Workload
    cluster: ClusterLike              # legacy two-VM Cluster or a Topology
    # optional measured-rate overlay (repro.calib.overlay.Calibration);
    # None and the identity overlay price bit-for-bit the analytic model
    calibration: Optional[object] = None

    @property
    def n_sites(self) -> int:
        return as_topology(self.cluster).n_sites

    def probe(self, technique: str, placement: Optional[Placement]
              ) -> Optional[float]:
        if placement is None:
            return avg_tflops(technique, self.wl, self.cluster, None,
                              calibration=self.calibration)
        return avg_tflops(technique, self.wl, self.cluster,
                          list(placement.sites),
                          stage_order=placement.stage_order,
                          stage_layers=placement.stage_layers,
                          schedule=placement.schedule,
                          calibration=self.calibration)


# Failure modes that mean "this plan cannot run on this hardware" — the
# OOM/'×' outcome Algorithm 1 expects — as opposed to a programming error.
# XLA surfaces both through XlaRuntimeError, so the status/message is the
# only discriminator: resource exhaustion, allocation failure, or a
# backend that cannot compile the requested collective.
_INFEASIBLE_MARKERS = (
    "RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
    "Unable to allocate", "Allocation failure", "UNIMPLEMENTED",
)


def probe_infeasible(exc: BaseException) -> bool:
    """True when ``exc`` is a resource/compile failure a probe may treat
    as 'technique infeasible here' (returning None); everything else —
    TypeError, bad mesh shapes, assertion failures — is a bug in the
    probe and must propagate."""
    if isinstance(exc, MemoryError):
        return True
    if type(exc).__name__ in ("XlaRuntimeError", "JaxRuntimeError"):
        return any(m in str(exc) for m in _INFEASIBLE_MARKERS)
    return False


@dataclass
class LiveProber:
    """Runs ε epochs of real training per probe (used on live hardware;
    exercised in tests with a tiny model on host devices).

    ``run_fn(technique, placement)`` receives the full
    ``core.plans.Placement`` so it can build the exact mesh the search
    priced.  Only resource/compile failures (``probe_infeasible``) are
    treated as the paper's OOM outcome; programming errors re-raise —
    silently mapping a TypeError to an OOM-style None probe would corrupt
    Algorithm 1's selection.
    """
    run_fn: Callable[[str, Optional[Placement]], Optional[float]]
    n_sites: int = 2

    def probe(self, technique: str, placement: Optional[Placement]
              ) -> Optional[float]:
        try:
            return self.run_fn(technique, placement)
        except Exception as e:
            if probe_infeasible(e):
                log.warning("probe %s@%s infeasible: %s",
                            technique, placement, e)
                return None
            log.error("probe %s@%s failed with a non-resource error "
                      "(%s) — re-raising, not treating as OOM",
                      technique, placement, type(e).__name__)
            raise


@dataclass
class Selection:
    technique: str
    vms: Optional[List[int]]          # None => infeasible everywhere
    probes: Dict[str, Optional[float]]

    @property
    def feasible(self) -> bool:
        return self.vms is not None or self.technique == "none"


def select_technique(prober: Prober, *, delta: float = 0.1,
                     extended: bool = False) -> Selection:
    """Algorithm 1, lines 1-36 — the N=2 (or prober-declared N) case of
    ``core.search.algorithm1_select``.

    Args:
        prober: probe provider (``CostModelProber`` / ``LiveProber``).
        delta: the paper's δ threshold.
        extended: opt into the beyond-paper ``shard_zero``/``fsdp``
            probes (``core.costmodel.ALL_TECHNIQUES``); the default
            keeps the paper's four-technique probe set bit-for-bit.
    """
    from repro.core.search import algorithm1_select
    n_sites = getattr(prober, "n_sites", 2)
    return algorithm1_select(prober.probe, n_sites, delta=delta,
                             extended=extended)
