"""N-site cluster topology: the generalization of the paper's two-VM world.

The paper's experiments are all two-VM FABRIC slices joined by one WAN
link; ``core/costmodel.Cluster`` reproduced exactly that shape.  This
module models the general case — a *graph* of sites:

  * a ``Site`` is a co-located GPU pool (the paper's "VM"): a list of
    (possibly heterogeneous) GPU names plus an intra-site link (PCIe);
  * a ``Topology`` is N sites plus per-pair inter-site ``Link``s, each
    with its own latency and bandwidth, subject to the same
    TCP-window-effective-throughput rule the paper measured (§II-C:
    NCCL over TCP/IP, no GPUDirect);
  * pairs without a direct link are routed over the latency-shortest
    multi-hop path (latencies add, bandwidth is the min along the path),
    so rings, stars/hubs and lines are all expressible.

``Cluster.topology()`` (core/costmodel.py) embeds every legacy two-VM
slice as the N=2 special case; ``core/search.PlanSearch`` enumerates
plans over arbitrary site subsets of a Topology.  See DESIGN.md §5.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


# --------------------------------------------------------------------- #
# hardware vocabulary (moved here from core/costmodel.py, which re-exports)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class GPUSpec:
    """One GPU model's achievable (not peak-marketing) capabilities.

    Attributes:
        name: short card name, a key of ``GPUS``.
        tflops: achievable mixed-precision TFLOP/s for training GEMMs.
        mem_gb: usable HBM/GDDR capacity.
        mem_bw_gbps: memory bandwidth in GB/s.
    """
    name: str
    tflops: float          # achievable mixed-precision TFLOP/s for GEMMs
    mem_gb: float
    mem_bw_gbps: float


# Achievable (not peak-marketing) numbers for the paper's cards:
GPUS = {
    # Quadro RTX 6000: 16.3 fp32 / ~32 fp16-ish; achievable trainer ~20
    "RTX": GPUSpec("RTX", 20.0, 24.0, 672.0),
    # Tesla T4: 8.1 fp32, 65 fp16 peak but bandwidth-starved; ~10 achievable
    "T4": GPUSpec("T4", 10.0, 16.0, 320.0),
    # A30: 10.3 fp32 / 165 bf16 peak; ~25 achievable with its 933 GB/s
    "A30": GPUSpec("A30", 25.0, 24.0, 933.0),
}


TCP_WINDOW_BYTES = 8e6   # effective socket window of NCCL-over-TCP streams


@dataclass(frozen=True)
class Link:
    """A (intra- or inter-site) interconnect edge.

    Attributes:
        latency_s: one-way latency in seconds (the paper reports RTTs in
            ms; builders take ``latency_ms`` and convert).
        bandwidth_gbps: GB/s usable at zero RTT — what NCCL-over-TCP
            achieves on the raw link, not the marketing line rate.
    """
    latency_s: float
    bandwidth_gbps: float  # GB/s usable at zero RTT

    @property
    def effective_gbps(self) -> float:
        """Single-stream TCP throughput is window/RTT-limited (paper §II-C:
        NCCL uses TCP/IP between VMs, no GPUDirect) — this is what makes
        Data/ZeRO2/Shard collapse on high-latency slices (Table II)."""
        if self.latency_s <= 0:
            return self.bandwidth_gbps
        return min(self.bandwidth_gbps,
                   TCP_WINDOW_BYTES / self.latency_s / 1e9)


PCIE = Link(5e-6, 12.0)   # default intra-site interconnect


@dataclass(frozen=True)
class Site:
    """A co-located GPU pool — the paper's 'VM', one node of the graph.

    Attributes:
        gpus: card names (keys of ``GPUS``), e.g. ``("RTX", "RTX")``;
            possibly heterogeneous.
        intra: the link within the site (default: PCIe).
        name: optional display name.
    """
    gpus: Tuple[str, ...]                 # e.g. ("RTX", "RTX")
    intra: Link = PCIE                    # link within the site (PCIe)
    name: str = ""

    def specs(self) -> List[GPUSpec]:
        """The ``GPUSpec`` of every GPU in this site, in order."""
        return [GPUS[g] for g in self.gpus]


def _key(i: int, j: int) -> Tuple[int, int]:
    return (i, j) if i <= j else (j, i)


@dataclass(frozen=True, eq=False)
class Topology:
    """N sites + inter-site link graph.

    ``links`` maps canonical ``(i, j)`` pairs (``i < j``) to Links; any
    pair not present is priced over the latency-shortest multi-hop path.
    """
    name: str
    sites: Tuple[Site, ...]
    links: Mapping[Tuple[int, int], Link] = field(default_factory=dict)

    # ----------------------------------------------------------------- #
    @property
    def n_sites(self) -> int:
        return len(self.sites)

    def select(self, sites: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
        """Normalize a site-subset argument.

        Args:
            sites: site indices, or None for all sites.

        Returns:
            The validated tuple of site indices (order preserved).

        Raises:
            IndexError: a site index is out of range.
            ValueError: the selection contains duplicates.
        """
        idx = tuple(range(self.n_sites)) if sites is None else tuple(sites)
        for i in idx:
            if not 0 <= i < self.n_sites:
                raise IndexError(f"site {i} not in topology "
                                 f"{self.name!r} (n={self.n_sites})")
        if len(set(idx)) != len(idx):
            raise ValueError(f"duplicate sites in selection {idx}")
        return idx

    def all_gpus(self, sites: Optional[Sequence[int]] = None) -> List[GPUSpec]:
        """Every GPU of the selected sites (None = all), in site order."""
        return [GPUS[g] for i in self.select(sites)
                for g in self.sites[i].gpus]

    def direct(self, i: int, j: int) -> Optional[Link]:
        """The direct edge between sites i and j, or None if unlinked."""
        return self.links.get(_key(i, j))

    def link(self, i: int, j: int) -> Link:
        """Link between sites i and j: the site's intra link when i == j,
        the direct link if present, else the latency-shortest routed path
        (latencies add, bandwidth is the min hop)."""
        if i == j:
            return self.sites[i].intra
        d = self.direct(i, j)
        if d is not None:
            return d
        return self._route(i, j)

    def _route(self, src: int, dst: int) -> Link:
        """Dijkstra on latency; the routed 'link' keeps the path's total
        latency and its narrowest hop bandwidth — the TCP window rule then
        applies to the end-to-end RTT, which is conservative and matches
        how a single NCCL TCP stream behaves across a relay."""
        done = set()
        q = [(0.0, src, float("inf"))]
        while q:
            lat, node, bw = heapq.heappop(q)
            if node in done:
                continue
            done.add(node)
            if node == dst:
                return Link(lat, bw)
            for (a, b), l in self.links.items():
                if node not in (a, b):
                    continue
                nxt = b if a == node else a
                if nxt not in done:
                    heapq.heappush(q, (lat + l.latency_s, nxt,
                                       min(bw, l.bandwidth_gbps)))
        raise ValueError(f"sites {src} and {dst} are not connected "
                         f"in topology {self.name!r}")

    def spanning_links(self, sites: Sequence[int]) -> List[Link]:
        """Every pairwise link a collective over `sites` must cross.

        Args:
            sites: the participating site subset.

        Returns:
            One (direct or routed) ``Link`` per site pair.
        """
        idx = self.select(sites)
        return [self.link(i, j) for i, j in itertools.combinations(idx, 2)]

    def worst_link(self, sites: Sequence[int]) -> Link:
        """Bottleneck link on the spanning set: minimal effective
        throughput, ties broken by larger latency.  For a single site this
        is its intra link — the N=2 special case reduces to the legacy
        ``Cluster.wan`` field."""
        idx = self.select(sites)
        if len(idx) <= 1:
            return self.sites[idx[0]].intra if idx else PCIE
        return min(self.spanning_links(idx),
                   key=lambda l: (l.effective_gbps, -l.latency_s))

    # ----------------------------------------------------------------- #
    # topology surgery (elastic re-planning, docs/elasticity.md)
    # ----------------------------------------------------------------- #

    def without_sites(self, dead: Sequence[int]
                      ) -> Tuple["Topology", Tuple[int, ...]]:
        """The surviving topology after site failures.

        Sites are reindexed densely (links follow); the returned ``kept``
        tuple maps each *new* site index back to its old one, which is
        what lets a re-planned ``core.plans.Placement`` on the survivor
        be realized on the original devices (``train.replan``).

        Args:
            dead: old site indices that disappeared (duplicates and
                out-of-range indices are rejected via ``select``).

        Returns:
            ``(survivor, kept)`` — the degraded topology and the
            new→old index map.

        Raises:
            ValueError: every site died (nothing to re-plan onto).
        """
        gone = set(self.select(tuple(dead)) if dead else ())
        kept = tuple(i for i in range(self.n_sites) if i not in gone)
        if not kept:
            raise ValueError(f"all {self.n_sites} sites of {self.name!r} "
                             f"died — no survivor to re-plan onto")
        remap = {old: new for new, old in enumerate(kept)}
        links = {(remap[i], remap[j]): l for (i, j), l in self.links.items()
                 if i in remap and j in remap}
        name = self.name if not gone else \
            f"{self.name}-S{'S'.join(str(i) for i in sorted(gone))}"
        return Topology(name, tuple(self.sites[i] for i in kept),
                        links), kept

    def without_link(self, i: int, j: int) -> "Topology":
        """The topology with the direct edge between sites i and j
        removed (site indices unchanged).  Traffic between the pair is
        then priced over the remaining routed path — or becomes
        unreachable, which ``components`` makes visible.

        Raises:
            ValueError: no direct link exists between the pair.
        """
        k = _key(i, j)
        if k not in self.links:
            raise ValueError(f"no direct link between sites {i} and {j} "
                             f"in topology {self.name!r}")
        links = {p: l for p, l in self.links.items() if p != k}
        return Topology(f"{self.name}-L{k[0]}{k[1]}", self.sites, links)

    def components(self) -> List[Tuple[int, ...]]:
        """Connected components of the link graph, each a sorted site
        tuple, largest-first (ties: smallest leading index).  A healthy
        topology has exactly one; after ``without_sites`` /
        ``without_link`` the survivors may split, and a re-plan must
        place within a single component (collectives cannot cross a
        partition) — ``train.replan.replan`` searches each component."""
        parent = list(range(self.n_sites))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for (i, j) in self.links:
            parent[find(i)] = find(j)
        groups: Dict[int, List[int]] = {}
        for i in range(self.n_sites):
            groups.setdefault(find(i), []).append(i)
        return sorted((tuple(sorted(g)) for g in groups.values()),
                      key=lambda g: (-len(g), g))

    # ----------------------------------------------------------------- #
    def describe(self) -> str:
        """Multi-line human-readable summary (sites, links, eff GB/s)."""
        parts = [f"{self.name}: {self.n_sites} sites"]
        for i, s in enumerate(self.sites):
            parts.append(f"  S{i} {s.name or '?'}: {'+'.join(s.gpus)}")
        for (i, j), l in sorted(self.links.items()):
            parts.append(f"  S{i}--S{j}: {l.latency_s * 1e3:.1f}ms "
                         f"{l.bandwidth_gbps:.1f}GB/s "
                         f"(eff {l.effective_gbps:.2f})")
        return "\n".join(parts)


# --------------------------------------------------------------------- #
# builders
# --------------------------------------------------------------------- #

def _norm_links(links: Mapping[Tuple[int, int], Link]
                ) -> Dict[Tuple[int, int], Link]:
    out: Dict[Tuple[int, int], Link] = {}
    for (i, j), l in links.items():
        if i == j:
            raise ValueError(f"self-link on site {i}")
        k = _key(i, j)
        if k in out and out[k] != l:
            raise ValueError(
                f"conflicting links for site pair {k}: {out[k]} vs {l}")
        out[k] = l
    return out


def make_topology(name: str, sites: Sequence[Site],
                  links: Mapping[Tuple[int, int], Link]) -> Topology:
    """Build a topology from an explicit link map.

    Args:
        name: display name.
        sites: the N sites.
        links: ``(i, j) -> Link`` in either index order; duplicate pairs
            with conflicting links are rejected.

    Returns:
        A ``Topology`` with links normalized to canonical ``i < j`` keys.
    """
    return Topology(name, tuple(sites), _norm_links(links))


def two_site(name: str, gpus1: Sequence[str], gpus2: Sequence[str],
             latency_ms: float, wan_gbps: float = 3.0) -> Topology:
    """The paper's shape: two sites, one WAN link (Table I).

    Args:
        name: display name.
        gpus1: card names of site V1's GPUs.
        gpus2: card names of site V2's GPUs.
        latency_ms: WAN RTT in milliseconds.
        wan_gbps: achievable NCCL-over-TCP bandwidth in GB/s.
    """
    return make_topology(
        name,
        (Site(tuple(gpus1), name="V1"), Site(tuple(gpus2), name="V2")),
        {(0, 1): Link(latency_ms * 1e-3, wan_gbps)})


def fully_connected(name: str, sites: Sequence[Site],
                    link: Link) -> Topology:
    """N sites, every pair joined directly by the same ``link``."""
    n = len(sites)
    return make_topology(name, sites, {
        (i, j): link for i in range(n) for j in range(i + 1, n)})


def ring(name: str, sites: Sequence[Site],
         links: Sequence[Link]) -> Topology:
    """N sites on a cycle; ``links[k]`` joins site k and (k+1) % N.

    Args:
        name: display name.
        sites: >= 3 sites (two sites have a single edge — use
            ``two_site``/``line``).
        links: exactly N links, one per cycle edge.
    """
    n = len(sites)
    if n < 3:
        raise ValueError(f"a ring needs >= 3 sites (got {n}); two sites "
                         f"have a single edge — use two_site/line")
    if len(links) != n:
        raise ValueError(f"ring of {n} sites needs {n} links, "
                         f"got {len(links)}")
    return make_topology(name, sites, {
        (k, (k + 1) % n): links[k] for k in range(n)})


def line(name: str, sites: Sequence[Site],
         links: Sequence[Link]) -> Topology:
    """N sites on a path; ``links[k]`` joins site k and k+1.  Non-adjacent
    pairs are priced over the (unique) routed path."""
    n = len(sites)
    if len(links) != n - 1:
        raise ValueError(f"line of {n} sites needs {n - 1} links")
    return make_topology(name, sites, {
        (k, k + 1): links[k] for k in range(n - 1)})


def hub(name: str, hub_site: Site, leaves: Sequence[Site],
        spoke: Link) -> Topology:
    """Star topology: site 0 is the hub, leaf↔leaf traffic relays
    through it (two spoke hops).

    Args:
        name: display name.
        hub_site: the central site (index 0 of the result).
        leaves: the spoke sites (indices 1..N-1).
        spoke: the hub↔leaf link, shared by every spoke.
    """
    sites = (hub_site,) + tuple(leaves)
    return make_topology(name, sites, {
        (0, k): spoke for k in range(1, len(sites))})
