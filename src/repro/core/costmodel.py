"""α–β analytical cost model of the paper's FABRIC GPU clusters.

Reproduces the paper's Figures 3–7 and Table II: per-technique pretraining
time for GPT-2 medium/large on two-VM slices with measured site-to-site
latencies.  The model is deliberately simple — compute term from achievable
per-GPU FLOP/s, communication terms from per-step traffic of each technique
over the cluster's link graph with latency α and bandwidth β — because the
*paper's claims are about orderings and trends*, which is what
EXPERIMENTS.md §Paper-validation checks.

Since the N-site generalization (core/topology.py, DESIGN.md §5) the
pricing works on an arbitrary ``Topology``: collectives pay the worst link
on their spanning set, Pipeshard pays each stage-boundary link it actually
crosses in its stage→site order.  The legacy two-VM ``Cluster`` is kept as
a thin shim whose ``topology()`` is the N=2 special case, so every paper
artifact (PAPER_CLUSTERS, benchmarks, Algorithm 1) keeps its exact shape
and numbers.

Per-technique pricing is a registry of composable cost components
(``TECHNIQUE_SPECS``, docs/cost-model.md): each ``TechniqueSpec``
assembles compute, collective, p2p (with a ``carrier_dtype`` byte
knob), and memory (explicit ``MemoryModel`` replication fractions)
terms over a shared ``CostContext``.  The paper's four specs price
bit-for-bit what the pre-registry chain did; the beyond-paper
``shard_zero`` and ``fsdp`` specs make every plan ``core.plans.PLANS``
executes also *recommendable* by the search.

The same machinery prices TPU meshes (ICI vs DCN) for plan selection when
no hardware is attached — the dry-run roofline (launch/roofline.py) uses
compiled HLO instead wherever it can.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from repro.configs.base import ModelConfig
from repro.core.topology import (GPUS, GPUSpec, Link, PCIE, Site,
                                 TCP_WINDOW_BYTES, Topology, two_site)

# Legacy alias: the paper called a site a "VM".
VM = Site


@dataclass(frozen=True)
class Cluster:
    """Two-VM FABRIC slice (paper Table I) — legacy N=2 shim over
    ``core.topology.Topology``."""
    name: str
    vms: Tuple[Site, ...]
    wan: Link                              # inter-VM (L2Bridge / L2STS)

    def all_gpus(self) -> List[GPUSpec]:
        return [GPUS[g] for vm in self.vms for g in vm.gpus]

    def topology(self) -> Topology:
        """Embed as the N=2 special case of the site/link graph."""
        import itertools
        sites = tuple(
            Site(vm.gpus, vm.intra, vm.name or f"V{i + 1}")
            for i, vm in enumerate(self.vms))
        links = {(i, j): self.wan
                 for i, j in itertools.combinations(range(len(sites)), 2)}
        return Topology(self.name, sites, links)


ClusterLike = Union[Cluster, Topology]


def as_topology(cluster: ClusterLike) -> Topology:
    return cluster.topology() if isinstance(cluster, Cluster) else cluster


def fabric_cluster(name: str, gpus1: Tuple[str, str], gpus2: Tuple[str, str],
                   latency_ms: float, wan_gbps: float = 3.0) -> Cluster:
    """WAN bandwidth: NCCL over TCP/IP on FABRIC achieves only a few GB/s
    of the 100 Gbps links (paper §II-C: TCP/IP, no GPUDirect)."""
    return Cluster(name, (Site(tuple(gpus1)), Site(tuple(gpus2))),
                   Link(latency_ms * 1e-3, wan_gbps))


# The paper's five slices (Table I).
PAPER_CLUSTERS: Dict[str, Cluster] = {
    "TACC-TACC": fabric_cluster("TACC-TACC", ("RTX", "RTX"), ("T4", "T4"), 0.1),
    "UTAH-GPN": fabric_cluster("UTAH-GPN", ("RTX", "RTX"), ("T4", "T4"), 20.2),
    "UTAH-MASS": fabric_cluster("UTAH-MASS", ("RTX", "RTX"), ("RTX", "RTX"), 57.4),
    "BRIS-STAR": fabric_cluster("BRIS-STAR", ("A30", "A30"), ("RTX", "RTX"), 95.9),
    "GAT-AMST": fabric_cluster("GAT-AMST", ("A30", "A30"), ("A30", "A30"), 103.0),
}

# The same slices as 2-site topologies (what PlanSearch consumes).
PAPER_TOPOLOGIES: Dict[str, Topology] = {
    name: c.topology() for name, c in PAPER_CLUSTERS.items()
}


# --------------------------------------------------------------------- #
# workload description
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Workload:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    steps_per_epoch: int
    epochs: int = 20                      # the paper runs 20 epochs
    microbatches: int = 4

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch

    @property
    def flops_per_step(self) -> float:
        return 6.0 * self.cfg.active_param_count() * self.tokens_per_step

    def bytes_params(self) -> float:
        return 2.0 * self.cfg.param_count()          # fp16/bf16 on the wire

    def bytes_grads(self) -> float:
        return 2.0 * self.cfg.param_count()

    # Alpa's gpt-2 training keeps fp32 master params + fp32 Adam moments:
    def bytes_train_state(self) -> float:           # p+g+m+v, fp32
        return 16.0 * self.cfg.param_count()

    ACT_FACTOR = 10.0  # no-remat Alpa training: activations + attn scores
    OVERHEAD_GB = 2.0  # CUDA context, NCCL buffers, framework workspace

    def activation_bytes_per_gpu(self, n_gpus: int) -> float:
        c = self.cfg
        per_layer = self.tokens_per_step // max(n_gpus, 1) * c.d_model * 2
        return per_layer * c.n_layers * self.ACT_FACTOR


# the paper pretrains on 20231101.ace (~8MB dump): roughly 2M tokens
def paper_workload(cfg: ModelConfig, *, global_batch: int = 32) -> Workload:
    tokens = 2_000_000
    steps = max(1, tokens // (cfg.max_seq_len * global_batch))
    return Workload(cfg, cfg.max_seq_len, global_batch, steps)


# --------------------------------------------------------------------- #
# per-technique cost
# --------------------------------------------------------------------- #

LOG2E = 1.4426950408889634

# The paper's four techniques — Algorithm 1's pool, and the default
# everywhere a technique tuple is expected (paper artifacts keep their
# exact numbers).  ``ALL_TECHNIQUES`` (defined with the registry below)
# appends the beyond-paper ``shard_zero`` and ``fsdp`` specs the search
# can opt into.
TECHNIQUES = ("data", "zero2", "shard", "pipeshard")

# Inter-stage activation carrier dtypes the Pipeshard p2p term can be
# priced at.  "fp32" is the legacy baseline (the XLA-CPU-safe default of
# ``core.pipeline.make_pipeline_loss``); "bf16" halves the wire bytes —
# the real-accelerator carrier the runtime supports (docs/cost-model.md).
CARRIER_DTYPES = ("fp32", "bf16")

_CARRIER_SCALE = {"fp32": 1.0, "bf16": 0.5}


def carrier_scale(carrier_dtype: str) -> float:
    """Byte multiplier of an inter-stage carrier dtype vs the fp32
    baseline (``1.0`` for fp32, ``0.5`` for bf16).

    Raises:
        ValueError: unknown carrier dtype.
    """
    try:
        return _CARRIER_SCALE[carrier_dtype]
    except KeyError:
        raise ValueError(f"unknown carrier_dtype {carrier_dtype!r}; "
                         f"expected one of {CARRIER_DTYPES}") from None


# Wire dtypes generalize the p2p-only carrier axis to *collective*
# traffic as well (docs/quantization.md): the quantizable share of each
# technique's collective volume (``CommPrecision``) plus the pipeline
# boundary carriers ride the wire dtype; the rest stays fp32.  int8's
# scale is not 0.25: the per-128-block absmax scale of the kernels'
# scheme (kernels/quantized.py) travels too — (128·1 + 4) bytes per 128
# elements over the 128·4-byte fp32 baseline = 0.2578125.
WIRE_DTYPES = ("fp32", "bf16", "int8")

_WIRE_SCALE = {"fp32": 1.0, "bf16": 0.5, "int8": (128 + 4) / (128 * 4)}


def wire_scale(wire_dtype: str) -> float:
    """Byte multiplier of a wire dtype vs the fp32 baseline (1.0 fp32,
    0.5 bf16, 0.2578125 int8 — payload + per-block absmax scales).

    Raises:
        ValueError: unknown wire dtype.
    """
    try:
        return _WIRE_SCALE[wire_dtype]
    except KeyError:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r}; "
                         f"expected one of {WIRE_DTYPES}") from None


@dataclass(frozen=True)
class CommPrecision:
    """Wire-quantizable fractions of a technique's collective volume
    (``TechniqueSpec.comm_precision``, docs/quantization.md).

    Attributes:
        act: fraction of *activation* collective volume (tensor-parallel
            all-reduces, stage-boundary traffic) that may ride the wire
            dtype.
        state: fraction of *gradient/optimizer-state* collective volume
            (DP all-reduce, ZeRO partition sync, FSDP gathers) that may
            ride the wire dtype.  The remaining ``1 - state`` is the
            fp32-master-weights correction term — partitioned fp32
            master syncs and reductions landing in fp32 shards cross the
            wire at full width whatever the wire dtype.
    """
    act: float = 1.0
    state: float = 1.0


def _eff_byte_scale(frac: float, ws: float) -> float:
    """Effective byte multiplier of a collective whose quantizable
    fraction is ``frac`` at wire scale ``ws``.  Exactly 1.0 at fp32 so
    legacy prices stay bit-for-bit (``frac*1 + (1-frac)`` may not)."""
    return 1.0 if ws == 1.0 else frac * ws + (1.0 - frac)


def _act_byte_scale(ctx: "CostContext") -> float:
    return _eff_byte_scale(ctx.comm.act, ctx.wire_scale)


def _state_byte_scale(ctx: "CostContext") -> float:
    return _eff_byte_scale(ctx.comm.state, ctx.wire_scale)

# Pipeline tick-order schedules (docs/schedules.md).  "gpipe" is the
# paper's measured Alpa behavior (all forwards, then all backwards —
# bubble (S-1)/m, m microbatches in flight); "1f1b" is PipeDream-Flush
# (same bubble, but a stage never holds more than S in-flight
# microbatches); "interleaved" is the Megatron-LM interleaved 1F1B
# schedule with v virtual stages (layer chunks) per device — bubble
# shrinks to (S-1)/(v*m) at the price of v crossings of every stage
# boundary.  "interleaved" defaults to v=2; "interleaved<k>" (e.g.
# "interleaved4") sets v explicitly.
SCHEDULES = ("gpipe", "1f1b", "interleaved")

DEFAULT_INTERLEAVE = 2


def parse_schedule(schedule: str) -> Tuple[str, int]:
    """Split a schedule name into (kind, virtual stages per device).

    Args:
        schedule: ``"gpipe"``, ``"1f1b"``, ``"interleaved"`` (v=2), or
            ``"interleaved<v>"`` with an explicit v >= 2 (e.g.
            ``"interleaved4"``).

    Returns:
        ``(kind, v)`` with ``kind`` in ``SCHEDULES`` and ``v == 1``
        except for interleaved schedules.

    Raises:
        ValueError: unknown schedule name or v < 2 on interleaved.
    """
    if schedule in ("gpipe", "1f1b"):
        return schedule, 1
    if schedule == "interleaved":
        return "interleaved", DEFAULT_INTERLEAVE
    if schedule.startswith("interleaved"):
        try:
            v = int(schedule[len("interleaved"):])
        except ValueError:
            raise ValueError(f"unknown schedule {schedule!r}; expected one "
                             f"of {SCHEDULES} or 'interleaved<v>'") from None
        if v < 2:
            raise ValueError(f"interleaved needs >= 2 virtual stages, "
                             f"got {schedule!r}")
        return "interleaved", v
    raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                     f"{SCHEDULES} or 'interleaved<v>'")


def pipeline_bubble_fraction(schedule: str, n_stages: int,
                             n_micro: int) -> float:
    """Idle fraction of the pipeline, relative to ideal compute time.

    GPipe and 1F1B both pay ``(S-1)/m`` — 1F1B reorders backwards
    between forwards but drains the same warm-up/flush ramps.  The
    interleaved schedule cuts the ramp by its v virtual stages:
    ``(S-1)/(v*m)`` (Narayanan et al. 2021).

    Args:
        schedule: schedule name (see ``parse_schedule``).
        n_stages: pipeline stages S (devices/meshes in the ring).
        n_micro: microbatches m per optimizer step.

    Returns:
        The bubble fraction b, so step compute time scales as (1 + b).
    """
    kind, v = parse_schedule(schedule)
    bubble = (n_stages - 1) / n_micro
    return bubble / v if kind == "interleaved" else bubble


def pipeline_inflight_microbatches(schedule: str, n_stages: int,
                                   n_micro: int) -> float:
    """Microbatches of activations a stage holds at the schedule's peak.

    GPipe stashes every forward before the first backward: m in flight.
    1F1B starts backwards as soon as the pipeline fills, so a stage
    never holds more than ``min(S, m)``.  The interleaved schedule
    keeps 1F1B's bound but holds partially-processed chunks of the
    next wave: ``min(S, m) * (1 + (S-1)/(S*v))`` (Narayanan et al.
    2021) — slightly above 1F1B, still far below GPipe at large m.

    Args:
        schedule: schedule name (see ``parse_schedule``).
        n_stages: pipeline stages S.
        n_micro: microbatches m per optimizer step.

    Returns:
        Effective in-flight microbatch count (fractional for
        interleaved), monotone non-decreasing in m for every schedule.
    """
    kind, v = parse_schedule(schedule)
    if kind == "gpipe":
        return float(n_micro)
    inflight = float(min(n_stages, n_micro))
    if kind == "1f1b":
        return inflight
    return inflight * (1.0 + (n_stages - 1) / (n_stages * v))

# Pipeline stage-size policies: "even" reproduces the paper's measured
# Alpa behavior (equal meshes -> equal layer slices, what Table II and
# Algorithm 1 were run with); "tflops" weights stage sizes by per-site
# compute so a T4 site gets fewer layers than an A30 site (ROADMAP
# "heterogeneous stage balancing", docs/topology-and-search.md).
STAGE_BALANCE_MODES = ("even", "tflops")


def stage_compute_tflops(topo: Topology, order: Sequence[int],
                         calibration=None) -> List[float]:
    """Achievable TFLOP/s of each pipeline stage's site, in stage order.

    Args:
        topo: the topology the stages are placed on.
        order: site index per stage (a ``Placement.stage_order`` or plain
            site subset).
        calibration: optional measured-rate overlay
            (``repro.calib.overlay.Calibration``); sites it covers use
            the achieved rate instead of the datasheet one.

    Returns:
        One entry per stage: the site's GPU count times its slowest GPU's
        achievable TFLOP/s (meshes are paced by their slowest member).
    """
    if calibration is None:
        return [min(GPUS[g].tflops for g in topo.sites[i].gpus)
                * len(topo.sites[i].gpus) for i in order]
    return [calibration.gpu_tflops(topo, i) * len(topo.sites[i].gpus)
            for i in order]


def balanced_stage_layers(n_layers: int, stage_tflops: Sequence[float]
                          ) -> Tuple[int, ...]:
    """Split ``n_layers`` across stages proportionally to stage TFLOP/s.

    Largest-remainder allocation with one layer reserved per stage, so the
    result always sums to ``n_layers``, every stage gets >= 1 layer, and a
    faster stage never gets fewer layers than a slower one.  Homogeneous
    stages degrade to the even split.

    Args:
        n_layers: total layers to distribute (>= number of stages).
        stage_tflops: per-stage achievable TFLOP/s (all > 0).

    Returns:
        Per-stage layer counts, in stage order.
    """
    k = len(stage_tflops)
    if k < 1:
        raise ValueError("need at least one stage")
    if n_layers < k:
        raise ValueError(f"cannot fill {k} stages with {n_layers} layers")
    if min(stage_tflops) <= 0:
        raise ValueError(f"non-positive stage TFLOP/s in {stage_tflops}")
    total = float(sum(stage_tflops))
    spare = n_layers - k
    quotas = [spare * t / total for t in stage_tflops]
    layers = [1 + int(q) for q in quotas]
    # leftover goes to the largest fractional parts (ties: earliest stage)
    order = sorted(range(k), key=lambda i: (-(quotas[i] - int(quotas[i])), i))
    for i in order[:n_layers - sum(layers)]:
        layers[i] += 1
    return tuple(layers)


@dataclass
class StepCost:
    compute_s: float
    comm_s: float
    mem_required_gb: float
    mem_available_gb: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    @property
    def fits(self) -> bool:
        return self.mem_required_gb <= self.mem_available_gb


def _allreduce_time(bytes_total: float, n: int, link: Link) -> float:
    """Ring all-reduce: 2(n-1)/n × bytes over the slowest link, with 2(n-1)
    latency hops, at the TCP-effective bandwidth."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * link.latency_s \
        + 2 * (n - 1) / n * bytes_total / (link.effective_gbps * 1e9)


def _gather_time(bytes_total: float, n: int, link: Link) -> float:
    """Ring all-gather or reduce-scatter: exactly half an all-reduce —
    (n-1) latency hops and (n-1)/n × bytes (an all-reduce IS a
    reduce-scatter followed by an all-gather)."""
    if n <= 1:
        return 0.0
    return (n - 1) * link.latency_s \
        + (n - 1) / n * bytes_total / (link.effective_gbps * 1e9)


# ---- calibrated lookups (repro.calib.overlay, docs/calibration.md) -- #
#
# ``cal`` is a ``repro.calib.overlay.Calibration``, duck-typed here so
# the core never imports the calib package: it must provide
# ``gpu_tflops(topo, i)``, ``link(topo, i, j)`` and
# ``spanning_links(topo, sites)``.  ``cal=None`` — and the identity
# overlay, whose lookups fall through to the very same objects and
# expressions — price bit-for-bit the analytic model (the differential
# gate in tests/test_calib_gates.py pins this with ``==``).

def _cal_intra(cal, topo: Topology, i: int) -> Link:
    return topo.sites[i].intra if cal is None else cal.link(topo, i, i)


def _cal_link(cal, topo: Topology, i: int, j: int) -> Link:
    return topo.link(i, j) if cal is None else cal.link(topo, i, j)


def _cal_spanning(cal, topo: Topology, sites: Sequence[int]) -> List[Link]:
    return topo.spanning_links(sites) if cal is None \
        else cal.spanning_links(topo, sites)


def _collective_time(bytes_total: float, n: int, topo: Topology,
                     sites: Sequence[int], cal=None) -> float:
    """All-reduce over a site subset: the ring crosses every site pair's
    path, so the *worst* spanning link prices the collective (the N=2
    special case is exactly the old single-``wan``-field rule)."""
    if len(sites) <= 1:
        return _allreduce_time(bytes_total, n,
                               _cal_intra(cal, topo, sites[0]))
    return max(_allreduce_time(bytes_total, n, l)
               for l in _cal_spanning(cal, topo, sites))


def _gather_collective_time(bytes_total: float, n: int, topo: Topology,
                            sites: Sequence[int], cal=None) -> float:
    """All-gather / reduce-scatter over a site subset, priced like
    ``_collective_time`` on the worst spanning link."""
    if len(sites) <= 1:
        return _gather_time(bytes_total, n, _cal_intra(cal, topo, sites[0]))
    return max(_gather_time(bytes_total, n, l)
               for l in _cal_spanning(cal, topo, sites))


# --------------------------------------------------------------------- #
# the technique cost registry (docs/cost-model.md)
#
# ``technique_step_cost`` used to be a four-way if/elif chain; it is now
# a dispatch over ``TECHNIQUE_SPECS`` — one ``TechniqueSpec`` per
# technique, built from four composable cost components sharing a
# ``CostContext``:
#
#   compute    pace-setter seconds (+ pipeline bubble)
#   collective per-collective volume terms on the worst spanning link
#   p2p        per-boundary microbatch carriers (pipeline only), scaled
#              by the carrier dtype
#   memory     params/grads/optimizer-state replication expressed as
#              explicit per-technique ``MemoryModel`` fractions
#
# The four paper specs price bit-for-bit what the legacy chain did
# (property-tested in tests/test_costmodel.py); ``shard_zero`` and
# ``fsdp`` are the beyond-paper specs that make the search able to
# recommend every plan ``core.plans.PLANS`` can execute.
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class _PipelineGeometry:
    """Derived pipeline quantities shared by the pipeshard components:
    validated stage order, schedule kind, per-chunk layer split, and the
    per-stage compute pool."""
    order: Tuple[int, ...]
    n_stages: int
    kind: str
    virt: int
    n_chunks: int
    stage_sites: Tuple[Site, ...]
    mesh_tflops: Tuple[float, ...]
    bubble: float
    split: Optional[Tuple[int, ...]]      # None = legacy even split
    stage_l: Optional[Tuple[int, ...]]    # per-stage layer totals


@dataclass
class CostContext:
    """Everything a cost component may look at for one
    (workload × placement) pricing.

    Attributes:
        wl: the workload being priced.
        topo: the N-site topology.
        sel: participating site indices.
        sites: the participating ``Site`` objects, in ``sel`` order.
        n: GPU pool size.
        tp: intra-site tensor-parallel degree available to hybrid
            techniques — the *smallest* participating site's GPU count
            (worst case for both memory and collective volume).
        flops: model FLOPs of one step.
        slowest: the pace-setting GPU's FLOP/s.
        g_bytes / p_bytes / state: gradient, bf16-param, and fp32 train
            state (p+g+m+v) bytes of the model.
        act: activation bytes per GPU at this pool size.
        ovh: fixed framework overhead GB.
        mem_avail: smallest participating GPU's memory in GB.
        stage_order / stage_balance / stage_layers / schedule: the
            Pipeshard placement knobs (ignored by flat-pool components).
        carrier_scale: byte multiplier of the inter-stage carrier dtype
            (``carrier_scale()``; 1.0 = legacy fp32 baseline).  When a
            sub-fp32 ``wire_dtype`` is active the narrower of the two
            prices the p2p carriers.
        wire_scale: byte multiplier of the collective wire dtype
            (``wire_scale()``; 1.0 = legacy fp32 baseline).
        comm: the priced technique's ``CommPrecision`` — which fractions
            of its collective volume may ride the wire dtype.
        cal: optional measured-rate ``Calibration`` overlay
            (``repro.calib.overlay``); None and the identity overlay
            price bit-for-bit the analytic model.
    """
    wl: Workload
    topo: Topology
    sel: Tuple[int, ...]
    sites: List[Site]
    n: int
    tp: int
    flops: float
    slowest: float
    g_bytes: float
    p_bytes: float
    state: float
    act: float
    ovh: float
    mem_avail: float
    stage_order: Optional[Sequence[int]] = None
    stage_balance: str = "even"
    stage_layers: Optional[Sequence[int]] = None
    schedule: str = "gpipe"
    carrier_scale: float = 1.0
    wire_scale: float = 1.0
    comm: CommPrecision = field(default_factory=CommPrecision)
    cal: Optional[object] = None
    _geom: Optional[_PipelineGeometry] = field(default=None, repr=False)

    @property
    def act_stream_bytes(self) -> float:
        """Bytes of one full activation tensor crossing the network
        (the per-layer intra-op all-reduce payload and the Pipeshard
        stage-boundary carrier, before any carrier-dtype scaling)."""
        return self.wl.tokens_per_step * self.wl.cfg.d_model * 2

    def pipeline(self) -> _PipelineGeometry:
        """Validate + derive the pipeline geometry (cached).  Raises the
        same errors the legacy chain did: bad stage orders, splits that
        do not partition the stack, unknown balance modes."""
        if self._geom is not None:
            return self._geom
        wl, topo, sel = self.wl, self.topo, self.sel
        order = sel if self.stage_order is None \
            else topo.select(self.stage_order)
        if sorted(order) != sorted(sel):
            raise ValueError(
                f"stage_order {order} is not a permutation of sites {sel}")
        n_stages = max(len(order), 1)
        kind, virt = parse_schedule(self.schedule)
        n_chunks = n_stages * virt
        stage_sites = tuple(topo.sites[i] for i in order)
        stage_tf = stage_compute_tflops(topo, order, self.cal)
        mesh_tflops = tuple(t * 1e12 for t in stage_tf)
        bubble = pipeline_bubble_fraction(self.schedule, n_stages,
                                          wl.microbatches)
        if self.stage_layers is not None:
            split: Optional[Tuple[int, ...]] = tuple(self.stage_layers)
            if len(split) != n_chunks or min(split, default=0) < 1 \
                    or sum(split) != wl.cfg.n_layers:
                raise ValueError(
                    f"stage_layers {split} does not partition "
                    f"{wl.cfg.n_layers} layers into {n_chunks} "
                    f"{self.schedule} chunks")
        elif self.stage_balance == "tflops":
            # interleaved: chunk c runs on stage c % n_stages, so its
            # quota follows that stage's compute
            split = balanced_stage_layers(
                wl.cfg.n_layers,
                [stage_tf[c % n_stages] for c in range(n_chunks)])
        elif self.stage_balance == "even":
            split = None        # legacy continuous flops/n_stages split
        else:
            raise ValueError(f"stage_balance {self.stage_balance!r} not "
                             f"in {STAGE_BALANCE_MODES}")
        if split is None:
            stage_l = None
        else:
            # per-stage layer totals (a stage owns every chunk with
            # c % n_stages == its index; v == 1 degrades to the split)
            stage_l = tuple(sum(split[c] for c in range(n_chunks)
                                if c % n_stages == s)
                            for s in range(n_stages))
        self._geom = _PipelineGeometry(
            tuple(order), n_stages, kind, virt, n_chunks, stage_sites,
            mesh_tflops, bubble, split, stage_l)
        return self._geom


def _make_context(wl: Workload, cluster: ClusterLike,
                  vms: Optional[Sequence[int]], *,
                  stage_order: Optional[Sequence[int]] = None,
                  stage_balance: str = "even",
                  stage_layers: Optional[Sequence[int]] = None,
                  schedule: str = "gpipe",
                  carrier_dtype: str = "fp32",
                  wire_dtype: str = "fp32",
                  comm: Optional[CommPrecision] = None,
                  calibration=None) -> CostContext:
    topo = as_topology(cluster)
    sel = topo.select(vms)
    sites = [topo.sites[i] for i in sel]
    gpus = [GPUS[g] for s in sites for g in s.gpus]
    n = len(gpus)
    ws = wire_scale(wire_dtype)
    cs = carrier_scale(carrier_dtype)
    if ws != 1.0:
        # stage-boundary activations are wire-quantizable (pipeshard's
        # CommPrecision.act == 1.0) — the narrower dtype carries them
        cs = min(cs, ws)
    if calibration is None:
        slowest = min(g.tflops for g in gpus) * 1e12
    else:
        # pool pace = the slowest site's achieved per-GPU rate; with no
        # overrides each per-site min is over the same datasheet floats,
        # so min-of-mins is bit-for-bit the flat min above
        slowest = min(calibration.gpu_tflops(topo, i) for i in sel) * 1e12
    return CostContext(
        wl=wl, topo=topo, sel=sel, sites=sites, n=n,
        tp=min(len(s.gpus) for s in sites),
        flops=wl.flops_per_step,
        slowest=slowest,
        g_bytes=wl.bytes_grads(),
        p_bytes=wl.bytes_params(),
        state=wl.bytes_train_state(),       # fp32 p+g+m+v (Alpa default)
        act=wl.activation_bytes_per_gpu(n),
        ovh=wl.OVERHEAD_GB,
        mem_avail=min(g.mem_gb for g in gpus),
        stage_order=stage_order, stage_balance=stage_balance,
        stage_layers=stage_layers, schedule=schedule,
        carrier_scale=cs, wire_scale=ws,
        comm=comm if comm is not None else CommPrecision(),
        cal=calibration)


# ---- compute components --------------------------------------------- #

def _pool_compute(ctx: CostContext) -> float:
    """Flat data-parallel pool: the slowest GPU paces everyone."""
    return ctx.flops / (ctx.n * ctx.slowest)


def _pipeline_compute(ctx: CostContext) -> float:
    """The slowest (layer-weighted) stage paces every tick, inflated by
    the schedule's bubble fraction."""
    g = ctx.pipeline()
    if g.split is None:
        return max(ctx.flops / g.n_stages / t for t in g.mesh_tflops) \
            * (1 + g.bubble)
    return max(li / ctx.wl.cfg.n_layers * ctx.flops / t
               for li, t in zip(g.stage_l, g.mesh_tflops)) \
        * (1 + g.bubble)


# ---- collective components ------------------------------------------ #

def _data_collective(ctx: CostContext) -> float:
    """One gradient all-reduce over the whole pool.  Fully
    wire-quantizable (each rank's optimizer consumes the reduced grads
    locally), so the byte volume scales with ``_state_byte_scale`` —
    exactly the legacy bytes at fp32."""
    return _collective_time(ctx.g_bytes * _state_byte_scale(ctx),
                            ctx.n, ctx.topo, ctx.sel, ctx.cal)


def _zero2_collective(ctx: CostContext) -> float:
    """Reduce-scatter grads + all-gather of updated fp16 params + the
    partitioned fp32 master sync => ~2.2x the Data volume, which is the
    paper's observed zero2-vs-data degradation ratio (Table II).  Of the
    2.2, the 0.2 master-sync share is the fp32 correction term
    (``CommPrecision.state = 2.0/2.2``); the grad scatter + param gather
    ride the wire dtype."""
    return 2.2 * _collective_time(ctx.g_bytes * _state_byte_scale(ctx),
                                  ctx.n, ctx.topo, ctx.sel, ctx.cal)


def _intraop_collective(ctx: CostContext) -> float:
    """Megatron-style: 4 all-reduces of activations per layer (fwd+bwd)
    over the whole pool."""
    return 4 * ctx.wl.cfg.n_layers * _collective_time(
        ctx.act_stream_bytes * _act_byte_scale(ctx), ctx.n, ctx.topo,
        ctx.sel, ctx.cal)


def _pipeline_collective(ctx: CostContext) -> float:
    """Intra-op all-reduces inside each stage's site, over its own intra
    link, weighted by the stage's layer share; the slowest stage paces."""
    g = ctx.pipeline()
    act_bytes = ctx.act_stream_bytes * _act_byte_scale(ctx)
    if g.split is None:       # keep the legacy expression bit-for-bit
        return max(
            4 * ctx.wl.cfg.n_layers / g.n_stages * _allreduce_time(
                act_bytes, len(s.gpus), _cal_intra(ctx.cal, ctx.topo, i))
            for i, s in zip(g.order, g.stage_sites))
    return max(
        4 * li * _allreduce_time(act_bytes, len(s.gpus),
                                 _cal_intra(ctx.cal, ctx.topo, i))
        for li, i, s in zip(g.stage_l, g.order, g.stage_sites))


def _shard_zero_collective(ctx: CostContext) -> float:
    """Hybrid intra-op × ZeRO-2: Megatron all-reduces stay *inside* each
    site (one tensor-parallel group per site over its intra link, each
    site a data-parallel replica handling 1/n_sites of the batch), plus
    the ZeRO-2 partition sync across the site replicas — the 2.2x-factor
    collective of ``zero2`` at 1/tp the volume (grads are already
    TP-sharded)."""
    n_rep = len(ctx.sel)
    share = ctx.act_stream_bytes * _act_byte_scale(ctx) / n_rep
    intra = max(4 * ctx.wl.cfg.n_layers
                * _allreduce_time(share, len(s.gpus),
                                  _cal_intra(ctx.cal, ctx.topo, i))
                for i, s in zip(ctx.sel, ctx.sites))
    inter = 2.2 * _collective_time(
        ctx.g_bytes * _state_byte_scale(ctx) / ctx.tp, n_rep,
        ctx.topo, ctx.sel, ctx.cal)
    return intra + inter


def _fsdp_collective(ctx: CostContext) -> float:
    """ZeRO-3: every layer's params are all-gathered before its forward
    AND again before its backward (nothing is kept), and grads are
    reduce-scattered straight into the shard layout — 3x the bf16 param
    bytes at gather rates, but 2L+1 latency rounds, which is what makes
    FSDP a LAN/single-site plan and never a WAN one."""
    layers = ctx.wl.cfg.n_layers
    s = _state_byte_scale(ctx)
    return 2 * layers * _gather_collective_time(
        ctx.p_bytes * s / layers, ctx.n, ctx.topo, ctx.sel, ctx.cal) \
        + _gather_collective_time(ctx.g_bytes * s, ctx.n, ctx.topo,
                                  ctx.sel, ctx.cal)


# ---- p2p components ------------------------------------------------- #

def _no_p2p(ctx: CostContext) -> float:
    """Collective-only techniques send nothing point-to-point."""
    return 0.0


def _pipeline_p2p(ctx: CostContext) -> float:
    """Per-boundary microbatch activation carriers: each microbatch
    crosses each stage boundary twice (fwd + bwd), paying that
    boundary's own link (N=2: the single WAN link).  Byte terms scale
    with the carrier dtype (``carrier_scale``); latency rounds do not."""
    g = ctx.pipeline()
    wl, topo, order = ctx.wl, ctx.topo, g.order
    carrier_bytes = ctx.act_stream_bytes * ctx.carrier_scale

    def boundary_s(link: Link) -> float:
        return 2 * (wl.microbatches * (carrier_bytes / wl.microbatches)
                    / (link.effective_gbps * 1e9)
                    + wl.microbatches * link.latency_s)

    p2p = sum(boundary_s(_cal_link(ctx.cal, topo, a, b))
              for a, b in zip(order[:-1], order[1:]))
    if g.kind == "interleaved" and g.n_stages > 1:
        # v virtual stages per device: every microbatch walks the
        # stage ring v times — each forward boundary link v times
        # and the wrap-around link (last stage back to first)
        # v - 1 times.  This is the schedule's price: the bubble
        # shrinks by v, the p2p bill grows by ~v.
        wrap = _cal_link(ctx.cal, topo, order[-1], order[0])
        p2p = g.virt * p2p + (g.virt - 1) * 2 * (
            carrier_bytes / (wrap.effective_gbps * 1e9)
            + wl.microbatches * wrap.latency_s)
    return p2p


# ---- memory component ----------------------------------------------- #

def _pipeline_act_factor(ctx: CostContext) -> float:
    """In-flight microbatches make Pipeshard the memory-hungry plan
    (paper §IV-G observation 3); 1F1B caps the stash at min(S, m) — the
    schedule dimension's memory lever (docs/schedules.md)."""
    inflight = pipeline_inflight_microbatches(
        ctx.schedule, ctx.pipeline().n_stages, ctx.wl.microbatches)
    return 1 + 0.5 * inflight


@dataclass(frozen=True)
class MemoryModel:
    """Per-GPU memory as explicit replication fractions of the train
    state, instead of per-technique inlined arithmetic.

    The fp32 train state (p+g+m+v, ``Workload.bytes_train_state``) is
    split into the bf16 param working copy (``Workload.bytes_params``)
    and the rest (grads + fp32 master + Adam moments, which every
    ZeRO-style stage partitions together):

    Attributes:
        params: where the bf16 param copy lives — ``"replicated"``
            (every GPU holds it all), ``"pool"`` (sharded over all n
            GPUs), or ``"tp"`` (sharded over the intra-site
            tensor-parallel group only).
        rest: where grads + optimizer state live — ``"replicated"`` or
            ``"pool"``.
        act_factor: multiplier on the per-GPU activation bytes (1.5 for
            intra-op all-gather buffers, a schedule-dependent callable
            for Pipeshard's in-flight stash).
    """
    params: str = "replicated"
    rest: str = "replicated"
    act_factor: Union[float, Callable[[CostContext], float]] = 1.0

    def state_bytes(self, ctx: CostContext) -> float:
        """Per-GPU bytes of params + grads + optimizer state."""
        if self.params == "replicated" and self.rest == "replicated":
            return ctx.state
        if self.params == "pool" and self.rest == "pool":
            return ctx.state / ctx.n
        if self.params == "replicated" and self.rest == "pool":
            return ctx.p_bytes + (ctx.state - ctx.p_bytes) / ctx.n
        if self.params == "tp" and self.rest == "pool":
            return ctx.p_bytes / ctx.tp \
                + (ctx.state - ctx.p_bytes) / ctx.n
        raise ValueError(f"unsupported memory placement "
                         f"(params={self.params!r}, rest={self.rest!r})")

    def mem_gb(self, ctx: CostContext) -> float:
        f = self.act_factor(ctx) if callable(self.act_factor) \
            else self.act_factor
        return (self.state_bytes(ctx) + f * ctx.act) / 1e9 + ctx.ovh


# ---- the registry --------------------------------------------------- #

@dataclass(frozen=True)
class TechniqueSpec:
    """A technique's price, assembled from composable cost components.

    Attributes:
        name: technique name (``core.plans.PLANS`` key).
        compute: ``(CostContext) -> seconds`` pace-setter term.
        collective: ``(CostContext) -> seconds`` collective traffic.
        memory: the per-GPU ``MemoryModel``.
        p2p: ``(CostContext) -> seconds`` point-to-point traffic
            (pipeline boundary carriers; zero for flat pools).
        paper: True for the paper's four Algorithm-1 techniques.
        summary: one-line description for docs/CLIs.
        comm_precision: which fractions of the technique's collective
            volume may ride a sub-fp32 ``wire_dtype``
            (docs/quantization.md); the default quantizes everything.
    """
    name: str
    compute: Callable[[CostContext], float]
    collective: Callable[[CostContext], float]
    memory: MemoryModel
    p2p: Callable[[CostContext], float] = _no_p2p
    paper: bool = False
    summary: str = ""
    comm_precision: CommPrecision = CommPrecision()


TECHNIQUE_SPECS: Dict[str, TechniqueSpec] = {}


def register_technique(spec: TechniqueSpec, *,
                       replace: bool = False) -> TechniqueSpec:
    """Add a ``TechniqueSpec`` to the registry (docs/cost-model.md
    walks through adding one).

    Args:
        spec: the spec to register under ``spec.name``.
        replace: allow overwriting an existing spec.

    Raises:
        ValueError: the name is taken and ``replace`` is False.
    """
    if spec.name in TECHNIQUE_SPECS and not replace:
        raise ValueError(f"technique {spec.name!r} already registered; "
                         f"pass replace=True to override")
    TECHNIQUE_SPECS[spec.name] = spec
    return spec


register_technique(TechniqueSpec(
    "data", _pool_compute, _data_collective,
    MemoryModel("replicated", "replicated", 1.0), paper=True,
    summary="pure data parallelism: replicated state, grad all-reduce"))
register_technique(TechniqueSpec(
    "zero2", _pool_compute, _zero2_collective,
    # fp16 replica + partitioned fp32 states: the paper's low-memory plan
    MemoryModel("replicated", "pool", 1.0), paper=True,
    summary="ZeRO-2: grads + optimizer state partitioned over the pool",
    # the 0.2 master-sync share of the 2.2x volume stays fp32
    comm_precision=CommPrecision(state=2.0 / 2.2)))
register_technique(TechniqueSpec(
    "shard", _pool_compute, _intraop_collective,
    # sharded states but activation replicas + all-gather buffers
    MemoryModel("pool", "pool", 1.5), paper=True,
    summary="Megatron intra-op: per-layer activation all-reduces"))
register_technique(TechniqueSpec(
    "pipeshard", _pipeline_compute, _pipeline_collective,
    MemoryModel("pool", "pool", _pipeline_act_factor),
    p2p=_pipeline_p2p, paper=True,
    summary="inter+intra-op: staged pipeline, intra-op inside each site"))
register_technique(TechniqueSpec(
    "shard_zero", _pool_compute, _shard_zero_collective,
    MemoryModel("tp", "pool", 1.5),
    summary="intra-op inside each site x ZeRO-2 across sites",
    # inter-site ZeRO sync carries the same fp32 master share as zero2
    comm_precision=CommPrecision(state=2.0 / 2.2)))
register_technique(TechniqueSpec(
    "fsdp", _pool_compute, _fsdp_collective,
    MemoryModel("pool", "pool", 1.0),
    summary="ZeRO-3/FSDP: per-layer param gathers, lowest memory",
    # of the ~3 param-volumes moved per step, the grad reduce-scatter
    # lands in fp32 master shards — the fp32 correction third
    comm_precision=CommPrecision(state=2.0 / 3.0)))

# Paper techniques first so exact-tie stable sorts keep paper winners;
# the beyond-paper specs extend, never reorder.
ALL_TECHNIQUES = TECHNIQUES + ("shard_zero", "fsdp")
assert set(ALL_TECHNIQUES) == set(TECHNIQUE_SPECS)


def technique_state_bytes(technique: str, wl: Workload,
                          cluster: ClusterLike,
                          vms: Optional[Sequence[int]] = None) -> float:
    """Per-GPU bytes of params + grads + optimizer state under a
    technique's ``MemoryModel`` — the quantity behind the
    ``fsdp <= shard_zero <= zero2 <= data`` ordering
    (tests/test_costmodel.py)."""
    spec = TECHNIQUE_SPECS[technique]
    return spec.memory.state_bytes(_make_context(wl, cluster, vms))


def memory_envelope_gb(cluster: ClusterLike,
                       vms: Optional[Sequence[int]] = None) -> float:
    """The site memory envelope every ``MemoryModel.mem_gb`` is judged
    against: the smallest participating GPU's memory in GB (the fp32
    training state must fit *everywhere* it is placed).  Exported so
    the static plan verifier (``repro.analysis.planlint``) can check
    ``technique_state_bytes`` against exactly the bound the feasibility
    filter uses."""
    topo = as_topology(cluster)
    sel = topo.select(vms)
    return min(GPUS[g].mem_gb
               for i in sel for g in topo.sites[i].gpus)


def technique_step_cost(technique: str, wl: Workload, cluster: ClusterLike,
                        vms: Optional[Sequence[int]] = None, *,
                        stage_order: Optional[Sequence[int]] = None,
                        stage_balance: str = "even",
                        stage_layers: Optional[Sequence[int]] = None,
                        schedule: str = "gpipe",
                        carrier_dtype: str = "fp32",
                        wire_dtype: str = "fp32",
                        calibration=None) -> StepCost:
    """Model one optimizer step of `technique` (paper §III) on a cluster
    or N-site topology, via the technique's registered
    ``TechniqueSpec`` components (docs/cost-model.md).

    Args:
        technique: a ``TECHNIQUE_SPECS`` key (``TECHNIQUES`` are the
            paper's four; ``ALL_TECHNIQUES`` adds ``shard_zero`` and
            ``fsdp``).
        wl: the workload being priced.
        cluster: legacy two-VM ``Cluster`` or an N-site ``Topology``.
        vms: which sites participate (None = all).  Heterogeneous GPUs
            make the *slowest* participant the pace-setter for
            data-parallel styles, while Pipeshard assigns stages per
            mesh (paper: meshes of equal capability).
        stage_order: Pipeshard only — explicit stage→site assignment;
            the pipeline crosses exactly the links between consecutive
            sites in this order, so on an asymmetric topology the order
            matters.
        stage_balance: Pipeshard only — "even" splits layers equally
            across stages (the paper's measured Alpa behavior — the
            default, so every paper artifact keeps its numbers);
            "tflops" weights stage (or chunk, under an interleaved
            schedule) sizes by per-site compute via
            ``balanced_stage_layers``.
        stage_layers: Pipeshard only — explicit per-stage layer counts
            overriding ``stage_balance``; must sum to the model's layer
            count.  Under an interleaved schedule the entries are *per
            virtual-stage chunk* (``n_stages * v`` of them, chunk c
            running on stage ``c % n_stages``).
        schedule: Pipeshard only — pipeline tick order (``SCHEDULES``,
            docs/schedules.md).  Selects the bubble term
            (``pipeline_bubble_fraction``), the activation-memory term
            (``pipeline_inflight_microbatches``), and — interleaved —
            the v-fold boundary crossings in the p2p term.
        carrier_dtype: Pipeshard only — inter-stage activation carrier
            dtype (``CARRIER_DTYPES``).  ``"bf16"`` halves the p2p byte
            terms vs the fp32 baseline; collectives and latency rounds
            are unaffected.
        wire_dtype: communication wire dtype (``WIRE_DTYPES``) — scales
            the wire-quantizable share of *collective* byte volumes per
            the technique's ``CommPrecision`` and, when narrower than
            ``carrier_dtype``, the Pipeshard p2p carriers too
            (docs/quantization.md).  ``"fp32"`` (default) is bit-for-bit
            the legacy pricing; latency rounds never scale.
        calibration: optional measured-rate overlay
            (``repro.calib.overlay.Calibration``, docs/calibration.md).
            Sites/links it covers are priced at their fitted achieved
            rates; everything else — and ``Calibration.identity()`` —
            keeps the analytic price bit-for-bit.

    Returns:
        A ``StepCost`` (compute_s, comm_s, memory required/available).

    Raises:
        ValueError: unknown technique / carrier / wire dtype, or an
            invalid pipeline placement (bad stage order, split, balance
            mode).
    """
    try:
        spec = TECHNIQUE_SPECS[technique]
    except KeyError:
        raise ValueError(
            f"unknown technique {technique!r}; registered: "
            f"{tuple(TECHNIQUE_SPECS)}") from None
    ctx = _make_context(wl, cluster, vms, stage_order=stage_order,
                        stage_balance=stage_balance,
                        stage_layers=stage_layers, schedule=schedule,
                        carrier_dtype=carrier_dtype,
                        wire_dtype=wire_dtype,
                        comm=spec.comm_precision,
                        calibration=calibration)
    compute = spec.compute(ctx)
    comm = spec.p2p(ctx) + spec.collective(ctx)
    mem = spec.memory.mem_gb(ctx)
    return StepCost(compute, comm, mem, ctx.mem_avail)


def epoch_minutes(technique: str, wl: Workload, cluster: ClusterLike,
                  vms: Optional[Sequence[int]] = None, *,
                  stage_order: Optional[Sequence[int]] = None,
                  stage_balance: str = "even",
                  stage_layers: Optional[Sequence[int]] = None,
                  schedule: str = "gpipe",
                  carrier_dtype: str = "fp32",
                  wire_dtype: str = "fp32",
                  calibration=None) -> Optional[float]:
    """Minutes per `epochs` epochs; None when the technique OOMs (the
    paper's '×' bars).  Keyword args as ``technique_step_cost``."""
    c = technique_step_cost(technique, wl, cluster, vms,
                            stage_order=stage_order,
                            stage_balance=stage_balance,
                            stage_layers=stage_layers,
                            schedule=schedule,
                            carrier_dtype=carrier_dtype,
                            wire_dtype=wire_dtype,
                            calibration=calibration)
    if not c.fits:
        return None
    return c.total_s * wl.steps_per_epoch * wl.epochs / 60.0


def avg_tflops(technique: str, wl: Workload, cluster: ClusterLike,
               vms: Optional[Sequence[int]] = None, *,
               stage_order: Optional[Sequence[int]] = None,
               stage_balance: str = "even",
               stage_layers: Optional[Sequence[int]] = None,
               schedule: str = "gpipe",
               carrier_dtype: str = "fp32",
               wire_dtype: str = "fp32",
               calibration=None) -> Optional[float]:
    """Average achieved TFLOP/s of one step (model FLOPs / step time);
    None when the technique OOMs.  Keyword args as
    ``technique_step_cost``."""
    c = technique_step_cost(technique, wl, cluster, vms,
                            stage_order=stage_order,
                            stage_balance=stage_balance,
                            stage_layers=stage_layers,
                            schedule=schedule,
                            carrier_dtype=carrier_dtype,
                            wire_dtype=wire_dtype,
                            calibration=calibration)
    if not c.fits:
        return None
    return wl.flops_per_step / c.total_s / 1e12
