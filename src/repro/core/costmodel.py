"""α–β analytical cost model of the paper's FABRIC GPU clusters.

Reproduces the paper's Figures 3–7 and Table II: per-technique pretraining
time for GPT-2 medium/large on two-VM slices with measured site-to-site
latencies.  The model is deliberately simple — compute term from achievable
per-GPU FLOP/s, communication terms from per-step traffic of each technique
over the cluster's link graph with latency α and bandwidth β — because the
*paper's claims are about orderings and trends*, which is what
EXPERIMENTS.md §Paper-validation checks.

Since the N-site generalization (core/topology.py, DESIGN.md §5) the
pricing works on an arbitrary ``Topology``: collectives pay the worst link
on their spanning set, Pipeshard pays each stage-boundary link it actually
crosses in its stage→site order.  The legacy two-VM ``Cluster`` is kept as
a thin shim whose ``topology()`` is the N=2 special case, so every paper
artifact (PAPER_CLUSTERS, benchmarks, Algorithm 1) keeps its exact shape
and numbers.

The same machinery prices TPU meshes (ICI vs DCN) for plan selection when
no hardware is attached — the dry-run roofline (launch/roofline.py) uses
compiled HLO instead wherever it can.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core.topology import (GPUS, GPUSpec, Link, PCIE, Site,
                                 TCP_WINDOW_BYTES, Topology, two_site)

# Legacy alias: the paper called a site a "VM".
VM = Site


@dataclass(frozen=True)
class Cluster:
    """Two-VM FABRIC slice (paper Table I) — legacy N=2 shim over
    ``core.topology.Topology``."""
    name: str
    vms: Tuple[Site, ...]
    wan: Link                              # inter-VM (L2Bridge / L2STS)

    def all_gpus(self) -> List[GPUSpec]:
        return [GPUS[g] for vm in self.vms for g in vm.gpus]

    def topology(self) -> Topology:
        """Embed as the N=2 special case of the site/link graph."""
        import itertools
        sites = tuple(
            Site(vm.gpus, vm.intra, vm.name or f"V{i + 1}")
            for i, vm in enumerate(self.vms))
        links = {(i, j): self.wan
                 for i, j in itertools.combinations(range(len(sites)), 2)}
        return Topology(self.name, sites, links)


ClusterLike = Union[Cluster, Topology]


def as_topology(cluster: ClusterLike) -> Topology:
    return cluster.topology() if isinstance(cluster, Cluster) else cluster


def fabric_cluster(name: str, gpus1: Tuple[str, str], gpus2: Tuple[str, str],
                   latency_ms: float, wan_gbps: float = 3.0) -> Cluster:
    """WAN bandwidth: NCCL over TCP/IP on FABRIC achieves only a few GB/s
    of the 100 Gbps links (paper §II-C: TCP/IP, no GPUDirect)."""
    return Cluster(name, (Site(tuple(gpus1)), Site(tuple(gpus2))),
                   Link(latency_ms * 1e-3, wan_gbps))


# The paper's five slices (Table I).
PAPER_CLUSTERS: Dict[str, Cluster] = {
    "TACC-TACC": fabric_cluster("TACC-TACC", ("RTX", "RTX"), ("T4", "T4"), 0.1),
    "UTAH-GPN": fabric_cluster("UTAH-GPN", ("RTX", "RTX"), ("T4", "T4"), 20.2),
    "UTAH-MASS": fabric_cluster("UTAH-MASS", ("RTX", "RTX"), ("RTX", "RTX"), 57.4),
    "BRIS-STAR": fabric_cluster("BRIS-STAR", ("A30", "A30"), ("RTX", "RTX"), 95.9),
    "GAT-AMST": fabric_cluster("GAT-AMST", ("A30", "A30"), ("A30", "A30"), 103.0),
}

# The same slices as 2-site topologies (what PlanSearch consumes).
PAPER_TOPOLOGIES: Dict[str, Topology] = {
    name: c.topology() for name, c in PAPER_CLUSTERS.items()
}


# --------------------------------------------------------------------- #
# workload description
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Workload:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    steps_per_epoch: int
    epochs: int = 20                      # the paper runs 20 epochs
    microbatches: int = 4

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch

    @property
    def flops_per_step(self) -> float:
        return 6.0 * self.cfg.active_param_count() * self.tokens_per_step

    def bytes_params(self) -> float:
        return 2.0 * self.cfg.param_count()          # fp16/bf16 on the wire

    def bytes_grads(self) -> float:
        return 2.0 * self.cfg.param_count()

    # Alpa's gpt-2 training keeps fp32 master params + fp32 Adam moments:
    def bytes_train_state(self) -> float:           # p+g+m+v, fp32
        return 16.0 * self.cfg.param_count()

    ACT_FACTOR = 10.0  # no-remat Alpa training: activations + attn scores
    OVERHEAD_GB = 2.0  # CUDA context, NCCL buffers, framework workspace

    def activation_bytes_per_gpu(self, n_gpus: int) -> float:
        c = self.cfg
        per_layer = self.tokens_per_step // max(n_gpus, 1) * c.d_model * 2
        return per_layer * c.n_layers * self.ACT_FACTOR


# the paper pretrains on 20231101.ace (~8MB dump): roughly 2M tokens
def paper_workload(cfg: ModelConfig, *, global_batch: int = 32) -> Workload:
    tokens = 2_000_000
    steps = max(1, tokens // (cfg.max_seq_len * global_batch))
    return Workload(cfg, cfg.max_seq_len, global_batch, steps)


# --------------------------------------------------------------------- #
# per-technique cost
# --------------------------------------------------------------------- #

LOG2E = 1.4426950408889634

TECHNIQUES = ("data", "zero2", "shard", "pipeshard")

# Pipeline tick-order schedules (docs/schedules.md).  "gpipe" is the
# paper's measured Alpa behavior (all forwards, then all backwards —
# bubble (S-1)/m, m microbatches in flight); "1f1b" is PipeDream-Flush
# (same bubble, but a stage never holds more than S in-flight
# microbatches); "interleaved" is the Megatron-LM interleaved 1F1B
# schedule with v virtual stages (layer chunks) per device — bubble
# shrinks to (S-1)/(v*m) at the price of v crossings of every stage
# boundary.  "interleaved" defaults to v=2; "interleaved<k>" (e.g.
# "interleaved4") sets v explicitly.
SCHEDULES = ("gpipe", "1f1b", "interleaved")

DEFAULT_INTERLEAVE = 2


def parse_schedule(schedule: str) -> Tuple[str, int]:
    """Split a schedule name into (kind, virtual stages per device).

    Args:
        schedule: ``"gpipe"``, ``"1f1b"``, ``"interleaved"`` (v=2), or
            ``"interleaved<v>"`` with an explicit v >= 2 (e.g.
            ``"interleaved4"``).

    Returns:
        ``(kind, v)`` with ``kind`` in ``SCHEDULES`` and ``v == 1``
        except for interleaved schedules.

    Raises:
        ValueError: unknown schedule name or v < 2 on interleaved.
    """
    if schedule in ("gpipe", "1f1b"):
        return schedule, 1
    if schedule == "interleaved":
        return "interleaved", DEFAULT_INTERLEAVE
    if schedule.startswith("interleaved"):
        try:
            v = int(schedule[len("interleaved"):])
        except ValueError:
            raise ValueError(f"unknown schedule {schedule!r}; expected one "
                             f"of {SCHEDULES} or 'interleaved<v>'") from None
        if v < 2:
            raise ValueError(f"interleaved needs >= 2 virtual stages, "
                             f"got {schedule!r}")
        return "interleaved", v
    raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                     f"{SCHEDULES} or 'interleaved<v>'")


def pipeline_bubble_fraction(schedule: str, n_stages: int,
                             n_micro: int) -> float:
    """Idle fraction of the pipeline, relative to ideal compute time.

    GPipe and 1F1B both pay ``(S-1)/m`` — 1F1B reorders backwards
    between forwards but drains the same warm-up/flush ramps.  The
    interleaved schedule cuts the ramp by its v virtual stages:
    ``(S-1)/(v*m)`` (Narayanan et al. 2021).

    Args:
        schedule: schedule name (see ``parse_schedule``).
        n_stages: pipeline stages S (devices/meshes in the ring).
        n_micro: microbatches m per optimizer step.

    Returns:
        The bubble fraction b, so step compute time scales as (1 + b).
    """
    kind, v = parse_schedule(schedule)
    bubble = (n_stages - 1) / n_micro
    return bubble / v if kind == "interleaved" else bubble


def pipeline_inflight_microbatches(schedule: str, n_stages: int,
                                   n_micro: int) -> float:
    """Microbatches of activations a stage holds at the schedule's peak.

    GPipe stashes every forward before the first backward: m in flight.
    1F1B starts backwards as soon as the pipeline fills, so a stage
    never holds more than ``min(S, m)``.  The interleaved schedule
    keeps 1F1B's bound but holds partially-processed chunks of the
    next wave: ``min(S, m) * (1 + (S-1)/(S*v))`` (Narayanan et al.
    2021) — slightly above 1F1B, still far below GPipe at large m.

    Args:
        schedule: schedule name (see ``parse_schedule``).
        n_stages: pipeline stages S.
        n_micro: microbatches m per optimizer step.

    Returns:
        Effective in-flight microbatch count (fractional for
        interleaved), monotone non-decreasing in m for every schedule.
    """
    kind, v = parse_schedule(schedule)
    if kind == "gpipe":
        return float(n_micro)
    inflight = float(min(n_stages, n_micro))
    if kind == "1f1b":
        return inflight
    return inflight * (1.0 + (n_stages - 1) / (n_stages * v))

# Pipeline stage-size policies: "even" reproduces the paper's measured
# Alpa behavior (equal meshes -> equal layer slices, what Table II and
# Algorithm 1 were run with); "tflops" weights stage sizes by per-site
# compute so a T4 site gets fewer layers than an A30 site (ROADMAP
# "heterogeneous stage balancing", docs/topology-and-search.md).
STAGE_BALANCE_MODES = ("even", "tflops")


def stage_compute_tflops(topo: Topology, order: Sequence[int]
                         ) -> List[float]:
    """Achievable TFLOP/s of each pipeline stage's site, in stage order.

    Args:
        topo: the topology the stages are placed on.
        order: site index per stage (a ``Placement.stage_order`` or plain
            site subset).

    Returns:
        One entry per stage: the site's GPU count times its slowest GPU's
        achievable TFLOP/s (meshes are paced by their slowest member).
    """
    return [min(GPUS[g].tflops for g in topo.sites[i].gpus)
            * len(topo.sites[i].gpus) for i in order]


def balanced_stage_layers(n_layers: int, stage_tflops: Sequence[float]
                          ) -> Tuple[int, ...]:
    """Split ``n_layers`` across stages proportionally to stage TFLOP/s.

    Largest-remainder allocation with one layer reserved per stage, so the
    result always sums to ``n_layers``, every stage gets >= 1 layer, and a
    faster stage never gets fewer layers than a slower one.  Homogeneous
    stages degrade to the even split.

    Args:
        n_layers: total layers to distribute (>= number of stages).
        stage_tflops: per-stage achievable TFLOP/s (all > 0).

    Returns:
        Per-stage layer counts, in stage order.
    """
    k = len(stage_tflops)
    if k < 1:
        raise ValueError("need at least one stage")
    if n_layers < k:
        raise ValueError(f"cannot fill {k} stages with {n_layers} layers")
    if min(stage_tflops) <= 0:
        raise ValueError(f"non-positive stage TFLOP/s in {stage_tflops}")
    total = float(sum(stage_tflops))
    spare = n_layers - k
    quotas = [spare * t / total for t in stage_tflops]
    layers = [1 + int(q) for q in quotas]
    # leftover goes to the largest fractional parts (ties: earliest stage)
    order = sorted(range(k), key=lambda i: (-(quotas[i] - int(quotas[i])), i))
    for i in order[:n_layers - sum(layers)]:
        layers[i] += 1
    return tuple(layers)


@dataclass
class StepCost:
    compute_s: float
    comm_s: float
    mem_required_gb: float
    mem_available_gb: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    @property
    def fits(self) -> bool:
        return self.mem_required_gb <= self.mem_available_gb


def _allreduce_time(bytes_total: float, n: int, link: Link) -> float:
    """Ring all-reduce: 2(n-1)/n × bytes over the slowest link, with 2(n-1)
    latency hops, at the TCP-effective bandwidth."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * link.latency_s \
        + 2 * (n - 1) / n * bytes_total / (link.effective_gbps * 1e9)


def _collective_time(bytes_total: float, n: int, topo: Topology,
                     sites: Sequence[int]) -> float:
    """All-reduce over a site subset: the ring crosses every site pair's
    path, so the *worst* spanning link prices the collective (the N=2
    special case is exactly the old single-``wan``-field rule)."""
    if len(sites) <= 1:
        return _allreduce_time(bytes_total, n, topo.sites[sites[0]].intra)
    return max(_allreduce_time(bytes_total, n, l)
               for l in topo.spanning_links(sites))


def technique_step_cost(technique: str, wl: Workload, cluster: ClusterLike,
                        vms: Optional[Sequence[int]] = None, *,
                        stage_order: Optional[Sequence[int]] = None,
                        stage_balance: str = "even",
                        stage_layers: Optional[Sequence[int]] = None,
                        schedule: str = "gpipe") -> StepCost:
    """Model one optimizer step of `technique` (paper §III) on a cluster
    or N-site topology.

    Args:
        technique: one of ``TECHNIQUES``.
        wl: the workload being priced.
        cluster: legacy two-VM ``Cluster`` or an N-site ``Topology``.
        vms: which sites participate (None = all).  Heterogeneous GPUs
            make the *slowest* participant the pace-setter for
            data-parallel styles, while Pipeshard assigns stages per
            mesh (paper: meshes of equal capability).
        stage_order: Pipeshard only — explicit stage→site assignment;
            the pipeline crosses exactly the links between consecutive
            sites in this order, so on an asymmetric topology the order
            matters.
        stage_balance: Pipeshard only — "even" splits layers equally
            across stages (the paper's measured Alpa behavior — the
            default, so every paper artifact keeps its numbers);
            "tflops" weights stage (or chunk, under an interleaved
            schedule) sizes by per-site compute via
            ``balanced_stage_layers``.
        stage_layers: Pipeshard only — explicit per-stage layer counts
            overriding ``stage_balance``; must sum to the model's layer
            count.  Under an interleaved schedule the entries are *per
            virtual-stage chunk* (``n_stages * v`` of them, chunk c
            running on stage ``c % n_stages``).
        schedule: Pipeshard only — pipeline tick order (``SCHEDULES``,
            docs/schedules.md).  Selects the bubble term
            (``pipeline_bubble_fraction``), the activation-memory term
            (``pipeline_inflight_microbatches``), and — interleaved —
            the v-fold boundary crossings in the p2p term.

    Returns:
        A ``StepCost`` (compute_s, comm_s, memory required/available).
    """
    topo = as_topology(cluster)
    sel = topo.select(vms)
    sites = [topo.sites[i] for i in sel]
    gpus = [GPUS[g] for s in sites for g in s.gpus]
    n = len(gpus)

    flops = wl.flops_per_step
    slowest = min(g.tflops for g in gpus) * 1e12
    g_bytes = wl.bytes_grads()
    p_bytes = wl.bytes_params()
    state = wl.bytes_train_state()          # fp32 p+g+m+v (Alpa default)
    act = wl.activation_bytes_per_gpu(n)
    ovh = wl.OVERHEAD_GB
    mem_avail = min(g.mem_gb for g in gpus)

    if technique == "data":
        compute = flops / (n * slowest)
        comm = _collective_time(g_bytes, n, topo, sel)
        mem = (state + act) / 1e9 + ovh
    elif technique == "zero2":
        compute = flops / (n * slowest)
        # reduce-scatter grads + all-gather of updated fp16 params + the
        # partitioned fp32 master sync => ~2.2x the Data volume, which is
        # the paper's observed zero2-vs-data degradation ratio (Table II)
        comm = 2.2 * _collective_time(g_bytes, n, topo, sel)
        # fp16 replica + partitioned fp32 states: the lowest-memory plan
        mem = (p_bytes + (state - p_bytes) / n + act) / 1e9 + ovh
    elif technique == "shard":
        compute = flops / (n * slowest)
        # Megatron-style: 4 all-reduces of activations per layer (fwd+bwd)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        comm = 4 * wl.cfg.n_layers * _collective_time(act_bytes, n, topo, sel)
        # sharded states but activation replicas + all-gather buffers
        mem = (state / n + 1.5 * act) / 1e9 + ovh
    elif technique == "pipeshard":
        # stages = sites of the selection in stage_order; shard (intra-op)
        # inside each site over PCIe; inter-stage point-to-point microbatch
        # activations over each crossed stage-boundary link.
        order = sel if stage_order is None else topo.select(stage_order)
        if sorted(order) != sorted(sel):
            raise ValueError(
                f"stage_order {order} is not a permutation of sites {sel}")
        n_stages = max(len(order), 1)
        kind, virt = parse_schedule(schedule)
        n_chunks = n_stages * virt
        stage_sites = [topo.sites[i] for i in order]
        stage_tf = stage_compute_tflops(topo, order)
        mesh_tflops = [t * 1e12 for t in stage_tf]
        bubble = pipeline_bubble_fraction(schedule, n_stages,
                                          wl.microbatches)
        if stage_layers is not None:
            split: Optional[Tuple[int, ...]] = tuple(stage_layers)
            if len(split) != n_chunks or min(split, default=0) < 1 \
                    or sum(split) != wl.cfg.n_layers:
                raise ValueError(
                    f"stage_layers {split} does not partition "
                    f"{wl.cfg.n_layers} layers into {n_chunks} "
                    f"{schedule} chunks")
        elif stage_balance == "tflops":
            # interleaved: chunk c runs on stage c % n_stages, so its
            # quota follows that stage's compute
            split = balanced_stage_layers(
                wl.cfg.n_layers,
                [stage_tf[c % n_stages] for c in range(n_chunks)])
        elif stage_balance == "even":
            split = None        # legacy continuous flops/n_stages split
        else:
            raise ValueError(f"stage_balance {stage_balance!r} not in "
                             f"{STAGE_BALANCE_MODES}")
        if split is None:
            compute = max(flops / n_stages / t for t in mesh_tflops) \
                * (1 + bubble)
        else:
            # per-stage layer totals (a stage owns every chunk with
            # c % n_stages == its index; v == 1 degrades to the split)
            stage_l = [sum(split[c] for c in range(n_chunks)
                           if c % n_stages == s) for s in range(n_stages)]
            # the slowest (layers-weighted) stage paces every tick
            compute = max(li / wl.cfg.n_layers * flops / t
                          for li, t in zip(stage_l, mesh_tflops)) \
                * (1 + bubble)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        # each microbatch crosses each stage boundary twice (fwd + bwd),
        # paying that boundary's own link (N=2: the single WAN link)
        p2p = sum(
            2 * (wl.microbatches * (act_bytes / wl.microbatches)
                 / (topo.link(a, b).effective_gbps * 1e9)
                 + wl.microbatches * topo.link(a, b).latency_s)
            for a, b in zip(order[:-1], order[1:]))
        if kind == "interleaved" and n_stages > 1:
            # v virtual stages per device: every microbatch walks the
            # stage ring v times — each forward boundary link v times
            # and the wrap-around link (last stage back to first)
            # v - 1 times.  This is the schedule's price: the bubble
            # shrinks by v, the p2p bill grows by ~v.
            wrap = topo.link(order[-1], order[0])
            p2p = virt * p2p + (virt - 1) * 2 * (
                act_bytes / (wrap.effective_gbps * 1e9)
                + wl.microbatches * wrap.latency_s)
        if split is None:       # keep the legacy expression bit-for-bit
            intra_comm = max(
                4 * wl.cfg.n_layers / n_stages * _allreduce_time(
                    act_bytes, len(s.gpus), s.intra)
                for s in stage_sites)
        else:
            intra_comm = max(
                4 * li * _allreduce_time(act_bytes, len(s.gpus), s.intra)
                for li, s in zip(stage_l, stage_sites))
        comm = p2p + intra_comm
        # in-flight microbatches make Pipeshard the memory-hungry plan
        # (paper §IV-G observation 3); 1F1B caps the stash at min(S, m)
        # — the schedule dimension's memory lever (docs/schedules.md)
        inflight = pipeline_inflight_microbatches(schedule, n_stages,
                                                  wl.microbatches)
        mem = (state / n + act * (1 + 0.5 * inflight)) / 1e9 + ovh
    else:
        raise ValueError(technique)
    return StepCost(compute, comm, mem, mem_avail)


def epoch_minutes(technique: str, wl: Workload, cluster: ClusterLike,
                  vms: Optional[Sequence[int]] = None, *,
                  stage_order: Optional[Sequence[int]] = None,
                  stage_balance: str = "even",
                  stage_layers: Optional[Sequence[int]] = None,
                  schedule: str = "gpipe") -> Optional[float]:
    """Minutes per `epochs` epochs; None when the technique OOMs (the
    paper's '×' bars).  Keyword args as ``technique_step_cost``."""
    c = technique_step_cost(technique, wl, cluster, vms,
                            stage_order=stage_order,
                            stage_balance=stage_balance,
                            stage_layers=stage_layers,
                            schedule=schedule)
    if not c.fits:
        return None
    return c.total_s * wl.steps_per_epoch * wl.epochs / 60.0


def avg_tflops(technique: str, wl: Workload, cluster: ClusterLike,
               vms: Optional[Sequence[int]] = None, *,
               stage_order: Optional[Sequence[int]] = None,
               stage_balance: str = "even",
               stage_layers: Optional[Sequence[int]] = None,
               schedule: str = "gpipe") -> Optional[float]:
    """Average achieved TFLOP/s of one step (model FLOPs / step time);
    None when the technique OOMs.  Keyword args as
    ``technique_step_cost``."""
    c = technique_step_cost(technique, wl, cluster, vms,
                            stage_order=stage_order,
                            stage_balance=stage_balance,
                            stage_layers=stage_layers,
                            schedule=schedule)
    if not c.fits:
        return None
    return wl.flops_per_step / c.total_s / 1e12
