"""α–β analytical cost model of the paper's FABRIC GPU clusters.

Reproduces the paper's Figures 3–7 and Table II: per-technique pretraining
time for GPT-2 medium/large on two-VM slices with measured site-to-site
latencies.  The model is deliberately simple — compute term from achievable
per-GPU FLOP/s, communication terms from per-step traffic of each technique
over (intra-VM PCIe, inter-VM WAN) links with latency α and bandwidth β —
because the *paper's claims are about orderings and trends*, which is what
EXPERIMENTS.md §Paper-validation checks.

The same machinery prices TPU meshes (ICI vs DCN) for plan selection when
no hardware is attached — the dry-run roofline (launch/roofline.py) uses
compiled HLO instead wherever it can.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------- #
# hardware vocabulary
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class GPUSpec:
    name: str
    tflops: float          # achievable mixed-precision TFLOP/s for GEMMs
    mem_gb: float
    mem_bw_gbps: float


# Achievable (not peak-marketing) numbers for the paper's cards:
GPUS = {
    # Quadro RTX 6000: 16.3 fp32 / ~32 fp16-ish; achievable trainer ~20
    "RTX": GPUSpec("RTX", 20.0, 24.0, 672.0),
    # Tesla T4: 8.1 fp32, 65 fp16 peak but bandwidth-starved; ~10 achievable
    "T4": GPUSpec("T4", 10.0, 16.0, 320.0),
    # A30: 10.3 fp32 / 165 bf16 peak; ~25 achievable with its 933 GB/s
    "A30": GPUSpec("A30", 25.0, 24.0, 933.0),
}


TCP_WINDOW_BYTES = 8e6   # effective socket window of NCCL-over-TCP streams


@dataclass(frozen=True)
class Link:
    latency_s: float
    bandwidth_gbps: float  # GB/s usable at zero RTT

    @property
    def effective_gbps(self) -> float:
        """Single-stream TCP throughput is window/RTT-limited (paper §II-C:
        NCCL uses TCP/IP between VMs, no GPUDirect) — this is what makes
        Data/ZeRO2/Shard collapse on high-latency slices (Table II)."""
        if self.latency_s <= 0:
            return self.bandwidth_gbps
        return min(self.bandwidth_gbps,
                   TCP_WINDOW_BYTES / self.latency_s / 1e9)


@dataclass(frozen=True)
class VM:
    gpus: Tuple[str, ...]                 # e.g. ("RTX", "RTX")
    intra: Link = Link(5e-6, 12.0)        # PCIe within a VM


@dataclass(frozen=True)
class Cluster:
    """Two-VM FABRIC slice (paper Table I)."""
    name: str
    vms: Tuple[VM, ...]
    wan: Link                              # inter-VM (L2Bridge / L2STS)

    def all_gpus(self) -> List[GPUSpec]:
        return [GPUS[g] for vm in self.vms for g in vm.gpus]


def fabric_cluster(name: str, gpus1: Tuple[str, str], gpus2: Tuple[str, str],
                   latency_ms: float, wan_gbps: float = 3.0) -> Cluster:
    """WAN bandwidth: NCCL over TCP/IP on FABRIC achieves only a few GB/s
    of the 100 Gbps links (paper §II-C: TCP/IP, no GPUDirect)."""
    return Cluster(name, (VM(gpus1), VM(gpus2)),
                   Link(latency_ms * 1e-3, wan_gbps))


# The paper's five slices (Table I).
PAPER_CLUSTERS: Dict[str, Cluster] = {
    "TACC-TACC": fabric_cluster("TACC-TACC", ("RTX", "RTX"), ("T4", "T4"), 0.1),
    "UTAH-GPN": fabric_cluster("UTAH-GPN", ("RTX", "RTX"), ("T4", "T4"), 20.2),
    "UTAH-MASS": fabric_cluster("UTAH-MASS", ("RTX", "RTX"), ("RTX", "RTX"), 57.4),
    "BRIS-STAR": fabric_cluster("BRIS-STAR", ("A30", "A30"), ("RTX", "RTX"), 95.9),
    "GAT-AMST": fabric_cluster("GAT-AMST", ("A30", "A30"), ("A30", "A30"), 103.0),
}


# --------------------------------------------------------------------- #
# workload description
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Workload:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    steps_per_epoch: int
    epochs: int = 20                      # the paper runs 20 epochs
    microbatches: int = 4

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch

    @property
    def flops_per_step(self) -> float:
        return 6.0 * self.cfg.active_param_count() * self.tokens_per_step

    def bytes_params(self) -> float:
        return 2.0 * self.cfg.param_count()          # fp16/bf16 on the wire

    def bytes_grads(self) -> float:
        return 2.0 * self.cfg.param_count()

    # Alpa's gpt-2 training keeps fp32 master params + fp32 Adam moments:
    def bytes_train_state(self) -> float:           # p+g+m+v, fp32
        return 16.0 * self.cfg.param_count()

    ACT_FACTOR = 10.0  # no-remat Alpa training: activations + attn scores
    OVERHEAD_GB = 2.0  # CUDA context, NCCL buffers, framework workspace

    def activation_bytes_per_gpu(self, n_gpus: int) -> float:
        c = self.cfg
        per_layer = self.tokens_per_step // max(n_gpus, 1) * c.d_model * 2
        return per_layer * c.n_layers * self.ACT_FACTOR


# the paper pretrains on 20231101.ace (~8MB dump): roughly 2M tokens
def paper_workload(cfg: ModelConfig, *, global_batch: int = 32) -> Workload:
    tokens = 2_000_000
    steps = max(1, tokens // (cfg.max_seq_len * global_batch))
    return Workload(cfg, cfg.max_seq_len, global_batch, steps)


# --------------------------------------------------------------------- #
# per-technique cost
# --------------------------------------------------------------------- #

LOG2E = 1.4426950408889634


@dataclass
class StepCost:
    compute_s: float
    comm_s: float
    mem_required_gb: float
    mem_available_gb: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    @property
    def fits(self) -> bool:
        return self.mem_required_gb <= self.mem_available_gb


def _allreduce_time(bytes_total: float, n: int, link: Link) -> float:
    """Ring all-reduce: 2(n-1)/n × bytes over the slowest link, with 2(n-1)
    latency hops, at the TCP-effective bandwidth."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * link.latency_s \
        + 2 * (n - 1) / n * bytes_total / (link.effective_gbps * 1e9)


def _worst_link(cluster: Cluster, spans_wan: bool) -> Link:
    return cluster.wan if spans_wan else cluster.vms[0].intra


def technique_step_cost(technique: str, wl: Workload, cluster: Cluster,
                        vms: Optional[List[int]] = None) -> StepCost:
    """Model one optimizer step of `technique` on `cluster` (paper §III).

    vms: which VMs participate (None = all).  Heterogeneous GPUs make the
    *slowest* participant the pace-setter for data-parallel styles, while
    Pipeshard assigns stages per mesh (paper: meshes of equal capability).
    """
    sel = cluster.vms if vms is None else [cluster.vms[i] for i in vms]
    gpus = [GPUS[g] for vm in sel for g in vm.gpus]
    n = len(gpus)
    spans_wan = len(sel) > 1
    link = _worst_link(cluster, spans_wan)
    intra = sel[0].intra

    flops = wl.flops_per_step
    slowest = min(g.tflops for g in gpus) * 1e12
    g_bytes = wl.bytes_grads()
    p_bytes = wl.bytes_params()
    state = wl.bytes_train_state()          # fp32 p+g+m+v (Alpa default)
    act = wl.activation_bytes_per_gpu(n)
    ovh = wl.OVERHEAD_GB
    mem_avail = min(g.mem_gb for g in gpus)

    if technique == "data":
        compute = flops / (n * slowest)
        comm = _allreduce_time(g_bytes, n, link)
        mem = (state + act) / 1e9 + ovh
    elif technique == "zero2":
        compute = flops / (n * slowest)
        # reduce-scatter grads + all-gather of updated fp16 params + the
        # partitioned fp32 master sync => ~2.2x the Data volume, which is
        # the paper's observed zero2-vs-data degradation ratio (Table II)
        comm = 2.2 * _allreduce_time(g_bytes, n, link)
        # fp16 replica + partitioned fp32 states: the lowest-memory plan
        mem = (p_bytes + (state - p_bytes) / n + act) / 1e9 + ovh
    elif technique == "shard":
        compute = flops / (n * slowest)
        # Megatron-style: 4 all-reduces of activations per layer (fwd+bwd)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        comm = 4 * wl.cfg.n_layers * _allreduce_time(act_bytes, n, link)
        # sharded states but activation replicas + all-gather buffers
        mem = (state / n + 1.5 * act) / 1e9 + ovh
    elif technique == "pipeshard":
        # stages = VMs; shard (intra-op) inside each VM over PCIe;
        # inter-stage point-to-point microbatch activations over WAN.
        n_stages = max(len(sel), 1)
        per_mesh = n // n_stages
        stage_flops = flops / n_stages
        mesh_tflops = [min(GPUS[g].tflops for g in vm.gpus) * 1e12
                       * len(vm.gpus) for vm in sel]
        bubble = (n_stages - 1) / wl.microbatches
        compute = max(stage_flops / t for t in mesh_tflops) * (1 + bubble)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        # each microbatch crosses each stage boundary twice (fwd + bwd)
        p2p = 2 * (n_stages - 1) * (
            wl.microbatches * (act_bytes / wl.microbatches)
            / (cluster.wan.effective_gbps * 1e9)
            + wl.microbatches * cluster.wan.latency_s)
        intra_comm = 4 * wl.cfg.n_layers / n_stages * _allreduce_time(
            act_bytes, per_mesh, intra)
        comm = (p2p if spans_wan else 0.0) + intra_comm
        # in-flight microbatches make Pipeshard the memory-hungry plan
        # (paper §IV-G observation 3)
        mem = (state / n + act * (1 + 0.5 * wl.microbatches)) / 1e9 + ovh
    else:
        raise ValueError(technique)
    return StepCost(compute, comm, mem, mem_avail)


def epoch_minutes(technique: str, wl: Workload, cluster: Cluster,
                  vms: Optional[List[int]] = None) -> Optional[float]:
    """Minutes per `epochs` epochs; None when the technique OOMs (the
    paper's '×' bars)."""
    c = technique_step_cost(technique, wl, cluster, vms)
    if not c.fits:
        return None
    return c.total_s * wl.steps_per_epoch * wl.epochs / 60.0


def avg_tflops(technique: str, wl: Workload, cluster: Cluster,
               vms: Optional[List[int]] = None) -> Optional[float]:
    c = technique_step_cost(technique, wl, cluster, vms)
    if not c.fits:
        return None
    return wl.flops_per_step / c.total_s / 1e12
