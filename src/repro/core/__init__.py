"""The paper's primary contribution: parallelization techniques as
first-class execution plans (Data / ZeRO2 / Shard / Pipeshard), the
pipeline runtime, plan-aware step builders, the N-site cluster topology
model + FABRIC cost model, and the plan search generalizing Algorithm 1
(technique selection)."""
from repro.core.plans import PLANS, Placement, Plan, get_plan
from repro.core.steps import (
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

__all__ = ["PLANS", "Placement", "Plan", "get_plan", "build_prefill_step",
           "build_serve_step", "build_train_step"]
