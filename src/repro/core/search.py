"""General plan search over an N-site topology (DESIGN.md §5).

``PlanSearch`` enumerates (technique × site-subset × stage-assignment)
candidates on a ``core.topology.Topology`` and prices each with the
cost model — the general machine behind the paper's Algorithm 1:

  * ``search()``/``best()`` rank the *full* candidate space: every
    non-empty site subset for every technique, and for Pipeshard every
    stage→site order (paths, deduplicated up to reversal).  This is what
    the two-VM API could not express — e.g. "Data over the two nearby
    sites of a three-site ring, ignoring the far one".
  * ``select()`` runs the generalized Algorithm 1 (paper §IV-H) over the
    restricted probe set the paper defines — Pipeshard on everything,
    Data/Shard per single site, ZeRO2-on-everything fallback — with the
    same δ-threshold decision structure.  For ``n_sites == 2`` it is
    *exactly* the paper's Algorithm 1; ``core.selector.select_technique``
    is now a thin wrapper over it.

Probing is pluggable exactly like the selector's: the default evaluator
prices candidates analytically, while a ``probe_fn`` (technique, sites)
hook lets live ε-epoch training measurements drive the same search.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.costmodel import (ClusterLike, TECHNIQUES, Workload,
                                  as_topology, avg_tflops)
from repro.core.plans import Placement
from repro.core.topology import Topology

ProbeFn = Callable[[str, Optional[List[int]]], Optional[float]]


# --------------------------------------------------------------------- #
# candidates
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Candidate:
    """One point of the search space: a technique placed on a site subset,
    plus (Pipeshard only) the stage→site order."""
    technique: str
    sites: Tuple[int, ...]
    stage_order: Optional[Tuple[int, ...]] = None

    def placement(self) -> Placement:
        return Placement(self.sites, self.stage_order)

    @property
    def key(self) -> str:
        s = "+".join(f"V{i + 1}" for i in self.sites)
        if self.stage_order and self.stage_order != self.sites:
            s += "|" + ">".join(f"V{i + 1}" for i in self.stage_order)
        return f"{self.technique}@{s}"


@dataclass(frozen=True)
class Scored:
    candidate: Candidate
    tflops: Optional[float]          # None => OOM / probe failure

    @property
    def feasible(self) -> bool:
        return bool(self.tflops)


def stage_orders(sites: Sequence[int],
                 max_orders: int = 24) -> Iterator[Tuple[int, ...]]:
    """Pipeline stage orders over `sites`: all site orderings up to
    reversal (a pipeline crossed backwards pays the same links), capped —
    beyond ~5 sites an exhaustive path enumeration stops paying for
    itself and the first `max_orders` lexicographic paths stand in."""
    seen = 0
    for perm in itertools.permutations(sites):
        if perm[0] > perm[-1]:           # canonical: keep one direction
            continue
        yield perm
        seen += 1
        if seen >= max_orders:
            return


# --------------------------------------------------------------------- #
# the search
# --------------------------------------------------------------------- #

@dataclass
class PlanSearch:
    """Enumerate + price candidate plans for a workload on a topology."""
    wl: Workload
    topology: Topology
    techniques: Tuple[str, ...] = TECHNIQUES
    max_sites: Optional[int] = None      # cap subset size (None = all N)
    max_stage_orders: int = 24
    probe_fn: Optional[ProbeFn] = None   # live prober; ignores stage_order

    @classmethod
    def for_cluster(cls, wl: Workload, cluster: ClusterLike,
                    **kw) -> "PlanSearch":
        return cls(wl, as_topology(cluster), **kw)

    # ------------------------------------------------------------- #
    def candidates(self) -> Iterator[Candidate]:
        n = self.topology.n_sites
        limit = n if self.max_sites is None else min(self.max_sites, n)
        for k in range(1, limit + 1):
            for subset in itertools.combinations(range(n), k):
                for tech in self.techniques:
                    if tech == "pipeshard":
                        if k == 1:
                            continue     # 1-stage pipeline degenerates
                        # live probes can't pin a stage order (and each is
                        # an epsilon-epoch training run): one per subset
                        orders = [tuple(subset)] if self.probe_fn \
                            else stage_orders(subset, self.max_stage_orders)
                        for order in orders:
                            yield Candidate(tech, subset, order)
                    else:
                        yield Candidate(tech, subset)

    def evaluate(self, cand: Candidate) -> Optional[float]:
        """Avg TFLOP/s of a candidate; None/0 on infeasibility (OOM)."""
        if self.probe_fn is not None:
            return self.probe_fn(cand.technique, list(cand.sites))
        return avg_tflops(cand.technique, self.wl, self.topology,
                          cand.sites, stage_order=cand.stage_order)

    def search(self) -> List[Scored]:
        """All candidates, best first (infeasible ones at the tail)."""
        scored = [Scored(c, self.evaluate(c)) for c in self.candidates()]
        return sorted(scored, key=lambda s: -(s.tflops or 0.0))

    def best(self) -> Optional[Scored]:
        top = self.search()
        return top[0] if top and top[0].feasible else None

    # ------------------------------------------------------------- #
    def select(self, *, delta: float = 0.1) -> "Selection":
        """Generalized Algorithm 1 over this topology (paper probe set +
        δ decision rule); the N=2 case is the paper's algorithm verbatim."""
        return algorithm1_select(self._probe, self.topology.n_sites,
                                 delta=delta)

    def _probe(self, technique: str, sites: Optional[List[int]]
               ) -> Optional[float]:
        if self.probe_fn is not None:
            return self.probe_fn(technique, sites)
        return avg_tflops(technique, self.wl, self.topology, sites)


# --------------------------------------------------------------------- #
# Algorithm 1, generalized to N sites
# --------------------------------------------------------------------- #

def algorithm1_select(probe: ProbeFn, n_sites: int, *,
                      delta: float = 0.1) -> "Selection":
    """Algorithm 1 (paper §IV-H), lines 1-36, for N sites.

    Probes Pipeshard on all sites, Data/Shard on each site alone, and
    keeps the paper's decision structure: Pipeshard must beat the best
    single-site plan by more than δ; the tie region takes the absolute
    best; ZeRO2-on-everything is the memory-pressure fallback.  For
    ``n_sites == 2`` the probe keys, comparisons and tie-breaks are
    exactly the original two-VM algorithm's.
    """
    from repro.core.selector import Selection

    probes: Dict[str, Optional[float]] = {}
    all_key = "both" if n_sites == 2 else "all"

    def run(tech: str, sites: Optional[List[int]], key: str) -> float:
        perf = probe(tech, sites)
        probes[key] = perf
        return perf if perf else 0.0          # line convention: 0 on failure

    # lines 1-2: Pipeshard on the union of all sites
    t_p = run("pipeshard", None, f"pipeshard@{all_key}")
    # lines 3-10: Data and Shard on each site separately
    t_d = [run("data", [i], f"data@V{i + 1}") for i in range(n_sites)]
    t_s = [run("shard", [i], f"shard@V{i + 1}") for i in range(n_sites)]
    # line 11
    t_z = max(t_d + t_s)

    def best_single() -> Selection:
        # argmax over sites with first-wins ties (the paper prefers V1)
        i = max(range(n_sites), key=lambda k: (max(t_d[k], t_s[k]), -k))
        tech = "data" if t_d[i] >= t_s[i] else "shard"
        return Selection(tech, [i], probes)

    every = list(range(n_sites))
    # lines 12-13: Pipeshard wins by more than δ
    if t_z > 0 and (t_p - t_z) / t_z > delta:
        return Selection("pipeshard", every, probes)
    # lines 14-27: a single-site plan wins by more than δ
    if t_p > 0 and (t_z - t_p) / t_p > delta:
        return best_single()
    # tie region but something ran: prefer the absolute best measured
    if t_p > 0 or t_z > 0:
        if t_p >= t_z:
            return Selection("pipeshard", every, probes)
        return best_single()
    # lines 29-35: ZeRO2 fallback on the whole cluster
    t_z2 = run("zero2", None, f"zero2@{all_key}")
    if t_z2 > 0:
        return Selection("zero2", every, probes)
    return Selection("none", None, probes)    # need more GPU memory
