"""General plan search over an N-site topology (DESIGN.md §5,
docs/topology-and-search.md).

``PlanSearch`` enumerates (technique × site-subset × stage-assignment ×
schedule) candidates on a ``core.topology.Topology`` and prices each
with the cost model — the general machine behind the paper's
Algorithm 1:

  * ``search()``/``best()`` rank the candidate space: every non-empty
    site subset for every technique, for Pipeshard every stage→site
    order (paths, deduplicated up to reversal) and every pipeline
    tick-order schedule (GPipe / 1F1B / interleaved —
    docs/schedules.md).  This is what the two-VM API could not express
    — e.g. "Data over the two nearby sites of a three-site ring,
    ignoring the far one", or "1F1B over all three sites because GPipe's
    activation stash doesn't fit".
  * the technique pool defaults to the paper's four and opens to the
    beyond-paper ``shard_zero``/``fsdp`` specs with ``techniques=
    core.costmodel.ALL_TECHNIQUES``; ``carrier_dtype="bf16"`` prices
    pipelines at halved inter-stage wire bytes (docs/cost-model.md).
  * by default the space is *pruned* — dominated site subsets are
    eliminated for the collective techniques and pipeline stage orders
    are explored with a beam over boundary-link costs — which keeps the
    search interactive up to N≈8 sites.  ``prune=False`` is the
    exactness escape hatch: it restores the exhaustive enumeration.
    Pruning is lossless for the best plan (and property-tested equal to
    exhaustive search on small N, tests/test_search.py).
  * ``select()`` runs the generalized Algorithm 1 (paper §IV-H) over the
    restricted probe set the paper defines — Pipeshard on everything,
    Data/Shard per single site, ZeRO2-on-everything fallback — with the
    same δ-threshold decision structure.  For ``n_sites == 2`` it is
    *exactly* the paper's Algorithm 1; ``core.selector.select_technique``
    is now a thin wrapper over it.

Probing is pluggable exactly like the selector's: the default evaluator
prices candidates analytically, while a ``probe_fn`` (technique,
``core.plans.Placement``) hook lets live ε-epoch training measurements
drive the same search (with pruning disabled — structural dominance
arguments only hold for the analytic cost model, not for live
measurements).  Each probe receives the candidate's full placement (site
subset, stage order, per-stage layer split), so a live probe realizes
exactly the plan being priced; probe-equivalent candidates — a stage
order and its reversal assign the same layers to the same sites and
cross the same links — are measured once via a per-search probe cache.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.costmodel import (ALL_TECHNIQUES, ClusterLike, SCHEDULES,
                                  StepCost, TECHNIQUES, Workload,
                                  _cal_intra, _cal_link, _cal_spanning,
                                  as_topology, avg_tflops,
                                  balanced_stage_layers, carrier_scale,
                                  parse_schedule, stage_compute_tflops,
                                  technique_step_cost, wire_scale)
from repro.core.plans import Placement
from repro.core.topology import Link, Topology

ProbeFn = Callable[[str, Optional[Placement]], Optional[float]]


# --------------------------------------------------------------------- #
# candidates
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Candidate:
    """One point of the search space.

    Attributes:
        technique: one of ``core.costmodel.TECHNIQUES``.
        sites: the site subset the technique runs on.
        stage_order: Pipeshard only — the stage→site order the pipeline
            crosses the topology in.
        schedule: Pipeshard only — the tick-order schedule
            (``core.costmodel.SCHEDULES``, docs/schedules.md); other
            techniques keep the ignored ``"gpipe"`` default.
        wire_dtype: communication wire dtype the candidate is priced at
            (``core.costmodel.WIRE_DTYPES``; docs/quantization.md).
            ``"fp32"`` — the default and the only value enumerated
            unless ``PlanSearch.wire_dtypes`` widens the pool — is the
            legacy pricing, bit-for-bit.
    """
    technique: str
    sites: Tuple[int, ...]
    stage_order: Optional[Tuple[int, ...]] = None
    schedule: str = "gpipe"
    wire_dtype: str = "fp32"

    def placement(self) -> Placement:
        """The bare ``core.plans.Placement`` (no stage balancing; use
        ``PlanSearch.placement`` for TFLOP-weighted stage layers)."""
        return Placement(self.sites, self.stage_order,
                         schedule=self.schedule)

    @property
    def key(self) -> str:
        """Human-readable id, e.g. ``pipeshard@V1+V3|V3>V1#1f1b`` or
        ``data@V1+V2~int8`` (the wire suffix appears only off the fp32
        default)."""
        s = "+".join(f"V{i + 1}" for i in self.sites)
        if self.stage_order and self.stage_order != self.sites:
            s += "|" + ">".join(f"V{i + 1}" for i in self.stage_order)
        if self.schedule != "gpipe":
            s += f"#{self.schedule}"
        if self.wire_dtype != "fp32":
            s += f"~{self.wire_dtype}"
        return f"{self.technique}@{s}"


@dataclass(frozen=True)
class Scored:
    """A candidate plus its measured/modelled performance.

    Attributes:
        candidate: the scored candidate.
        tflops: average TFLOP/s; ``None`` on OOM / probe failure.
    """
    candidate: Candidate
    tflops: Optional[float]          # None => OOM / probe failure

    @property
    def feasible(self) -> bool:
        return bool(self.tflops)


def stage_orders(sites: Sequence[int],
                 max_orders: Optional[int] = 24, *,
                 dedupe_reversals: bool = True
                 ) -> Iterator[Tuple[int, ...]]:
    """Exhaustive pipeline stage orders over ``sites``.

    Args:
        sites: the site subset the pipeline spans.
        max_orders: optional cap on yielded orders (None = unbounded —
            required for a true exactness oracle); a cap truncates to
            the first lexicographic paths, so prefer
            ``PlanSearch.beam_stage_orders``, which caps by link cost
            rather than lexicographic accident.
        dedupe_reversals: keep only the direction with
            ``perm[0] < perm[-1]`` of each reversal pair — correct
            whenever the cost model prices both directions identically
            (links are symmetric and even splits are
            direction-invariant).  TFLOP-weighted balancing breaks the
            symmetry in rare exact-tie cases, so searches running with
            ``stage_balance="tflops"`` pass False.

    Yields:
        Site orderings.
    """
    seen = 0
    for perm in itertools.permutations(sites):
        if dedupe_reversals and perm[0] > perm[-1]:
            continue                     # canonical: keep one direction
        yield perm
        seen += 1
        if max_orders is not None and seen >= max_orders:
            return


# --------------------------------------------------------------------- #
# subset dominance (pruning, collective techniques)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class _SubsetStats:
    """What the collective cost model can see of a site subset: the GPU
    pool size, the pace-setting GPU, the memory floor, the
    spanning-link extremes, and — for the hybrid ``shard_zero`` spec —
    the intra-site tensor-parallel floor plus each member site's intra
    all-reduce (latency, byte-rate) coefficients.  For subsets with
    equal pool sizes these numbers bound the step cost of every
    collective technique (data/zero2/shard/fsdp/shard_zero) from both
    sides."""
    subset: Tuple[int, ...]
    n_gpus: int
    min_tflops: float
    min_mem: float
    max_lat: float
    min_eff: float
    span: Tuple[Link, ...]
    # intra-site corners (shard_zero): per site, the affine all-reduce
    # coefficients alpha = (k-1)*lat and beta = (k-1)/k / eff_gbps —
    # site time for B bytes scales as alpha + beta*B.
    tp: int = 1
    intra_corners: Tuple[Tuple[float, float], ...] = ()

    @property
    def max_intra_alpha(self) -> float:
        return max((a for a, _ in self.intra_corners), default=0.0)

    @property
    def max_intra_beta(self) -> float:
        return max((b for _, b in self.intra_corners), default=0.0)


def _dominates(a: _SubsetStats, b: _SubsetStats, *,
               intra_sensitive: bool = False) -> bool:
    """True when subset ``a`` is provably at least as good as ``b`` for
    every collective technique in play: the pools are the same size
    (collective time and per-GPU memory are not monotone in pool size),
    ``a``'s slowest GPU and smallest memory are no worse, and ``b``'s
    spanning set contains a link at least as bad as ``a``'s worst-case
    (max-latency, min-throughput) corner — so ``b``'s collective time is
    >= ``a``'s for any message size, and anything that fits on ``b``
    fits on ``a``.  With ``intra_sensitive`` (the ``shard_zero`` spec in
    the pool), two extra corners must hold: ``a``'s tensor-parallel
    floor is no smaller (its ZeRO volume g/tp and its p/tp param bytes
    are no larger), and ``b`` has a member site whose intra all-reduce
    coefficients are at least as bad as ``a``'s worst — so ``b``'s
    max-over-sites intra term is >= ``a``'s for any payload."""
    if a.n_gpus != b.n_gpus:
        return False
    if a.min_tflops < b.min_tflops or a.min_mem < b.min_mem:
        return False
    if intra_sensitive:
        if a.tp < b.tp:
            return False
        if not any(al >= a.max_intra_alpha and be >= a.max_intra_beta
                   for al, be in b.intra_corners):
            return False
    return any(l.latency_s >= a.max_lat and l.effective_gbps <= a.min_eff
               for l in b.span)


# --------------------------------------------------------------------- #
# the search
# --------------------------------------------------------------------- #

@dataclass
class PlanSearch:
    """Enumerate + price candidate plans for a workload on a topology.

    Attributes:
        wl: the workload being placed.
        topology: the N-site topology (or use ``for_cluster`` to lift a
            legacy two-VM ``Cluster``).
        techniques: techniques to consider (default: the paper's four,
            ``core.costmodel.TECHNIQUES``; pass ``core.costmodel
            .ALL_TECHNIQUES`` to open the pool to the ``shard_zero`` /
            ``fsdp`` specs — every plan ``core.plans.PLANS`` executes).
        max_sites: cap subset size (None = up to all N sites).
        max_stage_orders: optional cap on stage orders per subset.  None
            (the default) keeps ``prune=False`` a true exactness oracle
            — every canonical order is enumerated.  When set, it bounds
            both paths: the exhaustive enumeration truncates (no longer
            exact!) and the beam width is clamped to it.
        probe_fn: live prober ``(technique, Placement) -> TFLOP/s``
            replacing the analytic evaluator; disables pruning.  Every
            probe carries the candidate's full placement (stage order +
            per-stage layers), and probe-equivalent candidates (reversed
            stage orders, repeated subsets) are measured once — each
            live probe is an ε-epoch training run.
        prune: eliminate dominated site subsets and beam-search stage
            orders (default).  ``prune=False`` is the exactness escape
            hatch — exhaustive enumeration, identical results, slower
            beyond N≈6.
        beam_width: beam size for stage-order search; 24 keeps subsets
            of <= 4 sites exhaustive (4!/2 = 12 canonical orders), so
            pruning only approximates on 5+-site pipelines.
        stage_balance: "even" (paper-faithful equal layer slices) or
            "tflops" (stage sizes weighted by per-site compute,
            ``core.costmodel.balanced_stage_layers``) — applied when
            pricing Pipeshard candidates and attached to placements.
        carrier_dtype: inter-stage activation carrier dtype Pipeshard
            candidates are priced at (``core.costmodel.CARRIER_DTYPES``;
            default ``"fp32"``, the legacy baseline).  ``"bf16"`` halves
            the p2p byte terms — cheap boundary bytes can flip a cell's
            stage order or schedule choice (docs/cost-model.md); the
            beam's boundary scoring uses the same scale.
        schedules: pipeline tick-order schedules to search over for
            Pipeshard candidates (``core.costmodel.SCHEDULES``; default
            all three — GPipe, 1F1B, interleaved).  Enumeration order
            breaks exact TFLOP/s ties (the sort is stable), so keeping
            ``"gpipe"`` first preserves every paper winner: 1F1B prices
            time-identical to GPipe and wins only where its smaller
            activation stash rescues a placement GPipe's ``fits`` check
            rejects.  Restrict to ``("gpipe",)`` for the legacy space
            (or to bound live-probe budgets — every schedule of every
            order is a separate ε-epoch run).
        wire_dtypes: communication wire dtypes to enumerate as a
            candidate dimension (``core.costmodel.WIRE_DTYPES``;
            docs/quantization.md).  ``None`` (default) keeps the legacy
            fp32-only space — every enumeration count and winner is
            unchanged.  Pass ``("fp32", "bf16", "int8")`` to let every
            candidate also be priced at quantized wire bytes; fp32 is
            enumerated first so exact-tie stable sorts keep legacy
            winners.  Subset dominance pruning stays lossless: a wire
            dtype rescales every subset's byte terms by the same factor
            and never touches latency or compute, so the dominance
            order between subsets is unchanged.
        calibration: optional measured-rate overlay
            (``repro.calib.overlay.Calibration``) pricing every
            candidate — and every pruning decision — at fitted rates
            instead of datasheet/analytic ones (docs/calibration.md).
            ``None`` and ``Calibration.identity()`` are bit-for-bit
            identical to the uncalibrated search: every lookup falls
            through to the very same objects and expressions, so
            subset dominance, beam boundary scores, and prices all
            coincide (pinned by tests/test_calib_gates.py).
    """
    wl: Workload
    topology: Topology
    techniques: Tuple[str, ...] = TECHNIQUES
    max_sites: Optional[int] = None      # cap subset size (None = all N)
    max_stage_orders: Optional[int] = None
    probe_fn: Optional[ProbeFn] = None   # live prober (takes a Placement)
    prune: bool = True
    beam_width: int = 24
    stage_balance: str = "even"
    schedules: Tuple[str, ...] = SCHEDULES
    carrier_dtype: str = "fp32"
    wire_dtypes: Optional[Tuple[str, ...]] = None
    calibration: Optional[object] = None   # repro.calib Calibration overlay
    # live probe memo: probe-equivalence key -> measured TFLOP/s
    _probe_cache: Dict[Tuple, Optional[float]] = field(
        default_factory=dict, init=False, repr=False, compare=False)

    @classmethod
    def for_cluster(cls, wl: Workload, cluster: ClusterLike,
                    **kw) -> "PlanSearch":
        """Lift a legacy two-VM ``Cluster`` (or pass through a
        ``Topology``) and search it."""
        return cls(wl, as_topology(cluster), **kw)

    # ------------------------------------------------------------- #
    def candidates(self) -> Iterator[Candidate]:
        """The *exhaustive* candidate space (no pruning): every
        technique on every non-empty site subset, every canonical stage
        order for Pipeshard.  ``search(prune=True)`` consumes the pruned
        twin ``pruned_candidates`` instead.  One exception under a live
        ``probe_fn``: Pipeshard stage orders are shortlisted by
        ``beam_stage_orders`` (exhaustive for subsets of <= 4 sites at
        the default width) — every shortlisted order costs a real
        ε-epoch training run, so the k!/2 enumeration is not an option;
        tighten further with ``max_stage_orders``/``beam_width``."""
        n = self.topology.n_sites
        limit = n if self.max_sites is None else min(self.max_sites, n)
        for k in range(1, limit + 1):
            for subset in itertools.combinations(range(n), k):
                for tech in self.techniques:
                    if tech == "pipeshard":
                        if k == 1:
                            continue     # 1-stage pipeline degenerates
                        # live probes pin stage orders too — the probe
                        # receives the full Placement and builds the
                        # staged mesh from it.  Each live probe is a
                        # real ε-epoch training run, so live orders are
                        # shortlisted by the (cheap, analytic) boundary
                        # -cost beam instead of enumerated k!/2-fold;
                        # the probe cache additionally keeps reversal
                        # -equivalent orders from re-measuring.
                        if self.probe_fn is not None:
                            orders = self.beam_stage_orders(subset)
                        else:
                            orders = stage_orders(
                                subset, self.max_stage_orders,
                                dedupe_reversals=self._reversible())
                        for order in orders:
                            for sched in self.schedules:
                                for wd in self._wire_pool():
                                    yield Candidate(tech, subset, order,
                                                    sched, wd)
                    else:
                        for wd in self._wire_pool():
                            yield Candidate(tech, subset, wire_dtype=wd)

    def _wire_pool(self) -> Tuple[str, ...]:
        """The wire-dtype dimension: ``("fp32",)`` (legacy space) unless
        ``wire_dtypes`` widens it.  Validates every entry."""
        if self.wire_dtypes is None:
            return ("fp32",)
        for wd in self.wire_dtypes:
            wire_scale(wd)                     # raises on unknown dtypes
        return tuple(self.wire_dtypes)

    def pruned_candidates(self) -> Iterator[Candidate]:
        """The pruned candidate space: per subset size, collective
        techniques skip dominated subsets (``_dominates`` — lossless for
        the best plan); Pipeshard explores stage orders via
        ``beam_stage_orders`` instead of exhaustively."""
        n = self.topology.n_sites
        limit = n if self.max_sites is None else min(self.max_sites, n)
        for k in range(1, limit + 1):
            subsets = list(itertools.combinations(range(n), k))
            keep = self._prune_dominated(subsets)
            for subset in subsets:
                for tech in self.techniques:
                    if tech == "pipeshard":
                        if k == 1:
                            continue
                        for order in self.beam_stage_orders(subset):
                            for sched in self.schedules:
                                for wd in self._wire_pool():
                                    yield Candidate(tech, subset, order,
                                                    sched, wd)
                    elif subset in keep:
                        for wd in self._wire_pool():
                            yield Candidate(tech, subset, wire_dtype=wd)

    def _reversible(self) -> bool:
        """Whether a stage order and its reversal are guaranteed the same
        price, so one canonical direction suffices.  True for even splits
        (links are symmetric); TFLOP-weighted splits can differ under
        exact quota ties (the tie-break is by stage position), so both
        directions must be priced."""
        return self.stage_balance != "tflops"

    def _subset_stats(self, subset: Tuple[int, ...]) -> _SubsetStats:
        # every rate below reads through the calibration overlay so the
        # dominance test compares what the evaluator will actually price
        # — pruning would stop being lossless if it kept judging subsets
        # by datasheet rates a calibration has overridden.  (min over
        # per-site minima == flat min over the pool, float-exact, so the
        # identity overlay changes nothing.)
        topo = self.topology
        cal = self.calibration
        gpus = topo.all_gpus(subset)
        span = tuple(_cal_spanning(cal, topo, subset)) if len(subset) > 1 \
            else (_cal_intra(cal, topo, subset[0]),)
        corners = []
        for i in subset:
            k = len(topo.sites[i].gpus)
            intra = _cal_intra(cal, topo, i)
            corners.append(((k - 1) * intra.latency_s,
                            (k - 1) / k / intra.effective_gbps))
        if cal is None:
            min_tflops = min(g.tflops for g in gpus)
        else:
            min_tflops = min(cal.gpu_tflops(topo, i) for i in subset)
        return _SubsetStats(
            subset=subset,
            n_gpus=len(gpus),
            min_tflops=min_tflops,
            min_mem=min(g.mem_gb for g in gpus),
            max_lat=max(l.latency_s for l in span),
            min_eff=min(l.effective_gbps for l in span),
            span=span,
            tp=min(len(topo.sites[i].gpus) for i in subset),
            intra_corners=tuple(corners))

    def _prune_dominated(self, subsets: Sequence[Tuple[int, ...]]
                         ) -> set:
        """Subsets (all the same size) worth pricing for the collective
        techniques: drop every subset strictly dominated by another, and
        keep only the lexicographically-first of exact-tie groups.  With
        ``shard_zero`` in the pool the dominance test adds its
        intra-site corners (``_dominates(intra_sensitive=True)``) so
        pruning stays lossless over the widened technique space."""
        intra = "shard_zero" in self.techniques
        stats = [self._subset_stats(s) for s in subsets]
        keep = set()
        for b in stats:
            dominated = any(
                _dominates(a, b, intra_sensitive=intra) and
                (not _dominates(b, a, intra_sensitive=intra)
                 or a.subset < b.subset)
                for a in stats if a.subset != b.subset)
            if not dominated:
                keep.add(b.subset)
        return keep

    def beam_stage_orders(self, subset: Sequence[int],
                          beam_width: Optional[int] = None
                          ) -> List[Tuple[int, ...]]:
        """Stage orders for a Pipeshard subset via beam search.

        Grows stage→site paths one site at a time, scoring partials by
        the accumulated boundary cost (the cost model's own p2p term,
        which is additive over crossed links while every other Pipeshard
        term is order-invariant up to reversal ties), and keeps the
        ``beam_width`` cheapest at each depth.  When the subset's full
        path count fits the beam this is exhaustive — with the default
        width, subsets of <= 4 sites always are.

        Args:
            subset: the site subset the pipeline spans.
            beam_width: overrides ``self.beam_width``.

        Returns:
            Orders cheapest-first; reversal pairs are deduplicated to
            the canonical direction except under ``stage_balance=
            "tflops"``, where both directions are kept (see
            ``stage_orders``).
        """
        sites = tuple(subset)
        if len(sites) <= 2:
            if len(sites) == 2 and not self._reversible():
                return [sites, sites[::-1]]
            return [sites]
        w = self.beam_width if beam_width is None else beam_width
        if self.max_stage_orders is not None:
            w = min(w, self.max_stage_orders)
        act = self.wl.tokens_per_step * self.wl.cfg.d_model * 2 \
            * carrier_scale(self.carrier_dtype)
        micro = self.wl.microbatches

        def edge_cost(a: int, b: int) -> float:
            l = _cal_link(self.calibration, self.topology, a, b)
            return 2 * (act / (l.effective_gbps * 1e9)
                        + micro * l.latency_s)

        frontier: List[Tuple[float, Tuple[int, ...]]] = \
            [(0.0, (s,)) for s in sites]
        for _ in range(len(sites) - 1):
            grown = [(cost + edge_cost(path[-1], s), path + (s,))
                     for cost, path in frontier
                     for s in sites if s not in path]
            grown.sort()
            frontier = grown[:w]
        dedupe = self._reversible()
        out: Dict[Tuple[int, ...], float] = {}
        for cost, path in frontier:
            canon = path if not dedupe or path[0] < path[-1] \
                else path[::-1]
            out.setdefault(canon, cost)
        return sorted(out, key=lambda p: (out[p], p))

    def evaluate(self, cand: Candidate) -> Optional[float]:
        """Avg TFLOP/s of a candidate; None/0 on infeasibility (OOM)."""
        if self.probe_fn is not None:
            return self._cached_probe(cand.technique, self.placement(cand))
        return avg_tflops(cand.technique, self.wl, self.topology,
                          cand.sites, stage_order=cand.stage_order,
                          stage_balance=self.stage_balance,
                          schedule=cand.schedule,
                          carrier_dtype=self.carrier_dtype,
                          wire_dtype=cand.wire_dtype,
                          calibration=self.calibration)

    def step_cost(self, cand: Candidate) -> StepCost:
        """The modelled ``StepCost`` behind ``evaluate`` — compute /
        comm seconds and the memory-vs-envelope pair, priced exactly as
        the scorer prices the candidate (same stage balance, schedule,
        carrier and wire dtypes).  The introspection hook the static
        plan verifier (``repro.analysis.planlint``) checks
        ``technique_state_bytes`` and feasibility consistency against.
        """
        place = self.placement(cand)
        return technique_step_cost(
            cand.technique, self.wl, self.topology, cand.sites,
            stage_order=cand.stage_order,
            stage_balance=self.stage_balance,
            stage_layers=place.stage_layers,
            schedule=cand.schedule,
            carrier_dtype=self.carrier_dtype,
            wire_dtype=cand.wire_dtype,
            calibration=self.calibration)

    @staticmethod
    def probe_key(technique: str, placement: Optional[Placement]) -> Tuple:
        """Probe-equivalence key: two candidates with the same key are
        guaranteed the same live measurement.  Non-pipeline techniques
        are defined by their site subset alone; a GPipe/1F1B pipeline
        and its reversal assign the same layer counts to the same sites
        and cross the same boundary links, so those reversal pairs share
        a key.  Interleaved pipelines do NOT: reversing the stage order
        re-deals the (non-contiguous) chunk→site assignment, so each
        direction keys separately."""
        if placement is None:
            return (technique, None)
        sites = tuple(placement.sites)
        if technique != "pipeshard" or len(sites) < 2:
            return (technique, sites)
        order = tuple(placement.stage_order or sites)
        layers = placement.stage_layers or ()
        _, virt = parse_schedule(placement.schedule)
        if virt > 1:
            return (technique, sites, placement.schedule, order,
                    tuple(layers))
        fwd = (order, tuple(layers))
        rev = (order[::-1], tuple(layers[::-1] if layers else ()))
        return (technique, sites, placement.schedule) + min(fwd, rev)

    def _cached_probe(self, technique: str,
                      placement: Optional[Placement]) -> Optional[float]:
        """Run ``probe_fn`` at most once per probe-equivalence class —
        every live probe is an ε-epoch training run."""
        key = self.probe_key(technique, placement)
        if key not in self._probe_cache:
            self._probe_cache[key] = self.probe_fn(technique, placement)
        return self._probe_cache[key]

    def _chunk_layers(self, order: Sequence[int],
                      schedule: str) -> Tuple[int, ...]:
        """Per-chunk layer split for a pipeline candidate: stage (chunk)
        quotas follow per-site TFLOP/s under ``stage_balance="tflops"``,
        uniform weights otherwise — largest-remainder either way, so
        non-divisible stacks still partition."""
        _, virt = parse_schedule(schedule)
        n_chunks = len(order) * virt
        if self.stage_balance == "tflops":
            tf = stage_compute_tflops(self.topology, order,
                                      self.calibration)
            weights = [tf[c % len(order)] for c in range(n_chunks)]
        else:
            weights = [1.0] * n_chunks
        return balanced_stage_layers(self.wl.cfg.n_layers, weights)

    def placement(self, cand: Candidate) -> Placement:
        """The ``core.plans.Placement`` realizing a candidate, with
        ``stage_layers`` attached when needed: TFLOP-weighted chunk
        quotas under ``stage_balance="tflops"``, and an explicit (even,
        largest-remainder) split for interleaved candidates even under
        ``"even"`` balance — interleaved chunks are non-contiguous on a
        stage, so the runtime always needs the split spelled out."""
        if cand.technique != "pipeshard" or (
                self.stage_balance != "tflops"
                and parse_schedule(cand.schedule)[1] == 1):
            return cand.placement()
        order = cand.stage_order or cand.sites
        return Placement(cand.sites, cand.stage_order,
                         self._chunk_layers(order, cand.schedule),
                         schedule=cand.schedule)

    def search(self, *, prune: Optional[bool] = None) -> List[Scored]:
        """All candidates, best first (infeasible ones at the tail).

        Args:
            prune: override the instance's ``prune`` flag for this call
                (``False`` = exhaustive exactness escape hatch).  Live
                ``probe_fn`` searches are never pruned.

        Returns:
            ``Scored`` candidates sorted by descending TFLOP/s.
        """
        do_prune = self.prune if prune is None else prune
        if self.probe_fn is not None:
            do_prune = False
        cands = self.pruned_candidates() if do_prune else self.candidates()
        scored = [Scored(c, self.evaluate(c)) for c in cands]
        return sorted(scored, key=lambda s: -(s.tflops or 0.0))

    def best(self, *, prune: Optional[bool] = None) -> Optional[Scored]:
        """The best feasible candidate, or None when everything OOMs."""
        top = self.search(prune=prune)
        return top[0] if top and top[0].feasible else None

    # ------------------------------------------------------------- #
    def restricted(self, sites: Sequence[int]
                   ) -> Tuple["PlanSearch", Tuple[int, ...]]:
        """A search over only the sub-topology spanned by ``sites``.

        The replica-placement objective (``serve/placement.py``) prices
        each candidate replica group through this: same workload and
        knobs, the topology cut down to the group, and the
        ``Calibration`` overlay's site/pair keys remapped to the dense
        sub-topology indices (sparse entries for dropped sites vanish;
        everything else keeps falling through to analytic rates).

        Returns:
            ``(search, kept)`` — ``kept[new_index] == old_index`` maps
            the sub-search's site numbering back to this topology's.
        """
        import dataclasses as _dc
        keep = set(self.topology.select(tuple(sites)))
        dead = [i for i in range(self.topology.n_sites) if i not in keep]
        sub, kept = self.topology.without_sites(dead)
        calib = self.calibration
        if calib is not None and dead:
            from repro.calib.overlay import Calibration
            remap = {old: new for new, old in enumerate(kept)}
            calib = Calibration(
                site_tflops={remap[i]: v
                             for i, v in calib.site_tflops.items()
                             if i in remap},
                links={(min(remap[i], remap[j]), max(remap[i], remap[j])): r
                       for (i, j), r in calib.links.items()
                       if i in remap and j in remap},
                note=calib.note)
        return _dc.replace(self, topology=sub, calibration=calib,
                           probe_fn=None), kept

    # ------------------------------------------------------------- #
    def select(self, *, delta: float = 0.1,
               extended: Optional[bool] = None) -> "Selection":
        """Generalized Algorithm 1 over this topology (paper probe set +
        δ decision rule); the N=2 case is the paper's algorithm verbatim.

        Args:
            delta: the paper's δ threshold.
            extended: opt into the beyond-paper probe set (``shard_zero``
                / ``fsdp``, see ``algorithm1_select``).  Default: derived
                from this search's technique pool — paper-faithful four
                unless the pool itself was widened.
        """
        if extended is None:
            extended = any(t not in TECHNIQUES for t in self.techniques)
        return algorithm1_select(self._probe, self.topology.n_sites,
                                 delta=delta, extended=extended)

    def _probe(self, technique: str, placement: Optional[Placement]
               ) -> Optional[float]:
        if self.probe_fn is not None:
            if placement is not None and technique == "pipeshard" \
                    and self.stage_balance == "tflops" \
                    and placement.stage_layers is None:
                # attach the same weighted split ``placement()`` would:
                # the Algorithm-1 probe then shares its cache key with
                # the search's candidate (no duplicate ε-epoch run) and
                # a live run_fn never sees an even split that cannot
                # partition a non-divisible stack
                order = placement.stage_order or placement.sites
                placement = Placement(
                    placement.sites, placement.stage_order,
                    self._chunk_layers(order, placement.schedule),
                    schedule=placement.schedule)
            return self._cached_probe(technique, placement)
        sites = None if placement is None else list(placement.sites)
        return avg_tflops(technique, self.wl, self.topology, sites,
                          stage_order=None if placement is None
                          else placement.stage_order,
                          stage_layers=None if placement is None
                          else placement.stage_layers,
                          stage_balance=self.stage_balance,
                          schedule="gpipe" if placement is None
                          else placement.schedule,
                          carrier_dtype=self.carrier_dtype,
                          calibration=self.calibration)


# --------------------------------------------------------------------- #
# Algorithm 1, generalized to N sites
# --------------------------------------------------------------------- #

def algorithm1_select(probe: ProbeFn, n_sites: int, *,
                      delta: float = 0.1,
                      extended: bool = False) -> "Selection":
    """Algorithm 1 (paper §IV-H), lines 1-36, for N sites.

    Probes Pipeshard on all sites, Data/Shard on each site alone, and
    keeps the paper's decision structure: Pipeshard must beat the best
    single-site plan by more than δ; the tie region takes the absolute
    best; ZeRO2-on-everything is the memory-pressure fallback.  For
    ``n_sites == 2`` the probe keys, comparisons and tie-breaks are
    exactly the original two-VM algorithm's.

    ``extended`` opts into the beyond-paper pool
    (``core.costmodel.ALL_TECHNIQUES``) while keeping the paper's
    decision structure: the "on everything" tier also probes
    ``shard_zero`` and ``fsdp`` on all sites (best of the three enters
    the δ comparison, ties preferring Pipeshard), and each single site
    is additionally probed under ``fsdp`` — the memory-rescue plan that
    can revive a site whose replicated-state plans OOM
    (docs/cost-model.md).  With ``extended=False`` (the default) the
    probe set, keys, comparisons, and tie-breaks are bit-for-bit the
    paper's.

    Args:
        probe: ``(technique, Placement) -> TFLOP/s`` (None/0 =
            infeasible); the paper's probe set pins only site subsets,
            so the placements carry no stage order or layer split.
        n_sites: number of sites the probe understands.
        delta: the paper's δ threshold — how much better
            Pipeshard-on-everything must be before it wins.
        extended: add the ``shard_zero``/``fsdp`` probes (opt-in).

    Returns:
        A ``core.selector.Selection`` with the chosen technique, its
        site list, and every probe taken.
    """
    from repro.core.selector import Selection

    probes: Dict[str, Optional[float]] = {}
    all_key = "both" if n_sites == 2 else "all"
    all_sites = tuple(range(n_sites))

    def run(tech: str, placement: Placement, key: str) -> float:
        perf = probe(tech, placement)
        probes[key] = perf
        return perf if perf else 0.0          # line convention: 0 on failure

    # lines 1-2: Pipeshard on the union of all sites
    t_p = run("pipeshard", Placement(all_sites), f"pipeshard@{all_key}")
    all_tech, t_all = "pipeshard", t_p
    if extended:
        # beyond-paper "on everything" probes; pipeshard keeps exact ties
        for tech in ("shard_zero", "fsdp"):
            t = run(tech, Placement(all_sites), f"{tech}@{all_key}")
            if t > t_all:
                all_tech, t_all = tech, t
    # lines 3-10: Data and Shard on each site separately
    t_d = [run("data", Placement((i,)), f"data@V{i + 1}")
           for i in range(n_sites)]
    t_s = [run("shard", Placement((i,)), f"shard@V{i + 1}")
           for i in range(n_sites)]
    t_f = [run("fsdp", Placement((i,)), f"fsdp@V{i + 1}")
           for i in range(n_sites)] if extended \
        else [0.0] * n_sites
    # line 11
    t_z = max(t_d + t_s + (t_f if extended else []))

    def best_single() -> Selection:
        # argmax over sites with first-wins ties (the paper prefers V1)
        i = max(range(n_sites),
                key=lambda k: (max(t_d[k], t_s[k], t_f[k]), -k))
        # paper-order tie-break: data, then shard, then (extended) fsdp
        if t_d[i] >= t_s[i] and t_d[i] >= t_f[i]:
            tech = "data"
        elif t_s[i] >= t_f[i]:
            tech = "shard"
        else:
            tech = "fsdp"
        return Selection(tech, [i], probes)

    every = list(range(n_sites))
    # lines 12-13: the distributed plan wins by more than δ
    if t_z > 0 and (t_all - t_z) / t_z > delta:
        return Selection(all_tech, every, probes)
    # lines 14-27: a single-site plan wins by more than δ
    if t_all > 0 and (t_z - t_all) / t_all > delta:
        return best_single()
    # tie region but something ran: prefer the absolute best measured
    if t_all > 0 or t_z > 0:
        if t_all >= t_z:
            return Selection(all_tech, every, probes)
        return best_single()
    # lines 29-35: ZeRO2 fallback on the whole cluster (in extended mode
    # the fsdp@all probe above already covered the only lower-memory
    # plan, and it OOMed too if we got here)
    t_z2 = run("zero2", Placement(all_sites), f"zero2@{all_key}")
    if t_z2 > 0:
        return Selection("zero2", every, probes)
    return Selection("none", None, probes)    # need more GPU memory
