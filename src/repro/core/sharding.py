"""Logical-axis sharding rule engine.

Every parameter leaf is matched (by its key path + rank) to a tuple of
*logical* dimension names; a plan then maps logical names to mesh axes.
Leaves with more dims than the rule's base rank are stacked (layer /
group axes) and get the plan's ``stack_axis`` (``None`` for SPMD plans,
``"stage"`` for Pipeshard) prepended.

jit input shardings must divide exactly, so assignment is divisibility-
aware: each dim takes its mapped mesh axis only when the size divides; and
when the primary tensor-parallel dim does not divide (minicpm3's 40 heads
or whisper's 51865 vocab on a 16-way model axis), a *secondary* dim
(head_dim / embedding-d) picks up the axis so the tensor still shards.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Secondary names take a mesh axis only when the primary dim of the same
# tensor failed divisibility.
SECONDARY = ("head_dim", "embed_d")

# (path regex, base rank, logical dims) — first match wins.
RULES: Sequence[Tuple[str, int, Tuple[Optional[str], ...]]] = (
    # embeddings / heads
    (r"(embed|lm_head)/table$", 2, ("vocab", "embed_d")),
    (r"pos(_embed)?/table$|pos/table$", 2, (None, "embed_d")),
    # attention (dense / encdec / hybrid-shared)
    (r"/wq$", 3, ("residual", "heads", "head_dim")),
    (r"/w[kv]$", 3, ("residual", "kv_heads", "head_dim")),
    (r"/wo$", 3, ("heads", "head_dim", "residual")),
    (r"/bq$", 2, ("heads", "head_dim")),
    (r"/b[kv]$", 2, ("kv_heads", "head_dim")),
    (r"/bo$", 1, ("residual",)),
    # MLA
    (r"mla/w_dq$", 2, ("residual", None)),
    (r"mla/(q|kv)_norm$", 1, (None,)),
    (r"mla/w_uq$", 3, (None, "heads", "head_dim")),
    (r"mla/w_dkv$", 2, ("residual", None)),
    (r"mla/w_kr$", 2, ("residual", None)),
    (r"mla/w_u[kv]$", 3, ("heads", None, "head_dim")),
    (r"mla/wo$", 3, ("heads", "head_dim", "residual")),
    # dense MLP
    (r"mlp/w_(gate|up)$", 2, ("residual", "mlp")),
    (r"mlp/b_up$", 1, ("mlp",)),
    (r"mlp/w_down$", 2, ("mlp", "residual")),
    (r"mlp/b_down$", 1, ("residual",)),
    # MoE
    (r"moe/router$", 2, ("residual", None)),
    (r"moe/w_(gate|up|down)$", 3, ("expert", None, None)),
    (r"moe/shared_(gate|up)$", 2, ("residual", "mlp")),
    (r"moe/shared_down$", 2, ("mlp", "residual")),
    # Mamba (1 and 2)
    (r"mamba/in_proj$", 2, ("residual", "d_inner")),
    (r"mamba/conv_w$", 2, (None, "d_inner")),
    (r"mamba/conv_b$", 1, ("d_inner",)),
    (r"mamba/x_proj$", 2, ("d_inner", None)),
    (r"mamba/dt_proj$", 2, (None, "d_inner")),
    (r"mamba/dt_bias$", 1, (None,)),
    (r"mamba/A_log$", 2, ("d_inner", None)),   # mamba1 [di, ds]
    (r"mamba/A_log$", 1, (None,)),             # mamba2 [nh]
    (r"mamba/D$", 1, (None,)),
    (r"mamba/norm_scale$", 1, ("d_inner",)),
    (r"mamba/out_proj$", 2, ("d_inner", "residual")),
    # VLM projector
    (r"projector/w1$", 2, (None, "residual")),
    (r"projector/w2$", 2, ("residual", "residual2")),
    # norms / gates / everything else: replicated
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def logical_spec(path_str: str, ndim: int,
                 *, n_stack: int = 0) -> Tuple[Optional[str], ...]:
    """Logical dims for one leaf. ``n_stack``: how many leading stacked dims
    precede the per-layer parameter (0 for unstacked, 1 for [L,...],
    2 for hybrid [G,k,...])."""
    base = ndim - n_stack
    for pat, rank, dims in RULES:
        if rank == base and re.search(pat, path_str):
            return ("__stack__",) * n_stack + dims
    return (None,) * ndim


def _stack_depth(path_str: str, family: str) -> int:
    """Stacked prefix depth for a leaf under layers/encoder-layers."""
    if "layers/blocks" in path_str:          # hybrid [G, k, ...]
        return 1 if path_str.endswith("gates") else 2
    if re.search(r"(^|/)layers/", path_str):
        return 1
    return 0


class AxisMap(dict):
    """logical name -> mesh axis (or axis tuple); missing => replicated."""

    def to_pspec(self, dims: Tuple[Optional[str], ...],
                 shape: Optional[Tuple[int, ...]] = None,
                 axis_sizes: Optional[Dict[str, int]] = None) -> P:
        """Divisibility-aware assignment.  Primary dims get their axis when
        the size divides; SECONDARY dims only fire when the tensor's primary
        dim failed, so each mesh axis is used at most once per tensor."""
        entries: list = [None] * len(dims)
        used: set = set()

        def axes_of(name):
            ax = self.get(name)
            if ax is None:
                return None, ()
            return ax, (ax if isinstance(ax, tuple) else (ax,))

        def divisible(i, ax_t):
            if shape is None or axis_sizes is None:
                return True
            size = 1
            for a in ax_t:
                size *= axis_sizes.get(a, 1)
            return size > 0 and shape[i] % size == 0

        for pass_secondary in (False, True):
            for i, d in enumerate(dims):
                if d is None or entries[i] is not None:
                    continue
                if (d in SECONDARY) != pass_secondary:
                    continue
                ax, ax_t = axes_of(d)
                if ax is None or any(a in used for a in ax_t):
                    continue
                if divisible(i, ax_t):
                    entries[i] = ax
                    used.update(ax_t)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)


def param_specs(params_or_shapes, axis_map: AxisMap, family: str,
                axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    """PartitionSpec pytree matching the parameter pytree."""

    def spec_of(path, leaf):
        ps = _path_str(path)
        dims = logical_spec(ps, leaf.ndim, n_stack=_stack_depth(ps, family))
        return axis_map.to_pspec(dims, tuple(leaf.shape), axis_sizes)

    return jax.tree_util.tree_map_with_path(spec_of, params_or_shapes)


def named_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def largest_dim_spec(leaf, axes: Tuple[str, ...], axes_size: int) -> P:
    """ZeRO spec: shard the largest *divisible* dimension over ``axes``."""
    if leaf.ndim == 0:
        return P()
    dims = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
    for dim in dims:
        if leaf.shape[dim] % axes_size == 0 and leaf.shape[dim] >= axes_size:
            entries: list = [None] * leaf.ndim
            entries[dim] = axes if len(axes) > 1 else axes[0]
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)
    return P()


def zero_specs(params_or_shapes, axes: Tuple[str, ...], axes_size: int):
    return jax.tree.map(lambda l: largest_dim_spec(l, axes, axes_size),
                        params_or_shapes)


def add_fsdp_axis(leaf, spec: P, axes: Tuple[str, ...], axes_size: int) -> P:
    """FSDP: put the data axes on the largest still-unsharded divisible dim
    of an already (tensor-)sharded leaf."""
    entries = list(spec) + [None] * (leaf.ndim - len(spec))
    free = [i for i in range(leaf.ndim) if entries[i] is None
            and leaf.shape[i] % axes_size == 0 and leaf.shape[i] >= axes_size]
    if not free:
        return spec
    dim = max(free, key=lambda i: leaf.shape[i])
    entries[dim] = axes if len(axes) > 1 else axes[0]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)
