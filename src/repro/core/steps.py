"""Plan-aware step builders: the glue between the paper's execution plans
(core/plans.py), the model zoo, and pjit.

``build_train_step`` realizes each technique:
  * data      — replicated params, batch split, XLA inserts the grad
                all-reduce;
  * zero2     — gradients are pinned to the ZeRO shardings (XLA lowers the
                pin to a reduce-scatter), AdamW updates the local shard, and
                the new params are pinned back to replicated (all-gather);
  * shard     — tensor-parallel param shardings from the rule engine;
  * pipeshard — loss comes from core/pipeline.py (stage axis + microbatch
                ppermute pipeline), Shard rules inside each stage.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.pipeline import make_pipeline_loss, pipeline_mesh
from repro.core.plans import Plan
from repro.models.model import Model
from repro.optim import AdamWState, adamw_update, init_adamw, lr_at


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(opt_shapes: AdamWState, param_specs) -> AdamWState:
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def _set_moe_dispatch(model: Model, plan: Plan, mesh: Mesh,
                      global_batch: int) -> None:
    """Per-data-shard local MoE routing (H1, EXPERIMENTS.md §Perf): the
    global token sort otherwise all-gathers [T, d] per MoE layer.  Not
    under Pipeshard (the stage axis is already manual there)."""
    import dataclasses
    from repro.compat import NATIVE_SHARD_MAP
    if model.cfg.family != "moe":
        return
    if not NATIVE_SHARD_MAP:
        # per-data-shard dispatch needs partial-auto shard_map, which the
        # jax-0.4.x SPMD partitioner rejects — fall back to the (slower,
        # mathematically identical) global dispatch path
        return
    axes = () if plan.pipeline else plan.batch_axes(mesh, global_batch)
    e_axis = ""
    if plan.shards_weights and not plan.pipeline and "model" in mesh.shape \
            and model.cfg.moe.n_experts % mesh.shape["model"] == 0:
        e_axis = "model"
    model.cfg = dataclasses.replace(model.cfg, moe_dispatch_axes=tuple(axes),
                                    moe_expert_axis=e_axis)


def _set_logits_spec(model: Model, plan: Plan, mesh: Mesh,
                     global_batch: int) -> None:
    """Keep [*, *, vocab] logits (and fp32 softmax temporaries) sharded on
    the model axis under weight-sharding plans — without this pin the loss
    all-gathers the full-vocab logits per device (95 GB/device for a 3B
    model at 128k vocab)."""
    cfg = model.cfg
    if plan.shards_weights and "model" in mesh.shape \
            and cfg.vocab_size % mesh.shape["model"] == 0:
        axes = plan.batch_axes(mesh, global_batch)
        b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)
        model.logits_pspec = P(b_ax, None, "model")
    else:
        model.logits_pspec = None


def build_train_step(model: Model, plan: Plan, mesh: Mesh,
                     tcfg: TrainConfig, *, params_shapes,
                     batch_shapes, stage_layers=None,
                     schedule: str = "gpipe"
                     ) -> Tuple[Callable, Dict[str, Any]]:
    """Returns (jitted step, shardings dict).

    step(params, opt_state, batch) -> (params, opt_state, metrics)

    ``stage_layers``: pipeline plans only — per-stage (per-chunk under
    an interleaved schedule) layer counts from a searched
    ``core.plans.Placement`` (uneven splits run pad-and-masked, see
    ``core.pipeline.make_pipeline_loss``).

    ``schedule``: pipeline plans only — the tick-order schedule
    (``core.costmodel.SCHEDULES``, docs/schedules.md) the pipeline
    executes; reordering only, the loss/grads are schedule-invariant.
    """
    cfg = model.cfg
    _set_logits_spec(model, plan, mesh, batch_shapes["tokens"].shape[0])
    _set_moe_dispatch(model, plan, mesh, batch_shapes["tokens"].shape[0])
    if plan.fsdp and "model" in mesh.shape \
            and cfg.d_model % mesh.shape["model"] == 0:
        axes = plan.batch_axes(mesh, batch_shapes["tokens"].shape[0])
        b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)
        model.resid_pspec = P(b_ax, None, "model")
    else:
        model.resid_pspec = None
    if plan.pipeline:
        loss_fn = make_pipeline_loss(model, mesh, tcfg.microbatches,
                                     remat=tcfg.remat,
                                     stage_layers=stage_layers,
                                     schedule=schedule)
    else:
        loss_fn = partial(model.loss, remat=tcfg.remat)

    p_specs = plan.param_specs(params_shapes, cfg, mesh)
    o_specs_p = plan.opt_specs(params_shapes, cfg, mesh)   # zero or mirror
    opt_specs = AdamWState(step=P(), m=o_specs_p, v=o_specs_p)
    b_specs = plan.batch_spec(batch_shapes, mesh)
    metric_specs = P()

    def grad_fn(params, batch):
        if tcfg.grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # sequential microbatching: activations exist for one microbatch at
        # a time; grads accumulate in fp32 on the optimizer shards
        # (EXPERIMENTS.md §Perf H2 iter 4)
        A = tcfg.grad_accum
        batch_m = jax.tree.map(
            lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)
        batch_m = jax.lax.with_sharding_constraint(
            batch_m, jax.tree.map(
                lambda s: NamedSharding(mesh, P(None, *s)),
                plan.batch_spec(batch_shapes, mesh),
                is_leaf=lambda x: isinstance(x, P)))
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        g0 = jax.lax.with_sharding_constraint(g0, _ns(mesh, o_specs_p))

        def acc(carry, mb):
            g_acc, loss_acc, metrics_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                 g_acc, g)
            g_acc = jax.lax.with_sharding_constraint(
                g_acc, _ns(mesh, o_specs_p))
            loss_acc = loss_acc + loss
            metrics_acc = jax.tree.map(lambda a, m: a + m, metrics_acc,
                                       metrics)
            return (g_acc, loss_acc, metrics_acc), None

        m0 = {"ce": 0.0, "aux": 0.0, "zloss": 0.0, "accuracy": 0.0,
              "tokens": 0.0}
        m0 = jax.tree.map(jnp.float32, m0)
        (g_sum, loss_sum, m_sum), _ = jax.lax.scan(
            acc, (g0, jnp.float32(0), m0), batch_m)
        grads = jax.tree.map(lambda g: g / A, g_sum)
        metrics = jax.tree.map(lambda m: m / A, m_sum)
        metrics["tokens"] = metrics["tokens"] * A
        return (loss_sum / A, metrics), grads

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        if plan.zero_sharding:
            # pin grads to the ZeRO shards => XLA reduce-scatters them
            grads = jax.lax.with_sharding_constraint(
                grads, _ns(mesh, o_specs_p))
        lr = lr_at(opt_state.step, tcfg)
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, tcfg, lr)
        if plan.zero_sharding:
            # updated shards all-gather back to the plan's param placement
            new_params = jax.lax.with_sharding_constraint(
                new_params, _ns(mesh, p_specs))
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    shardings = {
        "params": _ns(mesh, p_specs),
        "opt": _ns(mesh, opt_specs),
        "batch": _ns(mesh, b_specs),
        "param_specs": p_specs,
        "opt_specs": opt_specs,
        "batch_specs": b_specs,
    }
    metric_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, metric_specs), {"_": 0})["_"]
    step = jax.jit(
        train_step,
        in_shardings=(shardings["params"], shardings["opt"],
                      shardings["batch"]),
        out_shardings=(shardings["params"], shardings["opt"], None),
        donate_argnums=(0, 1),
    )
    return step, shardings


def build_prefill_step(model: Model, plan: Plan, mesh: Mesh, *,
                       params_shapes, batch_shapes, cache_shapes,
                       batch_size: int, window: int = 0,
                       gather_last: bool = False):
    """``gather_last`` (continuous batching): the returned step takes an
    extra traced ``last_pos`` scalar and reads logits at that position —
    one compile per prompt-length *bucket* instead of per prompt length
    (the pad tail past ``last_pos`` is causally invisible)."""
    cfg = model.cfg
    _set_logits_spec(model, plan, mesh, batch_size)
    _set_moe_dispatch(model, plan, mesh, batch_size)
    p_sh = _ns(mesh, plan.param_specs(params_shapes, cfg, mesh))
    b_sh = _ns(mesh, plan.batch_spec(batch_shapes, mesh))
    c_sh = plan.cache_shardings(cache_shapes, cfg, mesh, batch_size)

    if gather_last:
        def prefill_at(params, batch, cache, last_pos):
            return model.prefill(params, batch, cache, window=window,
                                 last_pos=last_pos)

        return jax.jit(prefill_at,
                       in_shardings=(p_sh, b_sh, c_sh,
                                     NamedSharding(mesh, P())),
                       out_shardings=(None, c_sh)), {
                           "params": p_sh, "batch": b_sh, "cache": c_sh}

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache, window=window)

    return jax.jit(prefill,
                   in_shardings=(p_sh, b_sh, c_sh),
                   out_shardings=(None, c_sh)), {
                       "params": p_sh, "batch": b_sh, "cache": c_sh}


def build_serve_step(model: Model, plan: Plan, mesh: Mesh, *,
                     params_shapes, cache_shapes, batch_size: int,
                     window: int = 0):
    """ONE new token against a KV/state cache — what decode shapes lower."""
    cfg = model.cfg
    _set_logits_spec(model, plan, mesh, batch_size)
    _set_moe_dispatch(model, plan, mesh, batch_size)
    p_sh = _ns(mesh, plan.param_specs(params_shapes, cfg, mesh))
    c_sh = plan.cache_shardings(cache_shapes, cfg, mesh, batch_size)
    axes = plan.batch_axes(mesh, batch_size)
    tok_sh = NamedSharding(
        mesh, P(axes if len(axes) > 1 else (axes[0] if axes else None)))

    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              window=window)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return logits, next_tok, new_cache

    return jax.jit(serve_step,
                   in_shardings=(p_sh, c_sh, tok_sh),
                   out_shardings=(None, tok_sh, c_sh),
                   donate_argnums=(1,)), {
                       "params": p_sh, "cache": c_sh, "tokens": tok_sh}


def _is_index_path(path) -> bool:
    return any(getattr(p, "name", "") == "index" for p in path)


def build_insert_step(model: Model, plan: Plan, mesh: Mesh, *,
                      cache_shapes, src_cache_shapes, batch_size: int):
    """Prefill-insert for continuous batching: scatter one freshly
    prefilled request (a batch-1 cache from ``build_prefill_step``) into
    slot ``slot`` of the live per-slot decode cache
    (``Model.init_slot_cache``).

    ``length`` is the request's true prompt length: it overwrites the
    slot's ``index`` entries (the prefill cache holds the padded bucket
    length there), so the pad tail past it stays masked out of attention
    and the next decode append overwrites the first pad position.  The
    destination cache is donated — the scatter is in-place.
    """
    cfg = model.cfg
    dst_sh = plan.cache_shardings(cache_shapes, cfg, mesh, batch_size)
    src_sh = plan.cache_shardings(src_cache_shapes, cfg, mesh, 1)
    scalar_sh = NamedSharding(mesh, P())

    def insert(dst, src, slot, length):
        def leaf(path, d, s):
            if _is_index_path(path):
                # dst: [layers..., B] per-slot indices; the src cache's
                # shared per-layer index is replaced by the true length
                return d.at[..., slot].set(jnp.asarray(length, d.dtype))
            # batch dim: where dst (B) and src (1) disagree; equal-shape
            # leaves fall back to the cache_spec size convention
            b_dim = next((i for i, (a, b) in enumerate(zip(d.shape, s.shape))
                          if a != b), None)
            if b_dim is None:
                b_dim = next((i for i, n in enumerate(d.shape)
                              if n == batch_size), None)
            if b_dim is None:       # batch-free leaf (shared state)
                return d
            return jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, b_dim)

        return jax.tree_util.tree_map_with_path(leaf, dst, src)

    return jax.jit(insert,
                   in_shardings=(dst_sh, src_sh, scalar_sh, scalar_sh),
                   out_shardings=dst_sh,
                   donate_argnums=(0,)), {"cache": dst_sh, "src": src_sh}


def build_decode_slots_step(model: Model, plan: Plan, mesh: Mesh, *,
                            params_shapes, cache_shapes, batch_size: int,
                            window: int = 0, pad_id: int = 0):
    """One decode step over the persistent slot cache (continuous
    batching).  Beyond ``build_serve_step`` it takes a ``live`` [B] bool
    mask: dead (evicted, not yet backfilled) slots emit ``pad_id`` and
    their per-slot cache indices are frozen, so an evicted slot's ring
    state cannot drift between eviction and the insert that recycles it.
    """
    cfg = model.cfg
    _set_logits_spec(model, plan, mesh, batch_size)
    _set_moe_dispatch(model, plan, mesh, batch_size)
    p_sh = _ns(mesh, plan.param_specs(params_shapes, cfg, mesh))
    c_sh = plan.cache_shardings(cache_shapes, cfg, mesh, batch_size)
    axes = plan.batch_axes(mesh, batch_size)
    b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)
    tok_sh = NamedSharding(mesh, P(b_ax))
    live_sh = NamedSharding(mesh, P(b_ax))

    def decode_slots(params, cache, tokens, live):
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              window=window)

        def freeze(path, new, old):
            if _is_index_path(path):
                return jnp.where(live, new, old)   # [..., B] broadcast
            return new

        new_cache = jax.tree_util.tree_map_with_path(freeze, new_cache,
                                                     cache)
        next_tok = jnp.where(live[:, None],
                             jnp.argmax(logits, axis=-1)[:, None],
                             pad_id).astype(jnp.int32)
        return logits, next_tok, new_cache

    return jax.jit(decode_slots,
                   in_shardings=(p_sh, c_sh, tok_sh, live_sh),
                   out_shardings=(None, tok_sh, c_sh),
                   donate_argnums=(1,)), {
                       "params": p_sh, "cache": c_sh, "tokens": tok_sh}
