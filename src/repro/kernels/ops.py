"""Jit'd wrappers: layout/padding glue between model code ([B, S, H, D]
activations) and the Pallas kernels ([B, H, S, D] MXU-aligned tiles).

``interpret`` defaults to True off-TPU so the kernels execute (and are
tested) on CPU; on TPU backends the real kernels are emitted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import mamba_scan as _scan
from repro.kernels import flash_attention as _fa
from repro.kernels import quantized as _q


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Model-layout flash attention.  q: [B, Sq, H, Dk]; k/v: [B, Sk, KV, D*].
    Pads seq to block multiples and head_dim to a lane multiple (128),
    runs the kernel in [B, H, S, D] layout, unpads."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, H, Dk = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    qT = _pad_axis(_pad_axis(q.transpose(0, 2, 1, 3), block_q, 2), 128, 3)
    kT = _pad_axis(_pad_axis(k.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    vT = _pad_axis(_pad_axis(v.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    # padded kv positions past Sk are masked in-kernel via the static
    # kv_len key-validity mask — causality alone only hides them for
    # causal inputs, so the non-causal path needs it too.
    o = _fa.flash_attention_bhsd(qT, kT, vT, causal=causal, window=window,
                                 block_q=min(block_q, qT.shape[2]),
                                 block_k=min(block_k, kT.shape[2]),
                                 scale=1.0 / (Dk ** 0.5), kv_len=Sk,
                                 interpret=interpret)
    o = o.transpose(0, 2, 1, 3)[:, :Sq, :, :Dv]
    return o.astype(q.dtype)


# ------------------------------------------------------------------ #
# int8 quantization (kernels/quantized.py; docs/quantization.md)
# ------------------------------------------------------------------ #

@partial(jax.jit, static_argnames=("block", "axis"))
def quantize(x, *, block: int = 128, axis: int = -1):
    """Symmetric per-block absmax int8 quantization along ``axis``:
    scale = absmax/127 per block of ``block`` elements (all-zero blocks
    take scale 1.0).  Returns (q int8, x.shape) and (scale fp32, with
    the ``axis`` dim shrunk to ceil(n/block))."""
    axis = axis % x.ndim
    n = x.shape[axis]
    xm = jnp.moveaxis(x, axis, -1).astype(jnp.float32)
    pad = (-n) % block
    if pad:
        xm = jnp.pad(xm, [(0, 0)] * (xm.ndim - 1) + [(0, pad)])
    nb = xm.shape[-1] // block
    t = xm.reshape(xm.shape[:-1] + (nb, block))
    absmax = jnp.max(jnp.abs(t), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127)
    q = q.astype(jnp.int8).reshape(xm.shape)[..., :n]
    return jnp.moveaxis(q, -1, axis), jnp.moveaxis(scale, -1, axis)


@partial(jax.jit, static_argnames=("block", "axis"))
def dequantize(q, scale, *, block: int = 128, axis: int = -1):
    """Inverse of ``quantize``: q int8 * per-block scale -> fp32."""
    axis = axis % q.ndim
    n = q.shape[axis]
    qm = jnp.moveaxis(q, axis, -1).astype(jnp.float32)
    sm = jnp.repeat(jnp.moveaxis(scale, axis, -1), block, axis=-1)[..., :n]
    return jnp.moveaxis(qm * sm, -1, axis)


@partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                   "interpret"))
def int8_matmul(x, w, *, block_m: int = 128, block_k: int = 128,
                block_n: int = 128, interpret: bool | None = None):
    """Quantize fp x [M, K] and w [K, N] into per-tile int8 and multiply
    with the Pallas kernel (int32 MXU accumulate, fp32 dequant epilogue).
    Pads to block multiples (zero pads quantize to 0 and contribute
    nothing), unpads.  Returns fp32 [M, N]."""
    if interpret is None:
        interpret = _default_interpret()
    M, K = x.shape
    N = w.shape[1]
    xp = _pad_axis(_pad_axis(x, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w, block_k, 0), block_n, 1)
    xq, xs = _q.quantize_blocks(xp, block_m, block_k)
    wq, ws = _q.quantize_blocks(wp, block_k, block_n)
    out = _q.int8_matmul_blocked(xq, xs, wq, ws, block_m=block_m,
                                 block_k=block_k, block_n=block_n,
                                 interpret=interpret)
    return out[:M, :N]


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_int8kv(q, k_q, k_scale, v_q, v_scale, *, valid=None,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None):
    """Model-layout attention over int8-quantized keys/values.
    q: [B, Sq, H, Dk] fp; k_q/v_q: [B, Sk, KV, D*] int8 with per-token
    absmax scales k_scale/v_scale: [B, Sk, KV] fp32 (``quantize`` over
    the head dim, one block); valid: optional [B, Sk], >0 = key live —
    traced, so the decode ring-cache fill state can flow through it.
    Pads seq/head_dim, dequantizes in-kernel, unpads."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, H, Dk = q.shape
    Sk, Dv = k_q.shape[1], v_q.shape[-1]
    if valid is None:
        valid = jnp.ones((B, Sk), jnp.float32)
    qT = _pad_axis(_pad_axis(q.transpose(0, 2, 1, 3), block_q, 2), 128, 3)
    kT = _pad_axis(_pad_axis(k_q.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    vT = _pad_axis(_pad_axis(v_q.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    ksT = _pad_axis(k_scale.transpose(0, 2, 1), block_k, 2)
    vsT = _pad_axis(v_scale.transpose(0, 2, 1), block_k, 2)
    validp = _pad_axis(valid.astype(jnp.float32), block_k, 1)  # pad => dead
    o = _q.flash_attention_int8kv_bhsd(
        qT, kT, ksT, vT, vsT, validp, causal=causal, window=window,
        block_q=min(block_q, qT.shape[2]), block_k=min(block_k, kT.shape[2]),
        scale=1.0 / (Dk ** 0.5), interpret=interpret)
    o = o.transpose(0, 2, 1, 3)[:, :Sq, :, :Dv]
    return o.astype(q.dtype)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, b_s, c_s, a, *, chunk: int = 64,
             interpret: bool | None = None):
    """Model-layout SSD.  xh: [B, S, nh, hd]; dt: [B, S, nh];
    b_s/c_s: [B, S, ds]; a: [nh].  Returns (y [B,S,nh,hd] fp32, h_last)."""
    if interpret is None:
        interpret = _default_interpret()
    B, S, nh, hd = xh.shape
    x_t = _pad_axis(xh.transpose(0, 2, 1, 3), chunk, 2)       # [B,nh,S,hd]
    dt_t = _pad_axis(dt.transpose(0, 2, 1), chunk, 2)         # [B,nh,S]
    b_p = _pad_axis(b_s, chunk, 1)
    c_p = _pad_axis(c_s, chunk, 1)
    y, h_last = _scan.ssd_scan(x_t, dt_t, b_p, c_p, a, chunk=chunk,
                               interpret=interpret)
    return y[:, :, :S].transpose(0, 2, 1, 3), h_last


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba1_scan(x, dt, b_s, c_s, A, *, chunk: int = 64,
                interpret: bool | None = None):
    """x/dt: [B, S, di]; b_s/c_s: [B, S, ds]; A: [di, ds]."""
    if interpret is None:
        interpret = _default_interpret()
    S = x.shape[1]
    y, h_last = _scan.mamba1_scan(
        _pad_axis(x, chunk, 1), _pad_axis(dt, chunk, 1),
        _pad_axis(b_s, chunk, 1), _pad_axis(c_s, chunk, 1), A,
        chunk=chunk, interpret=interpret)
    return y[:, :S], h_last


# ------------------------------------------------------------------ #
# model-facing adapters (called from repro.models.* when use_pallas=True)
# ------------------------------------------------------------------ #

def ssd_scan_op(xh, delta, B_s, C_s, A, h0, *, chunk: int):
    """Adapter matching models.ssm._ssd_chunk_scan's signature.
    h0 is assumed zero at train time (kernel owns the carry)."""
    y, h_last = ssd_scan(xh, delta, B_s, C_s, A, chunk=chunk)
    return y, h_last


def mamba1_scan_op(x_conv, z, params, cfg, h0, *, chunk: int):
    """Adapter matching models.ssm._mamba1_inner: projects dt/B/C itself and
    applies D skip + gate, mirroring the jnp path."""
    dt_x = x_conv.dtype
    dt_rank = params["dt_proj"].shape[0]
    ds = cfg.ssm.d_state
    proj = jnp.einsum("bsc,cr->bsr", x_conv, params["x_proj"].astype(dt_x))
    dt_raw, B_s, C_s = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_raw, params["dt_proj"].astype(dt_x))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h_last = mamba1_scan(x_conv.astype(jnp.float32), delta,
                            B_s.astype(jnp.float32), C_s.astype(jnp.float32),
                            A, chunk=chunk)
    y = y + params["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(dt_x), h_last


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool | None = None):
    """Fused RMSNorm (kernels/rmsnorm.py)."""
    from repro.kernels import rmsnorm as _rn
    return _rn.rmsnorm(x, weight, eps=eps, block_rows=block_rows,
                       interpret=interpret)
