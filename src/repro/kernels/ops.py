"""Jit'd wrappers: layout/padding glue between model code ([B, S, H, D]
activations) and the Pallas kernels ([B, H, S, D] MXU-aligned tiles).

``interpret`` defaults to True off-TPU so the kernels execute (and are
tested) on CPU; on TPU backends the real kernels are emitted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import mamba_scan as _scan
from repro.kernels import flash_attention as _fa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Model-layout flash attention.  q: [B, Sq, H, Dk]; k/v: [B, Sk, KV, D*].
    Pads seq to block multiples and head_dim to a lane multiple (128),
    runs the kernel in [B, H, S, D] layout, unpads."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, H, Dk = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    qT = _pad_axis(_pad_axis(q.transpose(0, 2, 1, 3), block_q, 2), 128, 3)
    kT = _pad_axis(_pad_axis(k.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    vT = _pad_axis(_pad_axis(v.transpose(0, 2, 1, 3), block_k, 2), 128, 3)
    # padded kv positions are masked out by causality for q<=Sq... they are
    # NOT in general: mask them via an additive key of -inf is handled by
    # the kernel's position mask only when causal. For non-causal inputs we
    # rely on Sk % block_k == 0 after padding with window/causal masking;
    # serving paths always run causal.
    o = _fa.flash_attention_bhsd(qT, kT, vT, causal=causal, window=window,
                                 block_q=min(block_q, qT.shape[2]),
                                 block_k=min(block_k, kT.shape[2]),
                                 scale=1.0 / (Dk ** 0.5),
                                 interpret=interpret)
    o = o.transpose(0, 2, 1, 3)[:, :Sq, :, :Dv]
    return o.astype(q.dtype)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, b_s, c_s, a, *, chunk: int = 64,
             interpret: bool | None = None):
    """Model-layout SSD.  xh: [B, S, nh, hd]; dt: [B, S, nh];
    b_s/c_s: [B, S, ds]; a: [nh].  Returns (y [B,S,nh,hd] fp32, h_last)."""
    if interpret is None:
        interpret = _default_interpret()
    B, S, nh, hd = xh.shape
    x_t = _pad_axis(xh.transpose(0, 2, 1, 3), chunk, 2)       # [B,nh,S,hd]
    dt_t = _pad_axis(dt.transpose(0, 2, 1), chunk, 2)         # [B,nh,S]
    b_p = _pad_axis(b_s, chunk, 1)
    c_p = _pad_axis(c_s, chunk, 1)
    y, h_last = _scan.ssd_scan(x_t, dt_t, b_p, c_p, a, chunk=chunk,
                               interpret=interpret)
    return y[:, :, :S].transpose(0, 2, 1, 3), h_last


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba1_scan(x, dt, b_s, c_s, A, *, chunk: int = 64,
                interpret: bool | None = None):
    """x/dt: [B, S, di]; b_s/c_s: [B, S, ds]; A: [di, ds]."""
    if interpret is None:
        interpret = _default_interpret()
    S = x.shape[1]
    y, h_last = _scan.mamba1_scan(
        _pad_axis(x, chunk, 1), _pad_axis(dt, chunk, 1),
        _pad_axis(b_s, chunk, 1), _pad_axis(c_s, chunk, 1), A,
        chunk=chunk, interpret=interpret)
    return y[:, :S], h_last


# ------------------------------------------------------------------ #
# model-facing adapters (called from repro.models.* when use_pallas=True)
# ------------------------------------------------------------------ #

def ssd_scan_op(xh, delta, B_s, C_s, A, h0, *, chunk: int):
    """Adapter matching models.ssm._ssd_chunk_scan's signature.
    h0 is assumed zero at train time (kernel owns the carry)."""
    y, h_last = ssd_scan(xh, delta, B_s, C_s, A, chunk=chunk)
    return y, h_last


def mamba1_scan_op(x_conv, z, params, cfg, h0, *, chunk: int):
    """Adapter matching models.ssm._mamba1_inner: projects dt/B/C itself and
    applies D skip + gate, mirroring the jnp path."""
    dt_x = x_conv.dtype
    dt_rank = params["dt_proj"].shape[0]
    ds = cfg.ssm.d_state
    proj = jnp.einsum("bsc,cr->bsr", x_conv, params["x_proj"].astype(dt_x))
    dt_raw, B_s, C_s = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_raw, params["dt_proj"].astype(dt_x))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h_last = mamba1_scan(x_conv.astype(jnp.float32), delta,
                            B_s.astype(jnp.float32), C_s.astype(jnp.float32),
                            A, chunk=chunk)
    y = y + params["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(dt_x), h_last


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool | None = None):
    """Fused RMSNorm (kernels/rmsnorm.py)."""
    from repro.kernels import rmsnorm as _rn
    return _rn.rmsnorm(x, weight, eps=eps, block_rows=block_rows,
                       interpret=interpret)
