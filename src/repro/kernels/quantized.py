"""Int8 Pallas kernels: blocked matmul and int8-KV flash attention.

Scale scheme (docs/quantization.md): symmetric per-block absmax —
``scale = max(|x|) / 127`` over each block, ``q = round(x / scale)``
clipped to [-127, 127].  Zero blocks take scale 1.0 so the round trip
stays exact.

  * ``int8_matmul_blocked``: [M, K] x [K, N] over a (nM, nN, nK) grid
    with K as the sequential minor dimension.  Each step issues an
    int8 x int8 MXU matmul accumulated in int32
    (``preferred_element_type=jnp.int32``); because absmax scales differ
    per K block, every step dequantizes its int32 partial into the fp32
    VMEM accumulator (dequant epilogue on the last K step writes out).
  * ``flash_attention_int8kv_bhsd``: flash_attention.py's online-softmax
    kernel with int8 k/v refs plus per-token fp32 scales, dequantized
    in-kernel right before the q.k^T and p.v matmuls.  A dynamic
    key-validity input masks ring-cache slots that are not yet filled
    (decode) and padded key positions (non-causal prefill).

Oracles: kernels/ref.py (``matmul_ref``, ``attention_ref``); parity and
error bounds in tests/test_quantized.py (interpret mode on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def quantize_blocks(x, block_rows: int, block_cols: int):
    """Per-2D-tile absmax int8 quantization of a [M, K] fp array (M, K
    already padded to block multiples).  Returns (q int8 [M, K],
    scale fp32 [M // block_rows, K // block_cols])."""
    M, K = x.shape
    nm, nk = M // block_rows, K // block_cols
    t = x.astype(jnp.float32).reshape(nm, block_rows, nk, block_cols)
    absmax = jnp.max(jnp.abs(t), axis=(1, 3))                  # [nm, nk]
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.round(t / scale[:, None, :, None])
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(M, K), scale


def _int8_matmul_kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, acc_scr, *,
                        n_k_blocks: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    prod = jax.lax.dot_general(
        xq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                  # [bm, bn] int32
    # one absmax scale per (row-block, K-block) x (K-block, col-block)
    # pair => the int32 partial dequantizes with a single scalar.
    acc_scr[...] += prod.astype(jnp.float32) * (xs_ref[0, 0] * ws_ref[0, 0])

    @pl.when(kk == n_k_blocks - 1)
    def _finalize():
        o_ref[...] = acc_scr[...]


def int8_matmul_blocked(xq, xs, wq, ws, *, block_m: int = 128,
                        block_k: int = 128, block_n: int = 128,
                        interpret: bool = False):
    """xq: [M, K] int8 with xs: [M/bm, K/bk] fp32 scales; wq: [K, N] int8
    with ws: [K/bk, N/bn].  Shapes must already be block multiples
    (ops.int8_matmul pads).  Returns fp32 [M, N]."""
    M, K = xq.shape
    N = wq.shape[1]
    nm, nn, nk = M // block_m, N // block_n, K // block_k
    assert xs.shape == (nm, nk) and ws.shape == (nk, nn), (xs.shape, ws.shape)

    kernel = functools.partial(_int8_matmul_kernel, n_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(xq, xs, wq, ws)


def _int8kv_flash_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, valid_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, block_q: int,
                         block_k: int, n_kv_blocks: int, scale: float,
                         causal: bool, window: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [bq, d]
    # dequant-in-kernel: int8 payload x per-token fp32 absmax scale
    k = kq_ref[0, 0].astype(jnp.float32) \
        * ks_ref[0, 0].reshape(block_k, 1)                 # [bk, d]
    v = vq_ref[0, 0].astype(jnp.float32) \
        * vs_ref[0, 0].reshape(block_k, 1)                 # [bk, dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = valid_ref[0].reshape(1, block_k) > 0            # dynamic validity
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                    # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_int8kv_bhsd(q, kq, ks, vq, vs, valid, *,
                                causal: bool = True, window: int = 0,
                                block_q: int = 128, block_k: int = 128,
                                scale: float | None = None,
                                interpret: bool = False):
    """q: [B, H, Sq, D] fp; kq/vq: [B, KV, Sk, D*] int8 with per-token
    scales ks/vs: [B, KV, Sk] fp32; valid: [B, Sk] fp32 (>0 = key is
    live — carries both pad masking and the decode ring-cache fill
    state, so it may be traced).  Returns [B, H, Sq, Dv] in q.dtype."""
    B, H, Sq, D = q.shape
    _, KV, Sk, Dv = vq.shape
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _int8kv_flash_kernel, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, scale=scale, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k),
                         lambda b, h, i, j: (b, h // group, j)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k),
                         lambda b, h, i, j: (b, h // group, j)),
            pl.BlockSpec((1, block_k), lambda b, h, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, Dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, kq, ks, vq, vs, valid)
