"""Fused RMSNorm as a Pallas TPU kernel.

The jnp path materializes three [*, d] fp32 intermediates (square, mean,
rsqrt-scaled) per call — at 2 norms/layer × 126 layers this is pure HBM
traffic.  The kernel fuses the reduction and the scale into one VMEM pass
per [block_rows, d] tile: read x once, write y once.

Oracle: kernels/ref.py::rmsnorm_ref (== models/layers.py::rmsnorm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # [rows, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool | None = None):
    """x: [..., d]; weight: [d].  Rows are tiled into VMEM blocks."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, weight)
    return out[:rows].reshape(orig_shape)
