"""Pure-jnp oracles for every kernel (the ground truth the Pallas kernels
are swept against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Direct (materialized-scores) attention.  q: [B, H, Sq, D];
    k/v: [B, KV, Sk, D*]; returns [B, H, Sq, Dv] in q.dtype."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (D ** 0.5)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= kj
    if window:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(xh, dt, b_s, c_s, a):
    """Sequential (per-token) SSD recurrence — the trusted slow path.
    xh: [B, nh, S, hd]; dt: [B, nh, S]; b_s/c_s: [B, S, ds]; a: [nh].
    Returns (y [B, nh, S, hd] fp32, h_last [B, nh, hd, ds] fp32)."""
    B, nh, S, hd = xh.shape
    ds = b_s.shape[-1]
    xh = xh.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    b_s = b_s.astype(jnp.float32)
    c_s = c_s.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dt[:, :, t] * a)                       # [B, nh]
        dx = dt[:, :, t, None] * xh[:, :, t]                   # [B, nh, hd]
        h = decay[..., None, None] * h \
            + dx[..., None] * b_s[:, None, t, None, :]
        y = jnp.einsum("bhds,bs->bhd", h, c_s[:, t])
        return h, y

    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 2), h_last


def mamba1_ref(x, dt, b_s, c_s, A):
    """Sequential mamba1 recurrence.  x/dt: [B, S, di]; b_s/c_s: [B, S, ds];
    A: [di, ds].  Returns (y [B, S, di] fp32, h_last [B, di, ds])."""
    B, S, di = x.shape
    ds = b_s.shape[-1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    b_s = b_s.astype(jnp.float32)
    c_s = c_s.astype(jnp.float32)

    def step(h, t):
        a_t = jnp.exp(dt[:, t, :, None] * A)                   # [B, di, ds]
        h = a_t * h + (dt[:, t] * x[:, t])[..., None] * b_s[:, t, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_s[:, t])
        return h, y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h_last


def rmsnorm_ref(x, weight, *, eps: float = 1e-5):
    """Reference RMSNorm (same math as models/layers.py::rmsnorm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * weight.astype(jnp.float32)).astype(x.dtype)


def matmul_ref(x, w):
    """fp32 reference matmul — the accuracy oracle for the int8 blocked
    matmul (kernels/quantized.py; tests/test_quantized.py)."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
