"""Causal GQA flash attention as a Pallas TPU kernel.

TPU adaptation (vs the CUDA flash-attention algorithm):
  * tiling is chosen for VMEM + the 128x128 MXU: q/k/v tiles are
    [block_q, head_dim] / [block_k, head_dim] with head_dim padded to a
    multiple of 128 by the wrapper, so every matmul hits the systolic array;
  * the kv loop is the *sequential* (minor) grid dimension — VMEM scratch
    (m, l, acc) persists across kv steps per (batch, head, q-block), which
    replaces the CUDA shared-memory accumulator;
  * softmax statistics are fp32 in VREGs; only the final normalized tile is
    cast back to the model dtype.

The pure-jnp oracle is kernels/ref.py::attention_ref; parity is asserted in
interpret mode over shape/dtype sweeps by tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, n_kv_blocks: int, scale: float,
                  causal: bool, window: int, kv_len: int = 0):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                # [bk, dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    if kv_len:
        # keys past the unpadded length are invalid for every query —
        # causality only hides them when q_pos is also < kv_len, so the
        # non-causal path needs this explicit key-validity mask.
        mask &= k_pos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         scale: float | None = None, kv_len: int = 0,
                         interpret: bool = False):
    """q: [B, H, Sq, D]; k/v: [B, KV, Sk, D] (already GQA-expanded index
    mapping, head_dim padded).  ``scale`` must be 1/sqrt(unpadded head_dim)
    when the wrapper padded D.  ``kv_len`` (static) masks key positions
    >= kv_len — required when the wrapper padded Sk and causal=False.
    Returns [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    _, KV, Sk, Dv = v.shape
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if kv_len >= Sk:
        kv_len = 0                       # every key valid — skip the mask

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        scale=scale, causal=causal, window=window, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, Dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
