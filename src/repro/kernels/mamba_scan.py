"""Mamba2/SSD chunked selective scan as a Pallas TPU kernel.

TPU adaptation (vs the CUDA mamba kernel):
  * the CUDA implementation leans on warp-level parallel prefix scans;
    TPUs have no warp shuffles, so we use the SSD *block decomposition*:
    the intra-chunk part becomes a decay-masked [K, K] matmul (MXU work)
    and only the chunk-boundary state is carried — the recurrence runs
    over the sequential grid dimension with the state in VMEM scratch;
  * chunk length K and head_dim are the MXU-aligned tile sides; d_state
    rides along the lane dimension.

Grid: (batch, heads, n_chunks) with n_chunks the sequential (minor) axis.
Per step the kernel computes
    y_intra = (L ∘ (C Bᵀ) ∘ dtᵀ) X        (within-chunk, matmul form)
    y_inter = exp(s) C · h                 (contribution of carried state)
    h      <- exp(s_K) h + Σ_j exp(s_K - s_j) dt_j x_j ⊗ B_j
with s the in-chunk cumulative log-decay.  Oracle: kernels/ref.py::ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hlast_ref, h_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # [K, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [K, 1]
    bs = b_ref[0].astype(jnp.float32)            # [K, ds]
    cs = c_ref[0].astype(jnp.float32)            # [K, ds]
    a = a_ref[0, 0]                              # scalar (negative)

    da = dt[:, 0] * a                            # [K] log-decay increments
    s_cum = jnp.cumsum(da)                       # [K]
    # intra-chunk decay matrix L[i,j] = exp(s_i - s_j) * dt_j, causal
    diff = s_cum[:, None] - s_cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(diff) * dt[:, 0][None, :], 0.0)
    scores = jax.lax.dot_general(cs, bs, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [K,K]
    y_intra = jax.lax.dot_general(L * scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(s_i) * C_i . h   (h: [hd, ds])
    h = h_scr[...]
    y_inter = jnp.exp(s_cum)[:, None] * jax.lax.dot_general(
        cs, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(s_K) h + Σ_j exp(s_K - s_j) dt_j x_j ⊗ B_j
    tail = jnp.exp(s_cum[-1] - s_cum) * dt[:, 0]          # [K]
    dh = jax.lax.dot_general(x * tail[:, None], bs,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [hd, ds]
    h_scr[...] = jnp.exp(s_cum[-1]) * h + dh

    @pl.when(ci == n_chunks - 1)
    def _final():
        hlast_ref[0, 0] = h_scr[...]


def ssd_scan(xh, dt, b_s, c_s, a, *, chunk: int = 64,
             interpret: bool = False):
    """xh: [B, nh, S, hd]; dt: [B, nh, S]; b_s/c_s: [B, S, ds]; a: [nh].
    Returns (y [B, nh, S, hd], h_last [B, nh, hd, ds]).  S % chunk == 0."""
    B, nh, S, hd = xh.shape
    ds = b_s.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    a_in = jnp.broadcast_to(a.astype(jnp.float32)[None, :, None], (B, nh, 1))

    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xh, dt[..., None], b_s, c_s, a_in)
    return y, h_last


# --------------------------------------------------------------------- #
# Mamba1: per-channel recurrence, sequential in-chunk loop
# --------------------------------------------------------------------- #

def _mamba1_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hlast_ref,
                   h_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)       # [K, di]
    dt = dt_ref[0].astype(jnp.float32)     # [K, di]
    bs = b_ref[0].astype(jnp.float32)      # [K, ds]
    cs = c_ref[0].astype(jnp.float32)      # [K, ds]
    A = a_ref[...].astype(jnp.float32)     # [di, ds]

    def step(t, carry):
        h, y = carry
        a_t = jnp.exp(dt[t][:, None] * A)                 # [di, ds]
        h = a_t * h + (dt[t] * x[t])[:, None] * bs[t][None, :]
        y = y.at[t].set(jnp.sum(h * cs[t][None, :], axis=1))
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros_like(x)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hlast_ref[0] = h_scr[...]


def mamba1_scan(x, dt, b_s, c_s, A, *, chunk: int = 64,
                interpret: bool = False):
    """x/dt: [B, S, di]; b_s/c_s: [B, S, ds]; A: [di, ds] (negative).
    Returns (y [B, S, di] fp32, h_last [B, di, ds])."""
    B, S, di = x.shape
    ds = b_s.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kernel = functools.partial(_mamba1_kernel, chunk=chunk, n_chunks=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di, ds), lambda b, c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, di, ds), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, b_s, c_s, A)
    return y, h_last
