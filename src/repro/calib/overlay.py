"""The ``Calibration`` overlay: measured rates on top of analytic prices.

A ``Calibration`` holds the two coefficient families the cost model is
linear in per ``CostContext`` (docs/calibration.md §2):

  * per-site achieved TFLOP/s (replacing the datasheet ``GPUS[...]``
    numbers that ``_make_context``/``stage_compute_tflops`` read), and
  * per-site-pair measured links — α (latency seconds) and an *achieved*
    rate in GB/s (replacing ``Topology.link``'s analytic edges).

Both maps are sparse: a site or pair with no entry falls through to the
exact analytic expression, returning the very same ``Link`` objects and
evaluating the very same ``min(GPUS[g].tflops ...)`` floats.  That makes
``Calibration.identity()`` (both maps empty) bit-for-bit equal to the
uncalibrated cost model — the differential gate in
``tests/test_calib_gates.py`` pins this with ``==`` on every searched
price.

Measured link rates are stored as *achieved effective* GB/s: the
measurement already includes every TCP-window/RTT effect, so
``MeasuredLink`` must not re-apply the analytic window clamp
(``topology.Link.effective_gbps``) on top of it.

Pair keys are end-to-end: on a routed topology (line/hub) the key
``(i, j)`` calibrates the whole relayed path between sites i and j, not
a physical edge — exactly the granularity ``Topology.link`` prices at.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.topology import GPUS, Link, Topology


@dataclass(frozen=True)
class MeasuredLink(Link):
    """A link whose effective rate IS the measured achieved rate.

    The analytic ``Link.effective_gbps`` clamps bandwidth by the TCP
    window rule; a fitted rate was *measured through* that window, so
    re-clamping would double-count the effect.
    """

    @property
    def effective_gbps(self) -> float:
        return self.bandwidth_gbps


@dataclass(frozen=True)
class LinkRate:
    """Measured coefficients of one site pair: α in seconds, achieved
    rate in GB/s (β, the inverse bandwidth, is ``1 / (gbps * 1e9)`` —
    stored as a rate so pricing keeps the ``bytes / (gbps * 1e9)``
    expression shape of the analytic model)."""
    alpha_s: float
    gbps: float

    def link(self) -> MeasuredLink:
        return MeasuredLink(self.alpha_s, self.gbps)


def _key(i: int, j: int) -> Tuple[int, int]:
    return (i, j) if i <= j else (j, i)


@dataclass(frozen=True)
class Calibration:
    """Sparse measured-rate overlay; see the module docstring.

    Attributes:
        site_tflops: site index -> achieved per-GPU TFLOP/s (the pace of
            the slowest card of that site, the quantity
            ``_make_context`` reduces datasheet specs to).
        links: canonical ``(i, j)`` site pair (``i <= j``; ``(i, i)`` is
            site i's intra link) -> measured ``LinkRate``.
        note: free-form provenance (who measured, when, which harness).
    """
    site_tflops: Mapping[int, float] = field(default_factory=dict)
    links: Mapping[Tuple[int, int], LinkRate] = field(default_factory=dict)
    note: str = ""

    def __post_init__(self) -> None:
        # canonicalize pair keys at construction so (1, 0) and (0, 1)
        # name the same measurement regardless of who built the map
        object.__setattr__(self, "links",
                           {_key(i, j): lr
                            for (i, j), lr in self.links.items()})

    # ------------------------------------------------------------- #
    @classmethod
    def identity(cls) -> "Calibration":
        """The empty overlay: every lookup falls through to the analytic
        price.  Bit-for-bit equal to passing ``calibration=None``."""
        return cls()

    @property
    def is_identity(self) -> bool:
        return not self.site_tflops and not self.links

    # ------------------------------------------------------------- #
    # lookups — fall through to the exact analytic objects/expressions
    # ------------------------------------------------------------- #

    def gpu_tflops(self, topo: Topology, i: int) -> float:
        """Achieved per-GPU TFLOP/s of site i (pool pace = its slowest
        card); falls back to the datasheet minimum over the site."""
        got = self.site_tflops.get(i)
        if got is not None:
            return got
        return min(GPUS[g].tflops for g in topo.sites[i].gpus)

    def link(self, topo: Topology, i: int, j: int) -> Link:
        """The (measured or analytic) link between sites i and j;
        ``i == j`` is the intra-site link."""
        got = self.links.get(_key(i, j))
        if got is not None:
            return got.link()
        return topo.link(i, j)

    def spanning_links(self, topo: Topology, sites: Sequence[int]
                       ) -> List[Link]:
        """Calibrated counterpart of ``Topology.spanning_links`` (same
        pair order, same objects wherever no override exists)."""
        import itertools
        idx = topo.select(sites)
        return [self.link(topo, i, j)
                for i, j in itertools.combinations(idx, 2)]

    # ------------------------------------------------------------- #
    # JSON round-trip
    # ------------------------------------------------------------- #

    def to_json(self) -> Dict:
        return {
            "site_tflops": {str(i): t
                            for i, t in sorted(self.site_tflops.items())},
            "links": [[i, j, lr.alpha_s, lr.gbps]
                      for (i, j), lr in sorted(self.links.items())],
            "note": self.note,
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "Calibration":
        sites = {int(i): float(t)
                 for i, t in obj.get("site_tflops", {}).items()}
        links = {_key(int(i), int(j)): LinkRate(float(a), float(g))
                 for i, j, a, g in obj.get("links", [])}
        return cls(sites, links, obj.get("note", ""))

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=1, sort_keys=True) + "\n"

    @classmethod
    def loads(cls, text: str) -> "Calibration":
        return cls.from_json(json.loads(text))

    # ------------------------------------------------------------- #
    def describe(self, topo: Topology) -> str:
        """Human-readable datasheet-vs-fitted table."""
        parts = [f"calibration ({self.note or 'unnamed'}):"]
        for i, s in enumerate(topo.sites):
            sheet = min(GPUS[g].tflops for g in s.gpus)
            got = self.site_tflops.get(i)
            tag = f"{got:.2f} fitted" if got is not None else "analytic"
            parts.append(f"  S{i} {'+'.join(s.gpus)}: "
                         f"{sheet:.1f} TFLOP/s datasheet -> {tag}")
        for (i, j), lr in sorted(self.links.items()):
            parts.append(f"  S{i}--S{j}: alpha {lr.alpha_s * 1e3:.3f}ms, "
                         f"rate {lr.gbps:.3f} GB/s (measured)")
        return "\n".join(parts)
