"""Measured-rate calibration: close the analytic cost model to hardware.

``overlay``    — the ``Calibration`` overlay (per-site achieved TFLOP/s,
                 per-link measured α/rate), JSON round-trippable, with
                 ``Calibration.identity()`` bit-for-bit equal to the
                 analytic prices in ``core/costmodel.py``.
``microbench`` — micro-benchmark harness over the Pallas kernels and a
                 host ring-collective emulation, the ``RecordingProber``
                 adapter pooling ``LiveProber`` ε-epoch step times, and
                 the synthetic-ground-truth measurement generator the
                 test harness fits against.
``fit``        — the least-squares fitter recovering per-site TFLOP/s
                 and per-link α/β from measurements; the design matrix
                 comes straight from the ``TECHNIQUE_SPECS`` component
                 terms (docs/calibration.md derives it).
"""
from repro.calib.overlay import Calibration, LinkRate, MeasuredLink
from repro.calib.fit import (FitResult, Sample, fit_calibration,
                             step_design_row)

__all__ = [
    "Calibration", "LinkRate", "MeasuredLink",
    "FitResult", "Sample", "fit_calibration", "step_design_row",
]
