"""Micro-benchmark harnesses producing ``fit.Sample`` measurement sets.

Three measurement families (docs/calibration.md §1):

  * ``kernel_compute_samples`` — times the Pallas kernels
    (``flash_attention``, ``int8_matmul``) and the jitted fp32 matmul at
    several sizes, interpret-mode fallback on CPU (same convention as
    tests/test_kernels.py), each with its known FLOP count → per-site
    compute rows.
  * ``host_ring_collective_samples`` — emulates the ring all-reduce's
    2(n-1) chunk exchanges over host memory at several payload sizes →
    per-link α/β rows (on one host this measures the loopback/memory
    path standing in for the intra-site link; real deployments run it
    once per site pair).
  * ``RecordingProber`` — wraps any ``core.selector`` prober (the live
    ε-epoch ``LiveProber`` included) and pools every probed step time
    into step rows, so Algorithm-1 probes stop being thrown away.

``synthetic_measurements`` generates the same three families from a
*known* ground-truth ``Calibration`` with bounded multiplicative noise —
the synthetic-ground-truth harness the fitter is proven against
(tests/test_calib.py) and that ``benchmarks/calib_bench.py`` closes the
before/after ``search_vs_measured_error`` loop with.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.calib.fit import (Sample, collective_sample, compute_sample,
                             step_sample)
from repro.calib.overlay import Calibration, _key
from repro.core.costmodel import Workload, technique_step_cost
from repro.core.plans import Placement
from repro.core.topology import Topology


def _time_s(fn, *args, iters: int = 2) -> float:
    """Warm once, then average wall seconds per call (the
    benchmarks/kernel_bench.py convention)."""
    import jax
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def kernel_compute_samples(site: int = 0, *, iters: int = 2,
                           sizes: Sequence[int] = (128, 192),
                           seed: int = 0,
                           interpret: bool = True) -> List[Sample]:
    """Compute rows from real kernel timings on this host.

    Args:
        site: which topology site this host stands for.
        iters: timed calls per kernel after the warm-up call.
        sizes: square matmul sizes M=K=N to time.
        seed: PRNG seed for the operand data.
        interpret: run Pallas kernels in interpret mode (required on
            CPU; pass False only on a real accelerator backend).

    Returns:
        One ``"compute"`` sample per timing, FLOPs attributed per GPU.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    out: List[Sample] = []
    mm = jax.jit(jnp.matmul)
    for m in sizes:
        x = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
        flops = 2.0 * m * m * m
        out.append(compute_sample(site, flops,
                                  _time_s(mm, x, w, iters=iters)))
        out.append(compute_sample(
            site, flops,
            _time_s(lambda *a: ops.int8_matmul(
                *a, block_m=64, block_k=64, block_n=64,
                interpret=interpret), x, w, iters=iters)))
    b, s, h, kv, d = 1, 128, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    flops = 4.0 * b * s * s * h * d
    out.append(compute_sample(
        site, flops,
        _time_s(lambda *a: ops.flash_attention(
            *a, causal=True, block_q=64, block_k=64,
            interpret=interpret), q, k, v, iters=iters)))
    return out


def host_ring_collective_samples(pair: Tuple[int, int] = (0, 0), *,
                                 n_ranks: int = 2,
                                 sizes_bytes: Sequence[float] = (
                                     1 << 20, 4 << 20, 16 << 20),
                                 iters: int = 2) -> List[Sample]:
    """Collective rows from an emulated ring all-reduce over host
    memory: 2(n-1) chunk exchanges of volume/n bytes each (the
    reduce-scatter + all-gather decomposition ``_allreduce_time``
    prices), timed wall-clock.

    On a single host this measures the loopback/memcpy path — a real
    per-link measurement runs the same exchange across the actual
    socket (``repro.launch.calibrate`` documents the protocol).
    """
    out: List[Sample] = []
    for volume in sizes_bytes:
        chunk = max(int(volume) // max(n_ranks, 1) // 4, 1)   # fp32 words
        src = np.ones(chunk, np.float32)
        acc = np.zeros(chunk, np.float32)
        buf = np.empty(chunk, np.float32)

        def once() -> None:
            for _ in range(2 * (n_ranks - 1)):
                np.copyto(buf, src)        # the "send/recv" hop
                np.add(acc, buf, out=acc)  # the reduce (or gather write)

        once()
        t0 = time.perf_counter()
        for _ in range(iters):
            once()
        out.append(collective_sample(
            pair, n_ranks, float(volume),
            (time.perf_counter() - t0) / iters))
    return out


@dataclass
class RecordingProber:
    """A ``core.selector.Prober`` that pools every ε-epoch step time.

    Wraps any inner prober (``LiveProber`` on hardware,
    ``CostModelProber`` in tests/benches) and converts each successful
    probe back to the step seconds the TFLOP/s figure came from
    (``time = flops_per_step / (tflops * 1e12)``), recording a
    ``"step"`` sample per probe — the measurements Algorithm 1 used to
    throw away become fitter rows.
    """
    inner: object               # anything with .probe(technique, placement)
    wl: Workload
    samples: List[Sample] = field(default_factory=list)

    @property
    def n_sites(self) -> int:
        return getattr(self.inner, "n_sites", 2)

    def probe(self, technique: str, placement: Optional[Placement]
              ) -> Optional[float]:
        tflops = self.inner.probe(technique, placement)
        if tflops and placement is not None:
            self.samples.append(step_sample(
                technique, tuple(placement.sites), self.wl,
                self.wl.flops_per_step / (tflops * 1e12),
                stage_order=placement.stage_order,
                stage_layers=placement.stage_layers,
                schedule=placement.schedule))
        return tflops


def synthetic_measurements(
        topo: Topology, truth: Calibration, *,
        rng: np.random.Generator, noise: float = 0.0,
        compute_flops: Sequence[float] = (1e12, 4e12),
        link_scales: Sequence[float] = (0.3, 3.0, 30.0),
        step_placements: Sequence[Tuple[str, Tuple[int, ...], dict]] = (),
        wl: Optional[Workload] = None) -> List[Sample]:
    """The synthetic-ground-truth harness: measurement sets whose exact
    generating coefficients are known.

    Times are computed from ``truth`` by the very formulas the cost
    model prices with, then perturbed multiplicatively by
    ``1 + noise * u`` with ``u ~ U(-1, 1)`` — so at ``noise=0`` the
    fitter must recover ``truth`` exactly (up to float roundoff), and
    under noise the recovery error is provably noise-bounded.

    Args:
        topo: the topology being "measured".
        truth: the ground-truth overlay generating the times.
        rng: noise source.
        noise: multiplicative noise bound (0 = exact).
        compute_flops: per-site kernel sizes (FLOPs per GPU).
        link_scales: per-link payload sizes as multiples of the link's
            latency-bandwidth product (spanning the α- and β-dominated
            regimes keeps both coefficients well-conditioned).
        step_placements: optional ``(technique, sites, knobs)`` whole-
            step probes, priced under ``truth`` (requires ``wl``).
        wl: the workload for step placements.
    """
    def jitter() -> float:
        return 1.0 + noise * float(rng.uniform(-1.0, 1.0)) if noise \
            else 1.0

    out: List[Sample] = []
    for i in range(topo.n_sites):
        rate = truth.gpu_tflops(topo, i) * 1e12
        for flops in compute_flops:
            out.append(compute_sample(i, flops,
                                      flops / rate * jitter()))
    pairs = [(i, i) for i in range(topo.n_sites)]
    pairs += [_key(i, j) for i in range(topo.n_sites)
              for j in range(i + 1, topo.n_sites)]
    for pair in pairs:
        link = truth.link(topo, *pair)
        alpha_s = link.latency_s
        rate = link.effective_gbps * 1e9
        base_bytes = max(alpha_s, 1e-6) * rate
        n = 2
        for scale in link_scales:
            volume = base_bytes * scale
            t = 2 * (n - 1) * alpha_s \
                + 2 * (n - 1) / n * volume / rate
            out.append(collective_sample(pair, n, volume, t * jitter()))
    for technique, sel, knobs in step_placements:
        assert wl is not None, "step placements need a workload"
        t = technique_step_cost(technique, wl, topo, sel,
                                calibration=truth, **knobs).total_s
        out.append(step_sample(technique, sel, wl, t * jitter(),
                               **knobs))
    return out
