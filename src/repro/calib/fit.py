"""Least-squares fit of measured rates into the cost model's coefficients.

The analytic cost model (``core/costmodel.py``) prices every step as a
sum that is *linear* in exactly two coefficient families per
``CostContext`` (docs/calibration.md §2 derives this from the
``TECHNIQUE_SPECS`` component terms):

  * ``theta_site = 1 / (tflops * 1e12)`` — seconds per FLOP of one GPU
    of a site (compute terms are ``flops_share * theta_site``), and
  * per site pair, ``alpha`` (link latency seconds; collectives pay
    ``rounds * alpha``) and ``beta = 1 / (gbps * 1e9)`` — seconds per
    byte (collectives pay ``fraction * volume_bytes * beta``).

So a measurement set — kernel timings (compute rows), ring-collective
timings at several sizes (link rows), and whole-step times pooled from
``LiveProber`` ε-epoch probes (step rows, whose design row
``step_design_row`` reads straight off the registered component
structure) — is an ordinary linear least-squares problem in those
coefficients.  ``fit_calibration`` solves it and returns a
``Calibration`` overlay; at zero measurement noise the recovery is
exact up to float roundoff (property-tested in tests/test_calib.py).

Step rows have one nonlinearity: the *structure* (which spanning link
is the worst, which stage paces the pipeline) depends on the
coefficients.  The fitter linearizes at the current estimate and
iterates to a fixpoint — micro rows pin the estimate well enough that
the structure is right after the first pass in practice.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.calib.overlay import Calibration, LinkRate, _key
from repro.core.costmodel import (TECHNIQUE_SPECS, Workload,
                                  _act_byte_scale, _allreduce_time,
                                  _gather_time, _make_context,
                                  _state_byte_scale, technique_step_cost)
from repro.core.topology import Topology

#: design-matrix coefficient keys
#:   ("site", i)          -> theta_site = 1 / (tflops * 1e12)
#:   ("alpha", (i, j))    -> link latency seconds (canonical i <= j)
#:   ("beta", (i, j))     -> 1 / (effective_gbps * 1e9)
CoefKey = Tuple[str, object]
Row = Dict[CoefKey, float]


# --------------------------------------------------------------------- #
# measurement samples
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Sample:
    """One timed measurement.  ``kind`` selects which fields apply:

    ``"compute"``    — ``site``, ``flops`` (FLOPs executed by ONE GPU of
                       that site), ``time_s``.
    ``"collective"`` — ``link`` (canonical site pair; ``(i, i)`` is the
                       intra link), ``n_ranks``, ``volume_bytes``,
                       ``time_s`` of one ring all-reduce.
    ``"step"``       — ``technique``, ``sites``, ``wl`` and the
                       placement knobs; ``time_s`` of one optimizer
                       step (a pooled ``LiveProber`` ε-epoch time).
    """
    kind: str
    time_s: float
    site: int = 0
    flops: float = 0.0
    link: Optional[Tuple[int, int]] = None
    n_ranks: int = 0
    volume_bytes: float = 0.0
    technique: str = ""
    sites: Optional[Tuple[int, ...]] = None
    wl: Optional[Workload] = None
    stage_order: Optional[Tuple[int, ...]] = None
    stage_layers: Optional[Tuple[int, ...]] = None
    schedule: str = "gpipe"
    carrier_dtype: str = "fp32"
    wire_dtype: str = "fp32"


def compute_sample(site: int, flops: float, time_s: float) -> Sample:
    return Sample("compute", time_s, site=site, flops=flops)


def collective_sample(link: Tuple[int, int], n_ranks: int,
                      volume_bytes: float, time_s: float) -> Sample:
    return Sample("collective", time_s, link=_key(*link), n_ranks=n_ranks,
                  volume_bytes=volume_bytes)


def step_sample(technique: str, sites: Sequence[int], wl: Workload,
                time_s: float, **knobs) -> Sample:
    return Sample("step", time_s, technique=technique,
                  sites=tuple(sites), wl=wl, **knobs)


# --------------------------------------------------------------------- #
# coefficient <-> Calibration conversions
# --------------------------------------------------------------------- #

def theta_value(key: CoefKey, cal: Calibration, topo: Topology) -> float:
    """The coefficient's current value under a calibration."""
    kind, k = key
    if kind == "site":
        return 1.0 / (cal.gpu_tflops(topo, k) * 1e12)
    link = cal.link(topo, k[0], k[1])
    if kind == "alpha":
        return link.latency_s
    return 1.0 / (link.effective_gbps * 1e9)


def row_dot(row: Row, cal: Calibration, topo: Topology) -> float:
    """Predicted seconds of a design row at a calibration — equals
    ``technique_step_cost(..., calibration=cal).total_s`` up to float
    roundoff when the row was built at the same linearization point."""
    return sum(w * theta_value(k, cal, topo) for k, w in row.items())


# --------------------------------------------------------------------- #
# design rows
# --------------------------------------------------------------------- #

def _add(row: Row, key: CoefKey, w: float) -> None:
    row[key] = row.get(key, 0.0) + w


def _allreduce_row(row: Row, volume_bytes: float, n: int,
                   pair: Tuple[int, int], scale: float = 1.0) -> None:
    """``scale * _allreduce_time(volume, n, link(pair))`` as a row."""
    if n <= 1:
        return
    _add(row, ("alpha", pair), scale * 2 * (n - 1))
    _add(row, ("beta", pair), scale * 2 * (n - 1) / n * volume_bytes)


def _gather_row(row: Row, volume_bytes: float, n: int,
                pair: Tuple[int, int], scale: float = 1.0) -> None:
    """``scale * _gather_time(...)`` (all-gather / reduce-scatter)."""
    if n <= 1:
        return
    _add(row, ("alpha", pair), scale * (n - 1))
    _add(row, ("beta", pair), scale * (n - 1) / n * volume_bytes)


def _worst_pair(cal: Calibration, topo: Topology, sel: Sequence[int],
                volume_bytes: float, n: int, timer) -> Tuple[int, int]:
    """The spanning pair a collective is priced on: argmax of ``timer``
    over the calibrated spanning links (first max, matching ``max()``
    in ``_collective_time``); single site -> its intra pair."""
    if len(sel) <= 1:
        return (sel[0], sel[0])
    best_t, best_pair = None, None
    for i, j in itertools.combinations(topo.select(sel), 2):
        t = timer(volume_bytes, n, cal.link(topo, i, j))
        if best_t is None or t > best_t:
            best_t, best_pair = t, (i, j)
    return best_pair


def step_design_row(technique: str, wl: Workload, topo: Topology,
                    sites: Optional[Sequence[int]] = None, *,
                    stage_order: Optional[Sequence[int]] = None,
                    stage_balance: str = "even",
                    stage_layers: Optional[Sequence[int]] = None,
                    schedule: str = "gpipe",
                    carrier_dtype: str = "fp32",
                    wire_dtype: str = "fp32",
                    calibration: Optional[Calibration] = None) -> Row:
    """The step time of one (technique × placement) as a linear row over
    the calibration coefficients, linearized at ``calibration`` (the
    max/argmax structure — worst spanning link, pace-setting site or
    stage — is frozen at that point; everything else is exact).

    ``row_dot(row, calibration, topo)`` reproduces
    ``technique_step_cost(..., calibration=calibration).total_s`` up to
    float roundoff — the consistency property in tests/test_calib.py.
    """
    cal = Calibration.identity() if calibration is None else calibration
    spec = TECHNIQUE_SPECS[technique]
    ctx = _make_context(wl, topo, sites, stage_order=stage_order,
                        stage_balance=stage_balance,
                        stage_layers=stage_layers, schedule=schedule,
                        carrier_dtype=carrier_dtype,
                        wire_dtype=wire_dtype,
                        comm=spec.comm_precision, calibration=cal)
    sel, n = ctx.sel, ctx.n
    n_layers = wl.cfg.n_layers
    state_scale = _state_byte_scale(ctx)
    act_scale = _act_byte_scale(ctx)
    row: Row = {}

    if technique != "pipeshard":
        # flat-pool compute: the slowest site's rate paces the pool
        pace = min(sel, key=lambda i: cal.gpu_tflops(topo, i))
        _add(row, ("site", pace), ctx.flops / n)

    if technique == "data":
        vol = ctx.g_bytes * state_scale
        pair = _worst_pair(cal, topo, sel, vol, n, _allreduce_time)
        _allreduce_row(row, vol, n, pair)
    elif technique == "zero2":
        vol = ctx.g_bytes * state_scale
        pair = _worst_pair(cal, topo, sel, vol, n, _allreduce_time)
        _allreduce_row(row, vol, n, pair, scale=2.2)
    elif technique == "shard":
        vol = ctx.act_stream_bytes * act_scale
        pair = _worst_pair(cal, topo, sel, vol, n, _allreduce_time)
        _allreduce_row(row, vol, n, pair, scale=4 * n_layers)
    elif technique == "shard_zero":
        n_rep = len(sel)
        share = ctx.act_stream_bytes * act_scale / n_rep
        pace_i, pace_t = None, None
        for i in sel:
            k = len(topo.sites[i].gpus)
            t = 4 * n_layers * _allreduce_time(share, k,
                                               cal.link(topo, i, i))
            if pace_t is None or t > pace_t:
                pace_i, pace_t = i, t
        _allreduce_row(row, share, len(topo.sites[pace_i].gpus),
                       (pace_i, pace_i), scale=4 * n_layers)
        if n_rep > 1:
            vol = ctx.g_bytes * state_scale / ctx.tp
            pair = _worst_pair(cal, topo, sel, vol, n_rep,
                               _allreduce_time)
            _allreduce_row(row, vol, n_rep, pair, scale=2.2)
    elif technique == "fsdp":
        p_vol = ctx.p_bytes * state_scale / n_layers
        pair = _worst_pair(cal, topo, sel, p_vol, n, _gather_time)
        _gather_row(row, p_vol, n, pair, scale=2 * n_layers)
        g_vol = ctx.g_bytes * state_scale
        pair = _worst_pair(cal, topo, sel, g_vol, n, _gather_time)
        _gather_row(row, g_vol, n, pair)
    elif technique == "pipeshard":
        g = ctx.pipeline()
        # compute: the slowest (layer-weighted) stage paces every tick
        pace_s, pace_t = 0, None
        for s in range(g.n_stages):
            share = (ctx.flops / g.n_stages if g.split is None
                     else g.stage_l[s] / n_layers * ctx.flops)
            t = share / g.mesh_tflops[s]
            if pace_t is None or t > pace_t:
                pace_s, pace_t = s, t
        site = g.order[pace_s]
        k = len(topo.sites[site].gpus)
        share = (ctx.flops / g.n_stages if g.split is None
                 else g.stage_l[pace_s] / n_layers * ctx.flops)
        _add(row, ("site", site), share / k * (1 + g.bubble))
        # per-stage intra-op all-reduces: slowest stage paces
        act_vol = ctx.act_stream_bytes * act_scale
        pace_s, pace_t = 0, None
        for s in range(g.n_stages):
            i = g.order[s]
            li = (n_layers / g.n_stages if g.split is None
                  else g.stage_l[s])
            t = 4 * li * _allreduce_time(act_vol,
                                         len(topo.sites[i].gpus),
                                         cal.link(topo, i, i))
            if pace_t is None or t > pace_t:
                pace_s, pace_t = s, t
        i = g.order[pace_s]
        li = (n_layers / g.n_stages if g.split is None
              else g.stage_l[pace_s])
        _allreduce_row(row, act_vol, len(topo.sites[i].gpus), (i, i),
                       scale=4 * li)
        # per-boundary p2p carriers
        m = wl.microbatches
        carrier_vol = m * (ctx.act_stream_bytes * ctx.carrier_scale / m)
        v = g.virt if (g.kind == "interleaved" and g.n_stages > 1) else 1
        for a, b in zip(g.order[:-1], g.order[1:]):
            pair = _key(a, b)
            _add(row, ("alpha", pair), v * 2 * m)
            _add(row, ("beta", pair), v * 2 * carrier_vol)
        if v > 1:
            pair = _key(g.order[-1], g.order[0])
            _add(row, ("alpha", pair), (v - 1) * 2 * m)
            _add(row, ("beta", pair),
                 (v - 1) * 2 * ctx.act_stream_bytes * ctx.carrier_scale)
    else:
        raise ValueError(f"no design row for technique {technique!r}")
    return row


def _sample_row(s: Sample, topo: Topology, cal: Calibration
                ) -> Tuple[Row, float]:
    """(design row, measured seconds) of any sample kind."""
    if s.kind == "compute":
        return {("site", s.site): s.flops}, s.time_s
    if s.kind == "collective":
        row: Row = {}
        _allreduce_row(row, s.volume_bytes, s.n_ranks, _key(*s.link))
        return row, s.time_s
    if s.kind == "step":
        row = step_design_row(
            s.technique, s.wl, topo, s.sites, stage_order=s.stage_order,
            stage_layers=s.stage_layers, schedule=s.schedule,
            carrier_dtype=s.carrier_dtype, wire_dtype=s.wire_dtype,
            calibration=cal)
        return row, s.time_s
    raise ValueError(f"unknown sample kind {s.kind!r}")


# --------------------------------------------------------------------- #
# the fitter
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class FitResult:
    """The fitted overlay plus diagnostics.

    Attributes:
        calibration: the fitted overlay (unmeasured sites/links fall
            through to the base/analytic prices).
        residual: RMS relative residual (predicted/measured - 1) over
            all samples at the fitted calibration.
        n_samples: number of samples fitted.
        n_iterations: linearize-and-solve passes taken.
    """
    calibration: Calibration
    residual: float
    n_samples: int
    n_iterations: int


#: relative weight of the pull-to-prior rows that regularize
#: directions no measurement constrains (perturbs well-measured
#: coefficients by ~ the square of this — far below fit tolerances)
_PRIOR_WEIGHT = 1e-6


def _solve(rows: List[Tuple[Row, float]], keys: List[CoefKey],
           cal: Calibration, topo: Topology) -> Dict[CoefKey, float]:
    """One relative least-squares solve.  Each measurement row is scaled
    by 1 / time so multiplicative noise is homoskedastic, and each
    *column* by the coefficient's prior value — the raw thetas span
    ~1e-14 s/FLOP to ~1e-2 s, and without the normalization the design
    matrix is so ill-conditioned that even noiseless recovery loses
    half its digits.  A weak prior row per coefficient pulls unmeasured
    directions toward the current calibration."""
    idx = {k: c for c, k in enumerate(keys)}
    priors = [theta_value(k, cal, topo) for k in keys]
    scales = [p if p > 0.0 else 1.0 for p in priors]
    a = np.zeros((len(rows) + len(keys), len(keys)))
    b = np.zeros(len(rows) + len(keys))
    for r, (row, t) in enumerate(rows):
        for k, w in row.items():
            a[r, idx[k]] = w * scales[idx[k]] / t
        b[r] = 1.0
    for c in range(len(keys)):
        a[len(rows) + c, c] = _PRIOR_WEIGHT
        b[len(rows) + c] = _PRIOR_WEIGHT * priors[c] / scales[c]
    ratio, *_ = np.linalg.lstsq(a, b, rcond=None)
    out = {}
    for c, k in enumerate(keys):
        v = float(ratio[c]) * scales[c]
        # a non-positive rate/latency is unphysical — keep the prior
        if k[0] == "alpha":
            out[k] = v if v >= 0.0 else priors[c]
        else:
            out[k] = v if v > 1e-18 else priors[c]
    return out


def _to_calibration(theta: Mapping[CoefKey, float], base: Calibration,
                    topo: Topology, note: str) -> Calibration:
    sites = dict(base.site_tflops)
    links = dict(base.links)
    for (kind, k), v in theta.items():
        if kind == "site":
            sites[k] = 1.0 / (v * 1e12)
    pairs = {k for kind, k in theta if kind in ("alpha", "beta")}
    for k in sorted(pairs):
        fallback = base.link(topo, k[0], k[1])
        alpha = theta.get(("alpha", k), fallback.latency_s)
        if ("beta", k) in theta:
            gbps = 1.0 / (theta[("beta", k)] * 1e9)
        else:
            gbps = fallback.effective_gbps
        links[k] = LinkRate(alpha, gbps)
    return Calibration(sites, links, note)


def fit_calibration(topo: Topology, samples: Sequence[Sample], *,
                    base: Optional[Calibration] = None, max_iter: int = 5,
                    note: str = "fitted") -> FitResult:
    """Fit a ``Calibration`` overlay to a measurement set.

    Args:
        topo: the topology the measurements were taken on.
        samples: compute / collective / step ``Sample``s (see
            ``repro.calib.microbench`` for harnesses that produce them).
        base: starting overlay; unmeasured coefficients keep its values
            (default: the identity — analytic prices).
        max_iter: linearize-and-solve passes for step-row structure.
        note: provenance string stored on the result.

    Returns:
        A ``FitResult``; exact recovery at zero noise, noise-bounded
        otherwise (tests/test_calib.py pins both).
    """
    samples = list(samples)
    if not samples:
        raise ValueError("cannot fit an empty measurement set")
    base0 = base if base is not None else Calibration.identity()
    cal = base0
    has_steps = any(s.kind == "step" for s in samples)
    n_iter = 0
    for n_iter in range(1, (max_iter if has_steps else 1) + 1):
        rows = [_sample_row(s, topo, cal) for s in samples]
        keys = sorted({k for row, _ in rows for k in row},
                      key=lambda k: (k[0], str(k[1])))
        theta = _solve(rows, keys, cal, topo)
        new_cal = _to_calibration(theta, base0, topo, note)
        drift = max((abs(theta[k] / theta_value(k, cal, topo) - 1.0)
                     for k in keys), default=0.0)
        cal = new_cal
        if drift < 1e-12:
            break
    sq = 0.0
    for s in samples:
        row, t = _sample_row(s, topo, cal)
        if s.kind == "step":
            pred = technique_step_cost(
                s.technique, s.wl, topo, s.sites,
                stage_order=s.stage_order, stage_layers=s.stage_layers,
                schedule=s.schedule, carrier_dtype=s.carrier_dtype,
                wire_dtype=s.wire_dtype, calibration=cal).total_s
        else:
            pred = row_dot(row, cal, topo)
        sq += (pred / t - 1.0) ** 2
    return FitResult(cal, float(np.sqrt(sq / len(samples))),
                     len(samples), n_iter)
