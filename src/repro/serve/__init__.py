from repro.serve.engine import (
    ContinuousEngine, ContinuousStats, Engine, OutputQueue, Request,
    ServeStats, SlotScheduler, sample_tokens,
)

__all__ = [
    "ContinuousEngine", "ContinuousStats", "Engine", "OutputQueue",
    "Request", "ServeStats", "SlotScheduler", "sample_tokens",
]
