from repro.serve.engine import Engine, ServeStats, sample_tokens

__all__ = ["Engine", "ServeStats", "sample_tokens"]
