"""Batched serving engine: prefill + autoregressive decode over the plan's
sharded caches.  ``long context`` uses the sliding-window ring cache for
attention archs and the native constant-size state for SSM/hybrid."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plans import Plan
from repro.core.steps import build_prefill_step, build_serve_step
from repro.models.model import Model
from repro.models.registry import abstractify


def sample_tokens(logits, rng_key, *, temperature: float = 0.0,
                  top_k: int = 0):
    """Greedy (temperature 0) or top-k temperature sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng_key, logits).astype(jnp.int32)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: List[float] = field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        times = self.decode_s[1:] or self.decode_s
        return 1.0 / float(np.mean(times)) if times else 0.0


class Engine:
    """Holds compiled prefill/decode steps for one (model, plan, mesh)."""

    def __init__(self, model: Model, plan: Plan, mesh, *, batch_size: int,
                 max_len: int, window: int = 0, temperature: float = 0.0,
                 top_k: int = 0, kv_dtype: str = "fp32"):
        self.model, self.plan, self.mesh = model, plan, mesh
        self.window = window
        self.temperature, self.top_k = temperature, top_k
        self.batch_size, self.max_len = batch_size, max_len
        self.kv_dtype = kv_dtype
        with jax.set_mesh(mesh):
            cache = model.init_cache(batch_size, max_len, window=window,
                                     kv_dtype=kv_dtype)
            self._cache0 = cache
            self._serve_step = None
            self._cache_shapes = abstractify(cache)

    def _build(self, params, batch):
        with jax.set_mesh(self.mesh):
            p_shapes = abstractify(params)
            b_shapes = abstractify(batch)
            self._prefill, sh_p = build_prefill_step(
                self.model, self.plan, self.mesh, params_shapes=p_shapes,
                batch_shapes=b_shapes, cache_shapes=self._cache_shapes,
                batch_size=self.batch_size, window=self.window)
            self._serve_step, sh_s = build_serve_step(
                self.model, self.plan, self.mesh, params_shapes=p_shapes,
                cache_shapes=self._cache_shapes,
                batch_size=self.batch_size, window=self.window)
            self.shardings = {**sh_p, **sh_s}

    def generate(self, params, batch: Dict[str, Any], n_tokens: int, *,
                 seed: int = 0) -> Dict[str, Any]:
        """batch: prompt inputs (tokens [B, S] + modality extras).
        Returns generated token matrix [B, n_tokens] and timing stats."""
        if self._serve_step is None:
            self._build(params, batch)
        stats = ServeStats()
        key = jax.random.key(seed)
        with jax.set_mesh(self.mesh):
            cache = jax.device_put(self._cache0, self.shardings["cache"])
            t0 = time.perf_counter()
            logits, cache = self._prefill(params, batch, cache)
            logits.block_until_ready()
            stats.prefill_s = time.perf_counter() - t0
            key, k = jax.random.split(key)
            tok = sample_tokens(logits, k, temperature=self.temperature,
                                top_k=self.top_k)[:, None]
            out = [np.asarray(tok)]
            for _ in range(n_tokens - 1):
                t0 = time.perf_counter()
                logits, next_tok, cache = self._serve_step(params, cache, tok)
                if self.temperature > 0:
                    key, k = jax.random.split(key)
                    tok = sample_tokens(logits, k,
                                        temperature=self.temperature,
                                        top_k=self.top_k)[:, None]
                else:
                    tok = next_tok
                tok.block_until_ready()
                stats.decode_s.append(time.perf_counter() - t0)
                out.append(np.asarray(tok))
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}
