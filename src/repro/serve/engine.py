"""Serving engines over the plan's sharded caches.

Two engines share the compiled-step machinery (``core/steps.py``):

  * ``Engine`` — the fixed-batch prefill + decode loop: every request in
    a batch waits for the longest prompt and the longest generation.
  * ``ContinuousEngine`` — slot-based continuous batching
    (docs/serving.md): a persistent decode state of ``slots`` slots,
    bucketed prefill lengths (pad-to-bucket keeps prefill
    compile-stable), prefill-insert scattering each new request's
    KV/state into a free slot, per-slot eviction on EOS or its token
    budget with immediate backfill from the pending queue, and a
    detokenize/backpressure ``OutputQueue`` so slow consumers never
    stall the decode step.

``long context`` uses the sliding-window ring cache for attention archs
and the native constant-size state for SSM/hybrid.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plans import Plan
from repro.core.steps import (
    build_decode_slots_step, build_insert_step, build_prefill_step,
    build_serve_step,
)
from repro.models.model import Model
from repro.models.registry import abstractify


def sample_tokens(logits, rng_key, *, temperature: float = 0.0,
                  top_k: int = 0):
    """Greedy (temperature 0) or top-k temperature sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng_key, logits).astype(jnp.int32)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: List[float] = field(default_factory=list)
    n_slots: int = 1            # live batch rows: one step = n_slots tokens
    total_decode_s: float = 0.0  # whole-loop wall time (timing=False path)
    n_steps: int = 0

    @property
    def steps_per_s(self) -> float:
        """Decode steps per second (drops the first, warm-up, step when
        per-step timings exist; falls back to the loop wall clock)."""
        times = self.decode_s[1:] or self.decode_s
        if times:
            return 1.0 / float(np.mean(times))
        if self.total_decode_s > 0 and self.n_steps:
            return self.n_steps / self.total_decode_s
        return 0.0

    @property
    def tokens_per_s(self) -> float:
        """Aggregate generated tokens/s: one decode step emits one token
        *per live slot*, so this is ``steps_per_s * n_slots`` — not the
        bare step rate (that unit bug is pinned by
        tests/test_serving.py::test_tokens_per_s_units)."""
        return self.steps_per_s * self.n_slots


class Engine:
    """Holds compiled prefill/decode steps for one (model, plan, mesh)."""

    def __init__(self, model: Model, plan: Plan, mesh, *, batch_size: int,
                 max_len: int, window: int = 0, temperature: float = 0.0,
                 top_k: int = 0, kv_dtype: str = "fp32"):
        self.model, self.plan, self.mesh = model, plan, mesh
        self.window = window
        self.temperature, self.top_k = temperature, top_k
        self.batch_size, self.max_len = batch_size, max_len
        self.kv_dtype = kv_dtype
        with jax.set_mesh(mesh):
            cache = model.init_cache(batch_size, max_len, window=window,
                                     kv_dtype=kv_dtype)
            self._cache0 = cache
            self._serve_step = None
            self._cache_shapes = abstractify(cache)

    def _build(self, params, batch):
        with jax.set_mesh(self.mesh):
            p_shapes = abstractify(params)
            b_shapes = abstractify(batch)
            self._prefill, sh_p = build_prefill_step(
                self.model, self.plan, self.mesh, params_shapes=p_shapes,
                batch_shapes=b_shapes, cache_shapes=self._cache_shapes,
                batch_size=self.batch_size, window=self.window)
            self._serve_step, sh_s = build_serve_step(
                self.model, self.plan, self.mesh, params_shapes=p_shapes,
                cache_shapes=self._cache_shapes,
                batch_size=self.batch_size, window=self.window)
            self.shardings = {**sh_p, **sh_s}

    def generate(self, params, batch: Dict[str, Any], n_tokens: int, *,
                 seed: int = 0, timing: bool = True) -> Dict[str, Any]:
        """batch: prompt inputs (tokens [B, S] + modality extras).
        Returns generated token matrix [B, n_tokens] and timing stats.

        ``timing=False`` skips the per-step ``block_until_ready`` and the
        per-step host transfer, letting steady-state decode pipeline
        host->device dispatch; only the loop total is measured.  The
        benchmark path keeps ``timing=True`` for per-step latencies.
        """
        if self._serve_step is None:
            self._build(params, batch)
        stats = ServeStats(n_slots=self.batch_size)
        key = jax.random.key(seed)
        with jax.set_mesh(self.mesh):
            cache = jax.device_put(self._cache0, self.shardings["cache"])
            t0 = time.perf_counter()
            logits, cache = self._prefill(params, batch, cache)
            logits.block_until_ready()
            stats.prefill_s = time.perf_counter() - t0
            key, k = jax.random.split(key)
            tok = sample_tokens(logits, k, temperature=self.temperature,
                                top_k=self.top_k)[:, None]
            out: List[Any] = [np.asarray(tok) if timing else tok]
            t_loop = time.perf_counter()
            for _ in range(n_tokens - 1):
                if timing:
                    t0 = time.perf_counter()
                logits, next_tok, cache = self._serve_step(params, cache, tok)
                if self.temperature > 0:
                    key, k = jax.random.split(key)
                    tok = sample_tokens(logits, k,
                                        temperature=self.temperature,
                                        top_k=self.top_k)[:, None]
                else:
                    tok = next_tok
                if timing:
                    tok.block_until_ready()
                    stats.decode_s.append(time.perf_counter() - t0)
                    out.append(np.asarray(tok))
                else:
                    out.append(tok)
            if not timing and n_tokens > 1:
                out[-1].block_until_ready()
            stats.total_decode_s = time.perf_counter() - t_loop
            stats.n_steps = n_tokens - 1
            tokens = np.concatenate([np.asarray(t) for t in out], axis=1)
        return {"tokens": tokens, "stats": stats}


# --------------------------------------------------------------------- #
# continuous batching (docs/serving.md)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Request:
    """One serving request: a prompt and its generation budget."""
    uid: int
    prompt: Any                       # int32 token ids [prompt_len]
    max_new: int = 0                  # 0 => the run()-level default


class SlotScheduler:
    """Host-side slot bookkeeping for continuous batching.

    Pure python, no jax — the property tests (tests/test_serving.py)
    drive it with random admit/generate/evict traces.  Invariants:

      * a slot is free or live, never both, and
        ``len(free) + occupancy == n_slots`` (occupancy conservation);
      * ``admit`` only hands out a free slot, so backfill can never
        overwrite a live request;
      * ``record_token``/``evict`` reject free slots, so nothing reads a
        slot after its eviction until a new admit recycles it.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free = deque(range(n_slots))
        self._uid: Dict[int, int] = {}      # slot -> request uid
        self._count: Dict[int, int] = {}    # slot -> tokens generated
        self._limit: Dict[int, int] = {}    # slot -> max_new budget

    @property
    def occupancy(self) -> int:
        return len(self._uid)

    def has_free(self) -> bool:
        return bool(self._free)

    def live_slots(self) -> List[int]:
        return sorted(self._uid)

    def uid_of(self, slot: int) -> int:
        return self._uid[slot]

    def admit(self, uid: int, max_new: int) -> int:
        """Claim a free slot for request ``uid``; returns the slot."""
        if not self._free:
            raise RuntimeError("admit with no free slot")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        slot = self._free.popleft()
        self._uid[slot] = uid
        self._count[slot] = 0
        self._limit[slot] = max_new
        return slot

    def record_token(self, slot: int) -> bool:
        """Count one generated token; True when the slot hit its budget."""
        if slot not in self._uid:
            raise KeyError(f"slot {slot} is not live")
        self._count[slot] += 1
        return self._count[slot] >= self._limit[slot]

    def evict(self, slot: int) -> int:
        """Release a live slot (EOS or budget); returns its uid."""
        if slot not in self._uid:
            raise KeyError(f"slot {slot} is not live")
        uid = self._uid.pop(slot)
        del self._count[slot], self._limit[slot]
        self._free.append(slot)
        return uid

    def check(self) -> None:
        """Audit the invariants (used by the property tests)."""
        free, live = set(self._free), set(self._uid)
        if free & live:
            raise AssertionError(f"slots both free and live: {free & live}")
        if len(self._free) + len(self._uid) != self.n_slots:
            raise AssertionError(
                f"occupancy leak: {len(self._free)} free + "
                f"{len(self._uid)} live != {self.n_slots}")


class OutputQueue:
    """Decode-side handoff to (possibly slow) consumers.

    The decode loop only ever appends raw token-id rows — O(1), no
    detokenization, no blocking — so a slow consumer can never stall a
    decode step.  The expensive part (detokenize) runs on the consumer
    side, inside ``drain``.
    """

    def __init__(self, detokenize: Optional[Callable[[Any], Any]] = None):
        self._q: deque = deque()
        self._detok = detokenize

    def __len__(self) -> int:
        return len(self._q)

    def put(self, uid: int, token_ids) -> None:
        self._q.append((uid, token_ids))

    def drain(self) -> List:
        """Pop every finished request as ``(uid, output)`` — detokenized
        here, on the consumer's clock, when a detokenizer was given."""
        out = []
        while self._q:
            uid, ids = self._q.popleft()
            out.append((uid, self._detok(ids) if self._detok else ids))
        return out


@dataclass
class ContinuousStats:
    n_slots: int = 1
    prefill_s: List[float] = field(default_factory=list)
    decode_s: List[float] = field(default_factory=list)   # timing=True
    ttft_s: Dict[int, float] = field(default_factory=dict)
    occupancy: List[int] = field(default_factory=list)    # per decode step
    n_tokens: int = 0            # generated tokens across all requests
    total_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        """Goodput: generated tokens per wall-clock second of the run."""
        return self.n_tokens / self.total_s if self.total_s > 0 else 0.0

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0


DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


class ContinuousEngine:
    """Slot-based continuous batching over a persistent decode state.

    ``slots`` requests decode in lock-step; each finished request's slot
    is immediately backfilled from the pending queue via a bucketed
    batch-1 prefill + ``build_insert_step`` scatter.  Greedy decoding
    only — the whole point is the bit-exactness contract: every request's
    tokens are bit-identical to what the fixed-batch ``Engine`` produces
    for the same prompt (pinned by the serving gate, BENCH_10.json).

    Prompt lengths are padded up to a bucket so prefill compiles once per
    bucket, not once per length; the causal mask keeps the pad tail
    invisible and the insert step rewinds the slot's index to the true
    prompt length.  SSM/hybrid prefills run the full-sequence recurrence
    — pad tokens would flow into the state — so those families compile
    per distinct prompt length instead (``exact_prefill``), a deliberate
    tradeoff documented in docs/serving.md.
    """

    def __init__(self, model: Model, plan: Plan, mesh, *, slots: int,
                 max_len: int, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 kv_dtype: str = "fp32", eos_id: int = -1, pad_id: int = 0,
                 detokenize: Optional[Callable[[Any], Any]] = None):
        if model.cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                f"continuous batching serves token-only prompts; family "
                f"{model.cfg.family!r} needs per-request modality extras")
        self.model, self.plan, self.mesh = model, plan, mesh
        self.slots, self.max_len = slots, max_len
        self.kv_dtype = kv_dtype
        self.eos_id, self.pad_id = eos_id, pad_id
        # SSM recurrences integrate every input token into the state, so
        # pad-to-bucket prefill is wrong for them: exact lengths instead.
        self.exact_prefill = model.cfg.family in ("ssm", "hybrid")
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))
        self.output_queue = OutputQueue(detokenize)
        with jax.set_mesh(mesh):
            self._slot_cache0 = model.init_slot_cache(
                slots, max_len, kv_dtype=kv_dtype)
            self._src_cache0 = model.init_cache(1, max_len,
                                                kv_dtype=kv_dtype)
        self._decode = None
        self._prefill_fns: Dict[int, Any] = {}

    # ------------------------------------------------------------- #
    def _bucket_of(self, n: int) -> int:
        if n > self.max_len:
            raise ValueError(f"prompt of {n} tokens exceeds max_len "
                             f"{self.max_len}")
        if self.exact_prefill:
            return n
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_len      # longest prompts pad to the full cache

    def _build(self, params):
        with jax.set_mesh(self.mesh):
            p_shapes = abstractify(params)
            slot_shapes = abstractify(self._slot_cache0)
            src_shapes = abstractify(self._src_cache0)
            self._decode, sh = build_decode_slots_step(
                self.model, self.plan, self.mesh, params_shapes=p_shapes,
                cache_shapes=slot_shapes, batch_size=self.slots,
                pad_id=self.pad_id)
            self._insert, sh_i = build_insert_step(
                self.model, self.plan, self.mesh, cache_shapes=slot_shapes,
                src_cache_shapes=src_shapes, batch_size=self.slots)
            self.shardings = {**sh, "src": sh_i["src"]}
            self._p_shapes, self._src_shapes = p_shapes, src_shapes

    def _prefill_for(self, bucket: int):
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            with jax.set_mesh(self.mesh):
                fn, _ = build_prefill_step(
                    self.model, self.plan, self.mesh,
                    params_shapes=self._p_shapes,
                    batch_shapes={"tokens": jax.ShapeDtypeStruct(
                        (1, bucket), jnp.int32)},
                    cache_shapes=self._src_shapes, batch_size=1,
                    gather_last=True)
            self._prefill_fns[bucket] = fn
        return fn

    def _prefill_one(self, params, prompt):
        """Bucketed batch-1 prefill; returns (first token, cache, len)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        L = int(prompt.shape[0])
        bucket = self._bucket_of(L)
        padded = np.full((1, bucket), self.pad_id, np.int32)
        padded[0, :L] = prompt
        logits, pcache = self._prefill_for(bucket)(
            params, {"tokens": padded}, self._src_dev,
            jnp.asarray(L - 1, jnp.int32))
        tok0 = int(jnp.argmax(logits, axis=-1)[0])
        return tok0, pcache, L

    # ------------------------------------------------------------- #
    def run(self, params, requests: Sequence[Request], *,
            max_new: int = 32, timing: bool = False) -> Dict[str, Any]:
        """Serve ``requests`` to completion; returns per-request outputs
        (uid -> generated token ids, EOS included when hit) and stats."""
        reqs = [r if isinstance(r, Request) else Request(i, r)
                for i, r in enumerate(requests)]
        if self._decode is None:
            self._build(params)
        sched = SlotScheduler(self.slots)
        stats = ContinuousStats(n_slots=self.slots)
        pending = deque(reqs)
        bufs: Dict[int, List[int]] = {}
        slot_tok = np.full((self.slots, 1), self.pad_id, np.int32)
        live = np.zeros((self.slots,), bool)
        t_start = time.perf_counter()

        with jax.set_mesh(self.mesh):
            cache = jax.device_put(self._slot_cache0,
                                   self.shardings["cache"])
            self._src_dev = jax.device_put(self._src_cache0,
                                           self.shardings["src"])

            def finish(slot: int) -> None:
                uid = sched.evict(slot)
                live[slot] = False
                slot_tok[slot, 0] = self.pad_id
                self.output_queue.put(
                    uid, np.asarray(bufs.pop(slot), np.int32))

            while pending or sched.occupancy:
                # backfill every free slot from the pending queue
                while pending and sched.has_free():
                    req = pending.popleft()
                    budget = req.max_new or max_new
                    t0 = time.perf_counter()
                    tok0, pcache, L = self._prefill_one(params, req.prompt)
                    now = time.perf_counter()
                    stats.prefill_s.append(now - t0)
                    stats.ttft_s[req.uid] = now - t_start
                    slot = sched.admit(req.uid, budget)
                    cache = self._insert(cache, pcache,
                                         jnp.asarray(slot, jnp.int32),
                                         jnp.asarray(L, jnp.int32))
                    bufs[slot] = [tok0]
                    live[slot] = True
                    slot_tok[slot, 0] = tok0
                    stats.n_tokens += 1
                    if sched.record_token(slot) or tok0 == self.eos_id:
                        finish(slot)
                if not sched.occupancy:
                    continue     # everything admitted finished at prefill
                # one decode step across all live slots
                if timing:
                    t0 = time.perf_counter()
                logits, next_tok, cache = self._decode(
                    params, cache, jnp.asarray(slot_tok),
                    jnp.asarray(live))
                nt = np.asarray(next_tok)    # host sync: scheduler input
                if timing:
                    stats.decode_s.append(time.perf_counter() - t0)
                stats.occupancy.append(sched.occupancy)
                for slot in sched.live_slots():
                    t = int(nt[slot, 0])
                    bufs[slot].append(t)
                    slot_tok[slot, 0] = t
                    stats.n_tokens += 1
                    if sched.record_token(slot) or t == self.eos_id:
                        finish(slot)
        stats.total_s = time.perf_counter() - t_start
        outputs = dict(self.output_queue.drain())
        return {"outputs": outputs, "stats": stats}
