"""Topology-aware decode-replica placement (docs/serving.md §4).

Given per-site request arrival rates over an N-site topology
(``core/topology.py``), choose how many continuous-batching decode
replicas to stand up and on which site subsets: the serving sites are
partitioned into *replica groups*, each group hosts one replica whose
parallelism plan, site subset and wire dtype come from ``PlanSearch``
(with the PR-9 ``Calibration`` overlay) restricted to the group's
sub-topology.  A site's traffic is served by its own group's replica;
prompts ship to the replica instances' ingress sites over the
topology's routed links, priced with the same α/β model the training
search uses — which
is exactly what makes a high-latency site earn its own local replica
(every request would otherwise pay the WAN RTT) while a LAN pair pools
capacity in one shared replica (halving its queue wait).

A group *tiles* its winning plan: if the restricted search picks a
k-site plan on a g-site group, the group runs ``g // k`` instances of
it behind one shared queue — that shared queue is the pooling win (at
equal utilization, doubling the instance pool halves the mean wait),
and it is why a LAN pair shares a group while joining a *far* site to
the pool instead costs every one of its requests the expected WAN
prompt-ship to whichever instance frees up first.

Approximations, stated once: a decode step is priced as the forward
share of the searched *train* step (``DECODE_FLOP_SHARE`` — 2 of the
6·P·T flops; the collective pattern is the same, the backward half and
the optimizer are not run); queue wait is M/D/1 on the pooled capacity
(Poisson arrivals, deterministic service): ``rho / (2 mu (1 - rho))``;
and dispatch across a group's instances is capacity-uniform (the shared
queue is work-conserving), so a request's prompt-ship cost is the mean
over instance primaries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.costmodel import Workload
from repro.core.search import PlanSearch
from repro.core.topology import Topology

#: prompts ship as int32 token ids
PROMPT_BYTES_PER_TOKEN = 4.0
#: forward-only share of the 6·P·T train-step flops (2 fwd of fwd+2·bwd)
DECODE_FLOP_SHARE = 1.0 / 3.0
#: utilization ceiling — past this the M/D/1 wait is effectively a queue
#: blow-up and the group is declared infeasible
RHO_MAX = 0.95


@dataclass(frozen=True)
class ReplicaSpec:
    """One decode replica: the sites it serves and how it runs.

    Attributes:
        serves: site indices whose traffic routes to this replica.
        compute_sites: the subset actually running the plan (the
            restricted search's winner, mapped back to topology indices).
        plan_key: the winning ``Candidate.key`` (technique@sites~wire).
        n_instances: plan instances tiled over the group's sites, all
            behind one shared queue (``len(serves) // len(compute_sites)``,
            at least 1; extra instances are priced at the winner's rate —
            a homogeneity approximation the docstring above owns up to).
        primaries: one ingress site per instance (first site of each
            tile, in sorted group order); prompts ship to the mean of
            these under capacity-uniform dispatch.
        decode_step_s: modelled seconds per decode step (all slots).
        prefill_s: modelled seconds to prefill one prompt.
        rho: utilization λ/μ of the *pooled* capacity under the group's
            summed rates.
        wait_s: shared-queue M/D/1 mean wait at that utilization.
    """
    serves: Tuple[int, ...]
    compute_sites: Tuple[int, ...]
    plan_key: str
    n_instances: int
    primaries: Tuple[int, ...]
    decode_step_s: float
    prefill_s: float
    rho: float
    wait_s: float


@dataclass(frozen=True)
class PlacementPlan:
    """A full serving placement: one replica per group + its objective.

    ``mean_latency_s`` is the rate-weighted mean per-request latency
    (prompt ship + queue wait + prefill + ``gen_len`` decode steps) —
    the quantity ``place_replicas`` minimizes.
    """
    replicas: Tuple[ReplicaSpec, ...]
    mean_latency_s: float
    site_latency_s: Tuple[float, ...]     # per-site mean request latency

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def groups(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(r.serves for r in self.replicas)


def partitions(items: Sequence[int]) -> Iterator[List[List[int]]]:
    """Every set partition of ``items`` (Bell(n) of them — fine for the
    site counts topologies actually have)."""
    items = list(items)
    if not items:
        yield []
        return
    head, rest = items[0], items[1:]
    for part in partitions(rest):
        for i in range(len(part)):
            yield part[:i] + [[head] + part[i]] + part[i + 1:]
        yield [[head]] + part


def _price_group(search: PlanSearch, topo: Topology, group: Sequence[int],
                 rates_rps: Sequence[float], *, slots: int,
                 prompt_len: int, gen_len: int
                 ) -> Optional[Tuple[ReplicaSpec, Dict[int, float]]]:
    """Price one replica group: among every feasible plan candidate on
    the group's sub-topology, pick the one minimizing the group's
    rate-weighted mean request latency — NOT the training-throughput
    winner.  The two disagree exactly when tiling wins: a 2-site
    pipeline out-trains two tiled single-site instances, but the tiled
    pool has more serving capacity.  Returns ``(spec, site_latency_s)``
    or None when no plan fits or every one saturates.
    """
    sub_search, kept = search.restricted(group)
    if len(sub_search.topology.components()) > 1:
        return None     # cutting the graph disconnected this group
    wl = search.wl
    ordered = tuple(sorted(group))
    lam_rps = sum(rates_rps[s] for s in ordered)
    best: Optional[Tuple[ReplicaSpec, Dict[int, float]]] = None
    best_obj = float("inf")
    for scored in sub_search.search():
        if not scored.feasible:
            break       # sorted best-first; the tail is all infeasible
        # the searched rate covers the whole train step; decode runs
        # its forward share with the same collective pattern
        step_time_s = wl.flops_per_step / (scored.tflops * 1e12)
        decode_step_s = DECODE_FLOP_SHARE * step_time_s
        prefill_flops = wl.flops_per_step * prompt_len / wl.tokens_per_step
        prefill_s = DECODE_FLOP_SHARE * prefill_flops / (scored.tflops * 1e12)
        # a request holds one slot for prefill plus gen_len decode steps
        service_s = prefill_s + gen_len * decode_step_s
        compute_sites = tuple(kept[i] for i in scored.candidate.sites)
        # tile the k-site plan across the g-site group: g // k instances
        # share one queue; leftover sites (g % k) idle
        k = len(compute_sites)
        n_instances = max(1, len(ordered) // k)
        primaries = tuple(ordered[j * k] for j in range(n_instances))
        capacity_rps = n_instances * slots / service_s
        rho = lam_rps / capacity_rps if capacity_rps > 0 else float("inf")
        if rho >= RHO_MAX:
            continue
        wait_s = rho / (2.0 * capacity_rps * (1.0 - rho))   # M/D/1
        gen_s = gen_len * decode_step_s
        site_latency_s: Dict[int, float] = {}
        obj = 0.0
        for s in ordered:
            # capacity-uniform dispatch: expected ship = mean over the
            # instances' ingress sites
            ship_s = sum(_ship_s(topo, s, p, prompt_len)
                         for p in primaries) / n_instances
            site_latency_s[s] = ship_s + wait_s + prefill_s + gen_s
            obj += rates_rps[s] * site_latency_s[s]
        if obj < best_obj:
            best_obj = obj
            spec = ReplicaSpec(ordered, compute_sites,
                               scored.candidate.key, n_instances,
                               primaries, decode_step_s, prefill_s,
                               rho, wait_s)
            best = (spec, site_latency_s)
    return best


def _ship_s(topo: Topology, src: int, dst: int, prompt_len: int) -> float:
    """Prompt-shipping seconds from the request's site to the replica's
    primary site over the (direct or routed) α/β link."""
    if src == dst:
        return 0.0
    link = topo.link(src, dst)
    return link.latency_s + \
        PROMPT_BYTES_PER_TOKEN * prompt_len / (link.effective_gbps * 1e9)


def evaluate_partition(search: PlanSearch, rates_rps: Sequence[float],
                       groups: Sequence[Sequence[int]], *, slots: int,
                       prompt_len: int, gen_len: int
                       ) -> Optional[PlacementPlan]:
    """Price one candidate partition; None when any group is infeasible."""
    topo = search.topology
    replicas: List[ReplicaSpec] = []
    site_latency_s = [0.0] * topo.n_sites
    total = 0.0
    total_rate = 0.0
    for group in groups:
        priced = _price_group(search, topo, group, rates_rps, slots=slots,
                              prompt_len=prompt_len, gen_len=gen_len)
        if priced is None:
            return None
        spec, group_latency_s = priced
        replicas.append(spec)
        for s, latency_s in group_latency_s.items():
            site_latency_s[s] = latency_s
            total = total + rates_rps[s] * latency_s
            total_rate += rates_rps[s]
    if total_rate <= 0:
        return None
    mean_latency_s = total / total_rate
    return PlacementPlan(tuple(replicas), mean_latency_s,
                         tuple(site_latency_s))


def place_replicas(search: PlanSearch, rates_rps: Sequence[float], *,
                   slots: int = 8, prompt_len: int = 512,
                   gen_len: int = 64) -> Optional[PlacementPlan]:
    """The placement pass: minimize rate-weighted mean request latency
    over every partition of the topology's sites into replica groups.

    Args:
        search: a ``PlanSearch`` over the serving topology — its
            workload should be the decode-shaped one from
            ``decode_workload``; its ``calibration`` / ``wire_dtypes`` /
            ``techniques`` knobs all apply to every replica's plan.
        rates_rps: per-site request arrival rates (requests/second).
        slots: continuous-batching slots per replica.
        prompt_len: representative prompt length (tokens).
        gen_len: representative generation length (tokens).

    Returns:
        The best ``PlacementPlan``, or None when no partition is
        feasible (every split saturates or OOMs).
    """
    if len(rates_rps) != search.topology.n_sites:
        raise ValueError(
            f"{len(rates_rps)} rates for "
            f"{search.topology.n_sites} sites")
    best: Optional[PlacementPlan] = None
    for groups in partitions(range(search.topology.n_sites)):
        plan = evaluate_partition(search, rates_rps, groups, slots=slots,
                                  prompt_len=prompt_len, gen_len=gen_len)
        if plan is None:
            continue
        if best is None or plan.mean_latency_s < best.mean_latency_s:
            best = plan
    return best


def decode_workload(cfg, *, slots: int = 8) -> Workload:
    """The decode-step workload shape: one token per step across
    ``slots`` live slots (seq_len 1, no microbatching)."""
    return Workload(cfg, seq_len=1, global_batch=slots, steps_per_epoch=1,
                    epochs=1, microbatches=1)
