"""Byte-fallback tokenizer with a trainable word vocabulary.

The paper pretrains on a Wikipedia dump (ace subset).  We implement a
self-contained tokenizer in the same spirit as GPT-2's byte-level BPE but
simplified to frequency-ranked whole words + byte fallback, so the data
pipeline has zero external dependencies and is exactly reproducible.
"""
from __future__ import annotations

import json
import re
from collections import Counter
from typing import Dict, Iterable, List, Optional

_WORD_RE = re.compile(r" ?[A-Za-z]+| ?[0-9]+|[^A-Za-z0-9]")

N_SPECIAL = 4
PAD, BOS, EOS, UNK = 0, 1, 2, 3
SPECIAL_TOKENS = {"<pad>": PAD, "<bos>": BOS, "<eos>": EOS, "<unk>": UNK}
N_BYTES = 256


class Tokenizer:
    """ids = [specials][256 raw bytes][learned words...]."""

    def __init__(self, vocab: Optional[List[str]] = None):
        self.words: List[str] = vocab or []
        self.word_to_id: Dict[str, int] = {
            w: N_SPECIAL + N_BYTES + i for i, w in enumerate(self.words)}

    # ------------------------------------------------------------- #
    @property
    def vocab_size(self) -> int:
        return N_SPECIAL + N_BYTES + len(self.words)

    @classmethod
    def train(cls, texts: Iterable[str], vocab_size: int) -> "Tokenizer":
        budget = max(vocab_size - N_SPECIAL - N_BYTES, 0)
        counts: Counter = Counter()
        for t in texts:
            counts.update(_WORD_RE.findall(t))
        words = [w for w, c in counts.most_common(budget) if c > 1]
        return cls(words)

    # ------------------------------------------------------------- #
    def encode(self, text: str, *, bos: bool = True,
               eos: bool = True) -> List[int]:
        ids: List[int] = [BOS] if bos else []
        for piece in _WORD_RE.findall(text):
            wid = self.word_to_id.get(piece)
            if wid is not None:
                ids.append(wid)
            else:
                ids.extend(N_SPECIAL + b for b in piece.encode("utf-8"))
        if eos:
            ids.append(EOS)
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out: List[str] = []
        byte_run: List[int] = []

        def flush():
            if byte_run:
                out.append(bytes(byte_run).decode("utf-8", errors="replace"))
                byte_run.clear()

        for i in ids:
            if N_SPECIAL <= i < N_SPECIAL + N_BYTES:
                byte_run.append(i - N_SPECIAL)
            elif i >= N_SPECIAL + N_BYTES:
                flush()
                out.append(self.words[i - N_SPECIAL - N_BYTES])
            else:
                flush()
        flush()
        return "".join(out)

    # ------------------------------------------------------------- #
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"words": self.words}, f)

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            return cls(json.load(f)["words"])
