"""Deterministic, shardable training-data pipeline.

Documents -> token stream (BOS/EOS framed) -> packed fixed-length examples
-> epoch-shuffled global batches -> per-host shard.  Everything is a pure
function of (corpus, seed, step), so any data-parallel worker can
reconstruct its shard without coordination — the property the paper's
multi-VM Ray setup gets from a shared filesystem.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.data.tokenizer import EOS, Tokenizer


@dataclass
class PackedDataset:
    """Token matrix [n_examples, seq_len + 1]; +1 gives the shifted labels."""
    examples: np.ndarray
    seq_len: int

    def __len__(self) -> int:
        return len(self.examples)


def pack_documents(token_docs: List[List[int]], seq_len: int
                   ) -> PackedDataset:
    """Concatenate framed documents and slice into seq_len+1 windows."""
    stream: List[int] = []
    for doc in token_docs:
        stream.extend(doc)
    n = len(stream) // (seq_len + 1)
    if n == 0:
        raise ValueError(
            f"corpus too small: {len(stream)} tokens < seq_len+1={seq_len + 1}")
    arr = np.asarray(stream[: n * (seq_len + 1)],
                     dtype=np.int32).reshape(n, seq_len + 1)
    return PackedDataset(arr, seq_len)


def build_dataset(texts, tokenizer: Tokenizer, seq_len: int) -> PackedDataset:
    docs = [tokenizer.encode(t) for t in texts]
    return PackedDataset(
        pack_documents(docs, seq_len).examples, seq_len)


class Loader:
    """Deterministic epoch-shuffled batches, shardable by (shard, n_shards)."""

    def __init__(self, ds: PackedDataset, global_batch: int, *, seed: int = 0,
                 shard: int = 0, n_shards: int = 1, drop_remainder: bool = True):
        if global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.ds = ds
        self.global_batch = global_batch
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards
        self.per_shard = global_batch // n_shards
        self.batches_per_epoch = len(ds) // global_batch
        if self.batches_per_epoch == 0:
            raise ValueError(
                f"dataset has {len(ds)} examples < global_batch={global_batch}")

    def epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(len(self.ds))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global-step indexed batch (this shard's slice)."""
        epoch = step // self.batches_per_epoch
        k = step % self.batches_per_epoch
        order = self.epoch_order(epoch)
        sel = order[k * self.global_batch: (k + 1) * self.global_batch]
        sel = sel[self.shard * self.per_shard:
                  (self.shard + 1) * self.per_shard]
        window = self.ds.examples[sel]
        return {"tokens": window[:, :-1],
                "labels": np.where(window[:, 1:] == 0, -1,
                                   window[:, 1:]).astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
