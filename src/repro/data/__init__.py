from repro.data.corpus import load_text_dir, synthetic_wikipedia
from repro.data.pipeline import Loader, PackedDataset, build_dataset, pack_documents
from repro.data.tokenizer import Tokenizer

__all__ = ["Loader", "PackedDataset", "Tokenizer", "build_dataset",
           "load_text_dir", "pack_documents", "synthetic_wikipedia"]
