"""Corpus sources.

``synthetic_wikipedia`` generates a deterministic wikipedia-like corpus
(Zipfian vocabulary, sentence/paragraph structure) so end-to-end pretraining
runs are fully reproducible offline — standing in for the paper's
HuggingFace ``wikimedia/wikipedia 20231101.ace`` dump.  ``load_text_dir``
reads real text files when the user supplies a dataset.
"""
from __future__ import annotations

import os
from typing import Iterator, List

import numpy as np

_CONSONANTS = "bcdfghjklmnprstvwz"
_VOWELS = "aeiou"


def _word(rng: np.random.Generator) -> str:
    n = int(rng.integers(1, 4))
    return "".join(
        _CONSONANTS[rng.integers(len(_CONSONANTS))]
        + _VOWELS[rng.integers(len(_VOWELS))]
        for _ in range(n))


def make_vocabulary(rng: np.random.Generator, size: int = 4000) -> List[str]:
    seen, out = set(), []
    while len(out) < size:
        w = _word(rng)
        if w not in seen:
            seen.add(w)
            out.append(w)
    return out


def synthetic_wikipedia(n_docs: int, *, seed: int = 0,
                        mean_doc_words: int = 180) -> Iterator[str]:
    """Deterministic Zipf-distributed documents with article structure."""
    rng = np.random.default_rng(seed)
    vocab = make_vocabulary(rng)
    ranks = np.arange(1, len(vocab) + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    for _ in range(n_docs):
        n_words = max(20, int(rng.poisson(mean_doc_words)))
        words = rng.choice(len(vocab), size=n_words, p=probs)
        title = " ".join(vocab[w].capitalize() for w in words[:3])
        body_words = [vocab[w] for w in words]
        sents, i = [], 0
        while i < len(body_words):
            n = int(rng.integers(5, 15))
            sent = " ".join(body_words[i:i + n])
            sents.append(sent.capitalize() + ".")
            i += n
        yield f"= {title} =\n" + " ".join(sents)


def load_text_dir(path: str) -> Iterator[str]:
    for name in sorted(os.listdir(path)):
        if name.endswith(".txt"):
            with open(os.path.join(path, name), errors="replace") as f:
                yield f.read()
