"""Schedule parity (ISSUE 4 tentpole): 1F1B and interleaved tick orders
must match the GPipe path and the unsharded reference bit-for-bit —
schedules reorder work; they must not change math.

Runs ``repro.launch.pipeline_check --schedules ...`` in subprocesses
(the forced host device count locks at first jax init).  The
(stage, 1, 1) meshes it builds are fully manual, so these tests run
UN-gated even on jax 0.4.x, where the partial-auto pipeshard tests must
skip (see test_plans.py and repro.compat.NATIVE_SHARD_MAP).

The in-process tests at the top check the static slot tables the
scheduled runner executes (core/pipeline.schedule_tables): every work
item runs exactly once, never before its producer's ppermute delivered,
and the tick counts match the formulas documented in docs/schedules.md.
"""
import json
import subprocess
import sys

import pytest

from repro.analysis import schedlint
from repro.core.costmodel import balanced_stage_layers
from repro.core.pipeline import schedule_tables, stage_gather_index


def _run_check(env, gpus, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.pipeline_check",
           "--gpus", gpus, *extra]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    return json.loads(line)


# ------------------------------------------------------------------ #
# static slot tables
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("sched,v", [("gpipe", 1), ("1f1b", 1),
                                     ("interleaved", 2),
                                     ("interleaved3", 3)])
@pytest.mark.parametrize("S,m", [(1, 1), (2, 4), (3, 2), (3, 4), (4, 7)])
def test_schedule_tables_are_valid_schedules(sched, v, S, m):
    """Each (chunk, microbatch) work item runs exactly once per stage,
    and only after its producer chunk ran on the ring predecessor at an
    earlier tick (ppermute delivers at tick+1)."""
    t = schedule_tables(sched, S, m)
    active, chunk, mb = t["active"], t["chunk"], t["mb"]
    done = {}
    T = active.shape[1]
    for tick in range(T):
        for s in range(S):
            if not active[s, tick]:
                continue
            c = int(chunk[s, tick]) * S + s
            key = (c, int(mb[s, tick]))
            assert key not in done, f"{key} ran twice"
            done[key] = tick
            if c > 0:
                prod = done.get((c - 1, key[1]))
                assert prod is not None and prod < tick, \
                    f"{key} ran before its input arrived"
    assert len(done) == S * v * m              # every item ran
    # the last chunk of every microbatch is banked on the last stage
    for i in range(m):
        assert (S * v - 1, i) in done


def test_schedule_tick_counts_match_the_docs():
    """docs/schedules.md formulas: GPipe m+S-1; 1F1B 2m+S-2 (forward
    slots interleave with the backward slots AD replays)."""
    assert schedule_tables("gpipe", 3, 4)["active"].shape[1] == 6
    assert schedule_tables("1f1b", 3, 4)["active"].shape[1] == 9
    assert schedule_tables("gpipe", 2, 8)["active"].shape[1] == 9
    assert schedule_tables("1f1b", 2, 8)["active"].shape[1] == 16


def test_1f1b_stage_never_holds_more_than_S_forwards_ahead():
    """The 1F1B property the cost model's memory term prices: at any
    tick, a stage has run at most min(S, m) more forwards than the last
    stage has retired (= backward-ready) microbatches."""
    S, m = 3, 8
    t = schedule_tables("1f1b", S, m)
    active, mb = t["active"], t["mb"]
    fwd_done = [0] * S
    retired = 0                 # last stage's completions proxy
    for tick in range(active.shape[1]):
        for s in range(S):
            if active[s, tick]:
                fwd_done[s] += 1
        retired = fwd_done[S - 1]
        for s in range(S):
            assert fwd_done[s] - retired <= min(S, m)


# ------------------------------------------------------------------ #
# edge cases (ISSUE 8 satellite): m < S, S == 1, non-divisible v > 1
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("sched", ["gpipe", "1f1b", "interleaved",
                                   "interleaved3"])
@pytest.mark.parametrize("S,m", [(4, 1), (4, 2), (3, 1), (4, 3)])
def test_fewer_microbatches_than_stages(sched, m, S):
    """m < S: the pipeline is mostly bubble, but every item must still
    run exactly once in producer order — the race detector's invariants
    are the oracle."""
    tables = schedule_tables(sched, S, m)
    assert schedlint.check_tables(tables, sched, S, m) == []


@pytest.mark.parametrize("sched,v", [("gpipe", 1), ("1f1b", 1),
                                     ("interleaved", 2),
                                     ("interleaved3", 3)])
def test_single_stage_degenerate_ring(sched, v):
    """S=1: the ring is a self-loop and every chunk's producer is the
    same stage, so chunks must serialize (chunk c strictly after c-1)
    and nothing is ever on the wire except inter-chunk hops."""
    m = 3
    tables = schedule_tables(sched, 1, m)
    assert schedlint.check_tables(tables, sched, 1, m) == []
    active, chunk, mb = tables["active"], tables["chunk"], tables["mb"]
    assert int(active.sum()) == v * m
    done = {}
    for tick in range(active.shape[1]):
        if active[0, tick]:
            done[(int(chunk[0, tick]), int(mb[0, tick]))] = tick
    for (k, i), tick in done.items():
        if k > 0:
            assert done[(k - 1, i)] < tick
    if v == 1:
        # no chunks to hand over: a pure loop, zero arrival traffic
        assert not tables["arr_valid"].any()


@pytest.mark.parametrize("layers,v,S", [(7, 2, 3), (9, 3, 2), (5, 2, 2)])
def test_interleaved_non_divisible_chunking(layers, v, S):
    """v*S chunks over a layer count that does not divide evenly: the
    pad-and-mask gather must still cover every layer exactly once, each
    chunk contiguously, and the tick tables still verify."""
    split = balanced_stage_layers(layers, [1.0] * (S * v))
    assert sum(split) == layers and min(split) >= 1
    assert max(split) != min(split)             # genuinely uneven
    idx, valid = stage_gather_index(split, S, v)
    assert idx.shape == valid.shape == (S * v * max(split),)
    covered = idx[valid]
    assert sorted(covered.tolist()) == list(range(layers))
    # each chunk's real rows are one contiguous ascending layer run
    per = max(split)
    for chunk_pos in range(S * v):
        rows = idx[chunk_pos * per:(chunk_pos + 1) * per]
        real = rows[valid[chunk_pos * per:(chunk_pos + 1) * per]]
        assert real.tolist() == list(range(real[0], real[0] + len(real)))
    m = 4
    sched = f"interleaved{v}" if v != 2 else "interleaved"
    tables = schedule_tables(sched, S, m)
    assert schedlint.check_tables(tables, sched, S, m) == []


# ------------------------------------------------------------------ #
# runtime parity (subprocess, fully-manual meshes)
# ------------------------------------------------------------------ #

@pytest.mark.slow
def test_1f1b_parity_even_and_uneven_two_stages(subproc_env):
    """A30+T4 line: 1F1B matches the reference and the GPipe path
    bit-for-bit on both the searched uneven (4, 2) split and the
    equal-block fast path; interleaved (4 chunks over 6 layers — a
    non-divisible chunking) matches too."""
    res = _run_check(subproc_env, "A30,T4",
                     ("--layers", "6",
                      "--schedules", "gpipe,1f1b,interleaved"))
    assert res["splits"]["searched@1f1b"] == [4, 2]
    assert len(res["splits"]["searched@interleaved"]) == 4
    for key, loss in res["losses"].items():
        assert loss == res["ref_loss"], key
    assert res["gnorms"]["searched@1f1b"] == pytest.approx(
        res["ref_gnorm"], rel=1e-4)
    assert res["gnorms"]["searched@interleaved"] == pytest.approx(
        res["ref_gnorm"], rel=1e-4)


@pytest.mark.slow
def test_schedules_three_stage_parity(subproc_env):
    """3 stages: the uneven (3, 2, 1) 1F1B split and the 6-chunk
    interleaved split both equal the reference exactly, and the
    explicit even interleaved split is a no-op vs its equal-block
    path."""
    res = _run_check(subproc_env, "A30,T4,T4",
                     ("--layers", "6", "--micro", "3", "--batch", "6",
                      "--schedules", "1f1b,interleaved"))
    assert res["splits"]["searched@1f1b"] == [3, 2, 1]
    for key, loss in res["losses"].items():
        assert loss == res["ref_loss"], key
    assert res["losses"]["even@interleaved"] == \
        res["losses"]["legacy@interleaved"]
    assert res["gnorms"]["searched@1f1b"] == pytest.approx(
        res["ref_gnorm"], rel=1e-4)


@pytest.mark.slow
def test_moe_aux_is_schedule_invariant(subproc_env):
    """MoE load-balance aux: every schedule accumulates the same
    per-(stage, microbatch) aux terms, so the sums agree to an ulp
    (XLA may tree-reduce the longer 1F1B/interleaved tick axis in a
    different association) and the losses match the GPipe path and the
    reference at the uneven-grouping tolerance of the PR-3 MoE test."""
    res = _run_check(subproc_env, "A30,T4",
                     ("--arch", "phi3.5-moe-42b-a6.6b", "--layers", "4",
                      "--schedules", "gpipe,1f1b,interleaved"))
    assert res["ref_aux"] > 0
    for sched in ("1f1b", "interleaved"):
        assert res["auxes"][f"searched@{sched}"] == pytest.approx(
            res["auxes"]["searched"], rel=1e-6), sched
        assert res["losses"][f"searched@{sched}"] == pytest.approx(
            res["losses"]["searched"], rel=1e-6), sched
        assert res["losses"][f"searched@{sched}"] == pytest.approx(
            res["ref_loss"], rel=5e-3), sched
        assert res["gnorms"][f"searched@{sched}"] == pytest.approx(
            res["ref_gnorm"], rel=1e-2), sched
