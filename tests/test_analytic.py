"""Analytic roofline-cost sanity tests + pipeline mesh construction."""
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.configs.base import ShapeConfig
from repro.launch.analytic import analytic_cost
from repro.launch.hlo_parse import _groups_cross_pod


def test_flops_scale_with_tokens():
    cfg = get_config("llama3.2-3b")
    a = analytic_cost(cfg, ShapeConfig("a", 1024, 8, "train"), n_devices=16)
    b = analytic_cost(cfg, ShapeConfig("b", 1024, 16, "train"), n_devices=16)
    assert 1.8 < b.flops_total / a.flops_total < 2.4  # ~2x (+attn S² const)


def test_train_flops_include_remat_overhead():
    cfg = get_config("llama3.2-3b")
    s = ShapeConfig("t", 4096, 256, "train")
    with_remat = analytic_cost(cfg, s, n_devices=256, remat=True)
    without = analytic_cost(cfg, s, n_devices=256, remat=False)
    assert with_remat.flops_total > without.flops_total
    assert with_remat.model_flops == without.model_flops
    # useful fraction below 1 by construction
    assert with_remat.model_flops < with_remat.flops_total


def test_decode_flops_tiny_vs_prefill():
    cfg = get_config("llama3.2-3b")
    pre = analytic_cost(cfg, get_shape("prefill_32k"), n_devices=256)
    dec = analytic_cost(cfg, get_shape("decode_32k"), n_devices=256)
    assert dec.flops_total < pre.flops_total / 1000


def test_moe_uses_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    s = ShapeConfig("t", 1024, 8, "train")
    a = analytic_cost(cfg, s, n_devices=16)
    # 6*N_active*D, not 6*N_total*D
    assert a.model_flops == 6.0 * cfg.active_param_count() * 1024 * 8


def test_window_bounds_decode_cache_traffic():
    cfg = get_config("llama3.2-3b")
    s = get_shape("long_500k")
    full = analytic_cost(cfg, s, n_devices=256, window=0)
    windowed = analytic_cost(cfg, s, n_devices=256, window=8192)
    assert windowed.hbm_bytes_per_device < full.hbm_bytes_per_device


def test_tp_reduces_param_traffic():
    cfg = get_config("llama3.2-3b")
    s = ShapeConfig("t", 1024, 16, "train")
    tp1 = analytic_cost(cfg, s, n_devices=16, dp=16, tp=1)
    tp16 = analytic_cost(cfg, s, n_devices=256, dp=16, tp=16)
    assert tp16.hbm_bytes_per_device < tp1.hbm_bytes_per_device


# ------------------------------------------------------------------ #
# pod-crossing classification
# ------------------------------------------------------------------ #

def test_iota_groups_within_pod():
    # [32,16]<=[512]: consecutive groups of 16 — never cross a 256 boundary
    line = "x = f32[4] all-reduce(%y), replica_groups=[32,16]<=[512]"
    assert not _groups_cross_pod(line, pod_size=256)


def test_iota_groups_crossing_pod():
    # [256,2]<=[2,16,16]T(2,1,0): pairs (i, i+256) — always cross
    line = ("x = f32[4] all-reduce(%y), "
            "replica_groups=[256,2]<=[2,16,16]T(2,1,0)")
    assert _groups_cross_pod(line, pod_size=256)


def test_explicit_groups_and_pairs():
    assert not _groups_cross_pod("replica_groups={{0,1},{2,3}}", pod_size=2)
    assert _groups_cross_pod("replica_groups={{0,2}}", pod_size=2)
    assert not _groups_cross_pod("replica_groups={{0,1}}", pod_size=2)
    assert _groups_cross_pod("source_target_pairs={{0,3},{3,0}}", pod_size=2)
    assert not _groups_cross_pod("source_target_pairs={{0,1},{1,0}}",
                                 pod_size=2)


def test_pipeline_mesh_construction():
    import jax
    from repro.core.pipeline import pipeline_mesh, validate_stages
    from repro.launch.mesh import make_host_mesh
    base = make_host_mesh((1, 1), ("data", "model"))
    m = pipeline_mesh(base, 1)
    assert m.shape["stage"] == 1
    # stage must divide the stack length
    class FakeCfg:
        name = "x"
    leaf = jax.ShapeDtypeStruct((9, 4), np.float32)
    with pytest.raises(ValueError):
        validate_stages(FakeCfg(), {"w": leaf}, 2)
    validate_stages(FakeCfg(), {"w": leaf}, 3)
