"""Optimizer tests: AdamW matches the reference formula, clipping and
schedule properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from prophelpers import given, settings, st

from repro.configs.base import TrainConfig
from repro.optim import adamw_update, global_norm, init_adamw, lr_at
from repro.optim.adamw import clip_by_global_norm


def test_adamw_matches_manual_formula():
    cfg = TrainConfig(learning_rate=1e-2, weight_decay=0.0, grad_clip=1e9,
                      beta1=0.9, beta2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = init_adamw(params)
    new_params, new_state, _ = adamw_update(grads, state, params, cfg,
                                            jnp.float32(1e-2))
    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.001 * g ** 2
    mh, vh = m / 0.1, v / 0.001
    want = np.asarray(params["w"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-5)
    assert int(new_state.step) == 1


def test_weight_decay_skips_norm_like_params():
    cfg = TrainConfig(learning_rate=1e-2, weight_decay=1.0, grad_clip=1e9)
    params = {"w": jnp.ones((3,)), "scale": jnp.ones((3,))}
    grads = {"w": jnp.zeros((3,)), "scale": jnp.zeros((3,))}
    state = init_adamw(params)
    new_params, _, _ = adamw_update(grads, state, params, cfg,
                                    jnp.float32(1e-2))
    assert float(jnp.max(jnp.abs(new_params["scale"] - 1.0))) < 1e-7
    assert float(jnp.max(jnp.abs(new_params["w"] - 1.0))) > 1e-4  # decayed


@settings(max_examples=50, deadline=None)
@given(
    scale=st.floats(0.01, 1000.0),
    max_norm=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_clip_property(scale, max_norm, seed):
    """After clipping, global norm <= max_norm (+eps) and direction kept."""
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal(5) * scale, jnp.float32),
         "b": jnp.asarray(rng.standard_normal((2, 3)) * scale, jnp.float32)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * 1.001 + 1e-6
    if float(norm) <= max_norm:  # no-op when under the limit
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"], np.float32), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(steps=st.integers(2, 1000))
def test_schedule_properties(steps):
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=1000,
                      schedule="cosine")
    lr0 = float(lr_at(jnp.asarray(0), cfg))
    lr_peak = float(lr_at(jnp.asarray(10), cfg))
    lr_s = float(lr_at(jnp.asarray(steps), cfg))
    assert 0 < lr0 < lr_peak <= 1e-3 + 1e-9
    assert 0 < lr_s <= 1e-3 + 1e-9
    # cosine floor: never below 10% after decay
    assert float(lr_at(jnp.asarray(1000), cfg)) >= 1e-4 * 0.99
