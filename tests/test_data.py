"""Data-pipeline tests: tokenizer roundtrip (hypothesis), packing
invariants, loader determinism and shard-partition properties."""
import numpy as np
import pytest
from prophelpers import given, settings, st

from repro.data import (Loader, Tokenizer, build_dataset, pack_documents,
                        synthetic_wikipedia)
from repro.data.tokenizer import BOS, EOS, N_BYTES, N_SPECIAL


@pytest.fixture(scope="module")
def corpus():
    return list(synthetic_wikipedia(100, seed=7))


@pytest.fixture(scope="module")
def tok(corpus):
    return Tokenizer.train(corpus, vocab_size=1024)


@settings(max_examples=100, deadline=None)
@given(st.text(max_size=300))
def test_tokenizer_roundtrip_any_text(text):
    """Byte fallback makes every unicode string decode(encode(x)) == x."""
    t = Tokenizer([])
    ids = t.encode(text)
    assert ids[0] == BOS and ids[-1] == EOS
    assert t.decode(ids) == text


def test_tokenizer_roundtrip_trained(corpus, tok):
    for text in corpus[:20]:
        assert tok.decode(tok.encode(text)) == text


def test_tokenizer_vocab_budget(corpus):
    t = Tokenizer.train(corpus, vocab_size=500)
    assert t.vocab_size <= 500


def test_tokenizer_save_load(tmp_path, tok, corpus):
    p = str(tmp_path / "tok.json")
    tok.save(p)
    t2 = Tokenizer.load(p)
    assert t2.encode(corpus[0]) == tok.encode(corpus[0])


@settings(max_examples=30, deadline=None)
@given(
    n_docs=st.integers(5, 30),
    seq_len=st.sampled_from([16, 32, 64]),
    doc_len=st.integers(10, 60),
    seed=st.integers(0, 1000),
)
def test_packing_preserves_stream(n_docs, seq_len, doc_len, seed):
    """Packing is exactly the concatenated token stream, windowed."""
    rng = np.random.default_rng(seed)
    docs = [list(rng.integers(N_SPECIAL + N_BYTES, 500, doc_len))
            for _ in range(n_docs)]
    stream = [t for d in docs for t in d]
    if len(stream) < seq_len + 1:
        return
    ds = pack_documents(docs, seq_len)
    flat = ds.examples.reshape(-1)
    np.testing.assert_array_equal(flat, stream[: len(flat)])
    assert ds.examples.shape[1] == seq_len + 1


def test_loader_deterministic_and_partitioned(corpus, tok):
    ds = build_dataset(corpus, tok, seq_len=32)
    full = Loader(ds, global_batch=8, seed=3)
    s0 = Loader(ds, global_batch=8, seed=3, shard=0, n_shards=2)
    s1 = Loader(ds, global_batch=8, seed=3, shard=1, n_shards=2)
    for step in (0, 1, full.batches_per_epoch):  # crosses an epoch boundary
        whole = full.batch_at(step)["tokens"]
        parts = np.concatenate([s0.batch_at(step)["tokens"],
                                s1.batch_at(step)["tokens"]])
        np.testing.assert_array_equal(whole, parts)
        # determinism
        np.testing.assert_array_equal(whole, full.batch_at(step)["tokens"])


def test_loader_epoch_coverage(corpus, tok):
    """Within one epoch every example is seen at most once."""
    ds = build_dataset(corpus, tok, seq_len=32)
    loader = Loader(ds, global_batch=4, seed=0)
    seen = []
    for step in range(loader.batches_per_epoch):
        order = loader.epoch_order(0)
        sel = order[step * 4: (step + 1) * 4]
        seen.extend(sel.tolist())
    assert len(seen) == len(set(seen))


def test_labels_shifted_and_masked(corpus, tok):
    ds = build_dataset(corpus, tok, seq_len=32)
    loader = Loader(ds, global_batch=4, seed=0)
    b = loader.batch_at(0)
    # labels are the next token; pad (id 0) masked to -1
    win_tokens, win_labels = b["tokens"], b["labels"]
    assert win_tokens.shape == win_labels.shape == (4, 32)
    assert np.all((win_labels >= -1))
