"""Uneven-stage-split runtime parity (ROADMAP "uneven stage splits at
runtime"): a searched heterogeneous ``Placement``'s pipeline loss must
match the unsharded reference loss bit-for-bit, and the pad-and-mask
stage construction must be a no-op for even splits.

Runs ``repro.launch.pipeline_check`` in subprocesses (the forced host
device count locks at first jax init).  The (stage, 1, 1) meshes it
builds are fully manual, so these tests run even on jax 0.4.x, where the
partial-auto pipeshard tests must skip (see test_plans.py and
repro.compat.NATIVE_SHARD_MAP).
"""
import json
import subprocess
import sys

import pytest


def _run_check(env, gpus, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.pipeline_check",
           "--gpus", gpus, *extra]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    return json.loads(line)


@pytest.mark.slow
def test_uneven_two_stage_parity(subproc_env):
    """A30+T4 line: the searched TFLOP-weighted split is uneven and its
    pipeline loss equals the unsharded reference exactly."""
    res = _run_check(subproc_env, "A30,T4", ("--layers", "6"))
    assert res["stage_layers"] == [4, 2]
    assert res["losses"]["searched"] == res["ref_loss"]
    assert res["losses"]["legacy"] == res["ref_loss"]
    # pad-and-mask no-op: explicit even split == equal-block fast path
    assert res["losses"]["even"] == res["losses"]["legacy"]
    assert res["gnorms"]["searched"] == pytest.approx(res["ref_gnorm"],
                                                      rel=1e-4)


@pytest.mark.slow
def test_uneven_three_stage_parity_non_divisible_stack(subproc_env):
    """3 stages over 7 layers — a split no equal-block sharding could
    even represent (7 % 3 != 0) — still matches the reference."""
    res = _run_check(subproc_env, "A30,A30,T4", ("--layers", "7"))
    assert res["stage_layers"] == [3, 3, 1]
    assert res["losses"]["searched"] == res["ref_loss"]
    assert res["gnorms"]["searched"] == pytest.approx(res["ref_gnorm"],
                                                      rel=1e-4)


@pytest.mark.slow
def test_moe_aux_accumulates_across_stages(subproc_env):
    """MoE load-balance aux must sum over stages (each owns distinct
    expert layers) and average over microbatches — not keep only the
    last stage's aux, and not scale with the microbatch count.  The
    residual gap vs. the reference is mean-of-microbatch-means vs.
    full-batch mean, which is small; the bugs this guards against were
    a missing-stages aux and an n_micro-times overcount."""
    res = _run_check(subproc_env, "A30,T4",
                     ("--arch", "phi3.5-moe-42b-a6.6b", "--layers", "4"))
    assert res["ref_aux"] > 0                   # MoE actually has aux
    assert res["auxes"]["searched"] == pytest.approx(res["ref_aux"],
                                                     rel=0.25)
    assert res["losses"]["searched"] == pytest.approx(res["ref_loss"],
                                                      rel=5e-3)


@pytest.mark.slow
def test_even_split_pad_and_mask_is_noop_three_stages(subproc_env):
    res = _run_check(subproc_env, "A30,T4,T4",
                     ("--layers", "9", "--micro", "3", "--batch", "6"))
    assert res["stage_layers"] == [5, 2, 2]
    assert res["losses"]["searched"] == res["ref_loss"]
    assert res["losses"]["even"] == res["losses"]["legacy"]
    assert res["losses"]["legacy"] == res["ref_loss"]
