"""End-to-end system tests: pretraining convergence, serving, dry-run CLI,
and engine generation — the integration layer over all substrates."""
import dataclasses
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core.plans import get_plan
from repro.data import Loader, Tokenizer, build_dataset, synthetic_wikipedia
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import Engine
from repro.train import train


@pytest.fixture(scope="module")
def tiny_setup():
    texts = list(synthetic_wikipedia(200, seed=1))
    tok = Tokenizer.train(texts, 512)
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=64)
    return cfg, tok, ds


@pytest.mark.slow
def test_pretraining_reduces_loss(tiny_setup):
    cfg, tok, ds = tiny_setup
    loader = Loader(ds, global_batch=8, seed=0)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    res = train(Model(cfg), get_plan("data"), mesh,
                TrainConfig(warmup_steps=5, total_steps=40), loader,
                steps=25, log_every=0)
    assert res.losses[-1] < res.losses[0] - 0.5
    assert np.isfinite(res.losses).all()


@pytest.mark.slow
def test_checkpoint_resume_continues(tiny_setup, tmp_path):
    cfg, tok, ds = tiny_setup
    loader = Loader(ds, global_batch=8, seed=0)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    model = Model(cfg)
    tcfg = TrainConfig(warmup_steps=2, total_steps=20)
    train(model, get_plan("data"), mesh, tcfg, loader, steps=5,
          log_every=0, ckpt_dir=str(tmp_path))
    from repro.optim import init_adamw
    from repro.train import latest_checkpoint, restore_checkpoint
    params = model.init(jax.random.key(0))
    opt = init_adamw(params)
    p2, o2, step = restore_checkpoint(latest_checkpoint(str(tmp_path)),
                                      params, opt)
    assert step == 5
    res = train(model, get_plan("data"), mesh, tcfg, loader, steps=3,
                params=p2, opt_state=o2, log_every=0)
    assert np.isfinite(res.losses).all()


@pytest.mark.slow
def test_engine_generates(tiny_setup):
    cfg, tok, ds = tiny_setup
    mesh = make_host_mesh((1, 1), ("data", "model"))
    model = Model(cfg)
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))
    eng = Engine(model, get_plan("data"), mesh, batch_size=2, max_len=128)
    prompts = np.stack([ds.examples[0, :16], ds.examples[1, :16]])
    out = eng.generate(params, {"tokens": np.asarray(prompts, np.int32)},
                       n_tokens=8)
    assert out["tokens"].shape == (2, 8)
    assert out["stats"].prefill_s > 0
    # greedy decode is deterministic
    out2 = eng.generate(params, {"tokens": np.asarray(prompts, np.int32)},
                        n_tokens=8)
    np.testing.assert_array_equal(out["tokens"], out2["tokens"])


@pytest.mark.slow
def test_dryrun_cli_smoke(subproc_env):
    """The dry-run entrypoint itself (512 forced devices, reduced to one
    combo) must lower + compile + emit a roofline record."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "whisper-small", "--shape", "decode_32k"]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=subproc_env)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads([l for l in out.stdout.splitlines()
                      if l.startswith("{")][-1])
    assert rec["status"] == "ok"


def test_serve_matches_forward_greedy(tiny_setup):
    """Prefill logits equal the teacher-forced forward's last position."""
    cfg, tok, ds = tiny_setup
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = np.asarray(ds.examples[:1, :12], np.int32)
    cache = model.init_cache(1, 64)
    lg, cache = model.prefill(params, {"tokens": jax.numpy.asarray(toks)},
                              cache)
    full, _ = model.forward(params, {"tokens": jax.numpy.asarray(toks)},
                            remat=False)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(lg), -1),
        np.argmax(np.asarray(full[:, -1]), -1))


def test_grad_accum_matches_full_batch(tiny_setup):
    """grad_accum=2 must produce the same update as the full batch (equal
    per-microbatch token counts => identical mean gradients)."""
    import dataclasses
    from repro.configs.base import TrainConfig
    from repro.core.steps import build_train_step
    from repro.core.plans import get_plan
    from repro.optim import init_adamw
    cfg, tok, ds = tiny_setup
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    from repro.data import Loader
    loader = Loader(ds, global_batch=8, seed=0)
    batch = loader.batch_at(0)
    results = {}
    with jax.set_mesh(mesh):
        for ga in (1, 2, 4):
            params = model.init(jax.random.key(0))
            opt = init_adamw(params)
            tcfg = TrainConfig(warmup_steps=1, total_steps=10, grad_accum=ga)
            step, sh = build_train_step(
                model, get_plan("data"), mesh, tcfg,
                params_shapes=jax.eval_shape(lambda: params),
                batch_shapes=jax.eval_shape(lambda: batch))
            p, o, metrics = step(params, opt, batch)
            results[ga] = (float(metrics["loss"]),
                           float(metrics["grad_norm"]))
    for ga in (2, 4):
        np.testing.assert_allclose(results[ga][0], results[1][0], rtol=2e-3)
        np.testing.assert_allclose(results[ga][1], results[1][1], rtol=2e-2)
