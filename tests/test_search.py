"""PlanSearch: the general (technique × site-subset × stage-order) search,
its Algorithm-1 equivalence on two-VM topologies, and the selector's
tie-region / ZeRO2-fallback branches (paper §IV-H)."""
import itertools

import pytest

from prophelpers import given, settings, st

from repro.configs import get_config
from repro.core.costmodel import (ALL_TECHNIQUES, PAPER_CLUSTERS,
                                  TECHNIQUES, fabric_cluster,
                                  paper_workload)
from repro.core.search import (Candidate, PlanSearch, algorithm1_select,
                               stage_orders)
from repro.core.selector import CostModelProber, select_technique
from repro.core.topology import (Link, Site, line, make_topology, ring,
                                 two_site)

WL_M = paper_workload(get_config("gpt2m"))
WL_L = paper_workload(get_config("gpt2L"))


def _sites(n, gpu="A30"):
    return [Site((gpu, gpu), name=f"S{i}") for i in range(n)]


# ------------------------------------------------------------------ #
# enumeration
# ------------------------------------------------------------------ #

def test_candidate_enumeration_3_sites():
    t = make_topology("f", _sites(3), {
        (i, j): Link(1e-3, 3.0)
        for i, j in itertools.combinations(range(3), 2)})
    cands = list(PlanSearch(WL_M, t).candidates())
    # singles: 3 sites x {data, zero2, shard}; pairs: 3 x (3 + 1 order
    # x 3 schedules); triple: 3 + 3 stage orders x 3 schedules
    assert len(cands) == 9 + 18 + 12
    assert all(c.technique != "pipeshard" or len(c.sites) > 1
               for c in cands)
    # the schedule dimension only applies to pipeline candidates
    assert {c.schedule for c in cands if c.technique == "pipeshard"} \
        == {"gpipe", "1f1b", "interleaved"}
    assert all(c.schedule == "gpipe" for c in cands
               if c.technique != "pipeshard")
    # restricting schedules restores the legacy space
    legacy = list(PlanSearch(WL_M, t, schedules=("gpipe",)).candidates())
    assert len(legacy) == 9 + 12 + 6


def test_stage_orders_dedupe_reversals():
    assert list(stage_orders((0, 1))) == [(0, 1)]
    assert set(stage_orders((0, 1, 2))) == {(0, 1, 2), (0, 2, 1), (1, 0, 2)}
    assert len(list(stage_orders(tuple(range(5)), max_orders=10))) == 10


def test_candidate_key_and_placement():
    c = Candidate("pipeshard", (0, 2), (2, 0))
    assert c.key == "pipeshard@V1+V3|V3>V1"
    assert c.placement().pod_permutation() == (1, 0)
    assert Candidate("data", (1,)).key == "data@V2"


# ------------------------------------------------------------------ #
# Algorithm 1 as the N=2 special case (satellite: PlanSearch must
# reproduce select_technique on every paper cluster)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("mname", ["gpt2m", "gpt2L"])
@pytest.mark.parametrize("cname", sorted(PAPER_CLUSTERS))
def test_plansearch_select_equals_algorithm1_on_paper_clusters(cname, mname):
    wl = paper_workload(get_config(mname))
    cluster = PAPER_CLUSTERS[cname]
    legacy = select_technique(CostModelProber(wl, cluster), delta=0.1)
    searched = PlanSearch.for_cluster(wl, cluster).select(delta=0.1)
    assert (searched.technique, searched.vms) == (legacy.technique,
                                                  legacy.vms)
    assert searched.probes == legacy.probes


@settings(max_examples=25, deadline=None)
@given(lat=st.floats(0.1, 150.0),
       g1=st.sampled_from(["RTX", "T4", "A30"]),
       g2=st.sampled_from(["RTX", "T4", "A30"]),
       delta=st.floats(0.01, 0.5))
def test_plansearch_select_equals_algorithm1_property(lat, g1, g2, delta):
    """PlanSearch on any 2-site topology makes Algorithm 1's exact call."""
    c = fabric_cluster("x", (g1, g1), (g2, g2), lat)
    for wl in (WL_M, WL_L):
        legacy = select_technique(CostModelProber(wl, c), delta=delta)
        searched = PlanSearch.for_cluster(wl, c).select(delta=delta)
        assert (searched.technique, searched.vms) == (legacy.technique,
                                                      legacy.vms)


# ------------------------------------------------------------------ #
# selector tie-region and fallback branches (core/selector.py lines
# 90-100 of the seed — now core/search.algorithm1_select)
# ------------------------------------------------------------------ #

class FakeProber:
    """Scripted probe table: (technique, sites-tuple) -> TFLOP/s.  The
    paper's 'on everything' probes key on the full site tuple (the probe
    now always receives an explicit Placement)."""

    def __init__(self, table, n_sites=2):
        self.table = table
        self.n_sites = n_sites

    def probe(self, technique, placement):
        every = tuple(range(self.n_sites))
        sites = every if placement is None else tuple(placement.sites)
        key = (technique, None if sites == every and technique in
               ("pipeshard", "zero2") else sites)
        return self.table.get(key)


def test_tie_region_prefers_pipeshard_when_at_least_equal():
    # (t_p - t_z)/t_z = 5% < delta and t_p >= t_z: tie region -> pipeshard
    sel = select_technique(FakeProber({
        ("pipeshard", None): 10.5, ("data", (0,)): 10.0,
        ("shard", (0,)): 1.0, ("data", (1,)): 1.0, ("shard", (1,)): 1.0,
    }), delta=0.1)
    assert (sel.technique, sel.vms) == ("pipeshard", [0, 1])


def test_tie_region_picks_best_single_vm_when_it_edges_out():
    # within delta but t_z > t_p: the absolute best measured plan wins
    sel = select_technique(FakeProber({
        ("pipeshard", None): 10.0, ("data", (0,)): 1.0,
        ("shard", (0,)): 1.0, ("data", (1,)): 2.0, ("shard", (1,)): 10.5,
    }), delta=0.1)
    assert (sel.technique, sel.vms) == ("shard", [1])


def test_tie_region_vm1_wins_exact_ties():
    sel = select_technique(FakeProber({
        ("pipeshard", None): 9.0, ("data", (0,)): 10.0,
        ("shard", (0,)): 1.0, ("data", (1,)): 10.0, ("shard", (1,)): 1.0,
    }), delta=0.5)
    assert (sel.technique, sel.vms) == ("data", [0])


def test_pipeshard_wins_beyond_delta():
    sel = select_technique(FakeProber({
        ("pipeshard", None): 12.0, ("data", (0,)): 10.0,
        ("shard", (0,)): 1.0, ("data", (1,)): 1.0, ("shard", (1,)): 1.0,
    }), delta=0.1)
    assert (sel.technique, sel.vms) == ("pipeshard", [0, 1])


def test_zero2_fallback_when_everything_ooms():
    sel = select_technique(FakeProber({
        ("zero2", None): 3.0,
    }), delta=0.1)
    assert (sel.technique, sel.vms) == ("zero2", [0, 1])
    assert "zero2@both" in sel.probes


def test_none_when_even_zero2_ooms():
    sel = select_technique(FakeProber({}), delta=0.1)
    assert sel.technique == "none"
    assert sel.vms is None
    assert sel.feasible


def test_wrapper_respects_prober_site_count():
    sel = select_technique(FakeProber({
        ("data", (2,)): 5.0,
    }, n_sites=3), delta=0.1)
    assert (sel.technique, sel.vms) == ("data", [2])
    assert "data@V3" in sel.probes and "pipeshard@all" in sel.probes


# ------------------------------------------------------------------ #
# beyond the two-VM API: selections the paper's shape cannot express
# ------------------------------------------------------------------ #

def edge3():
    """Two metro-adjacent sites + one transatlantic site."""
    return make_topology(
        "edge3", _sites(3),
        {(0, 1): Link(0.5e-3, 3.0), (1, 2): Link(60e-3, 3.0),
         (0, 2): Link(100e-3, 3.0)})


def test_search_spans_the_cheap_pair_of_three_sites():
    search = PlanSearch(WL_M, edge3())
    best = search.best()
    # Data over the two nearby sites: a (technique, subset) pair Algorithm
    # 1 never probes and the two-VM Cluster cannot even represent.
    assert best.candidate.technique == "data"
    assert best.candidate.sites == (0, 1)
    # ... and it strictly beats what the generalized Algorithm 1 picks
    # from the paper's restricted probe set.
    alg1 = search.select(delta=0.1)
    alg1_perf = search.evaluate(
        Candidate(alg1.technique, tuple(alg1.vms)))
    assert best.tflops > alg1_perf


def test_search_orders_pipeline_stages_around_dear_links():
    # asymmetric ring: A-B and B-C at 5ms, C-A at 120ms.  The best
    # 3-stage pipeline crosses the two cheap links (order A>B>C); any
    # order crossing the 120ms edge prices strictly worse.
    topo = ring("ring3", _sites(3),
                [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)])
    search = PlanSearch(WL_L, topo)
    scored = {s.candidate.stage_order: s.tflops for s in search.search()
              if s.candidate.technique == "pipeshard"
              and len(s.candidate.sites) == 3}
    assert set(scored) == {(0, 1, 2), (0, 2, 1), (1, 0, 2)}
    assert max(scored, key=scored.get) == (0, 1, 2)
    assert scored[(0, 1, 2)] > scored[(0, 2, 1)]


def test_live_probe_fn_gets_placements_and_dedupes():
    """Live probes carry the full Placement (stage order pinned, so the
    probe can build the exact staged mesh), and each probe-equivalence
    class — reversed orders are the same physical pipeline — is measured
    exactly once per search instance (every probe is an epsilon-epoch
    training run)."""
    calls = []

    def probe(tech, placement):
        calls.append((tech, placement))
        return 1.0

    search = PlanSearch(WL_M, edge3(), probe_fn=probe)
    search.search()
    pipe = [p for t, p in calls if t == "pipeshard"]
    # stage orders are pinned now: (3 pairs + 3 canonical triple
    # orders) x 3 schedules
    assert all(p.stage_order is not None for p in pipe)
    assert len(pipe) == len({(p.sites, p.stage_order, p.schedule)
                             for p in pipe}) == 18
    # re-running the search (and Algorithm 1's overlapping probe set)
    # reuses cached measurements instead of re-training
    n = len(calls)
    search.search()
    search.select(delta=0.1)
    assert len([c for c in calls[n:] if c[0] == "pipeshard"]) == 0


def test_live_probe_dedupes_reversed_orders_under_tflops_balance():
    """stage_balance='tflops' enumerates both directions of each order
    (exact-tie layer quotas can break the symmetry), but a reversed
    placement assigns the same layers to the same sites — one live
    measurement must serve both."""
    calls = []

    def probe(tech, placement):
        calls.append((tech, placement))
        return 1.0

    het = make_topology(
        "het3", [Site(("A30", "A30")), Site(("A30", "A30")),
                 Site(("T4", "T4"))],
        {(0, 1): Link(0.5e-3, 3.0), (1, 2): Link(60e-3, 3.0),
         (0, 2): Link(100e-3, 3.0)})
    search = PlanSearch(WL_M, het, stage_balance="tflops", probe_fn=probe)
    search.search()
    pipe = [p for t, p in calls if t == "pipeshard"]
    # every pipeline probe carries its TFLOP-weighted layer split
    assert all(p.stage_layers is not None for p in pipe)
    keys = {PlanSearch.probe_key("pipeshard", p) for p in pipe}
    # 12 directed orders: GPipe and 1F1B merge reversal pairs (6 keys
    # each); interleaved does NOT — reversing the ring re-deals the
    # chunk->site assignment, so all 12 directions measure separately
    assert len(pipe) == len(keys) == 6 + 6 + 12
    for p in pipe:
        if p.schedule == "interleaved":
            assert len(p.stage_layers) == 2 * len(p.sites)


def test_live_select_shares_tflops_probe_cache_and_valid_splits():
    """Under stage_balance='tflops', Algorithm 1's all-site pipeline
    probe gets the same weighted split the search attached: the cache
    key matches (no duplicate epsilon-epoch run) and a live run_fn
    never receives an even split that cannot partition a non-divisible
    stack (gpt2l: 26 layers over 3 stages)."""
    calls = []
    wl = paper_workload(get_config("gpt2l"))
    assert wl.cfg.n_layers % 3 != 0

    def probe(tech, placement):
        calls.append((tech, placement))
        if tech == "pipeshard":
            assert placement.stage_layers is not None
            assert sum(placement.stage_layers) == wl.cfg.n_layers
        return 1.0

    het = make_topology(
        "het3", [Site(("A30", "A30")), Site(("A30", "A30")),
                 Site(("T4", "T4"))],
        {(0, 1): Link(0.5e-3, 3.0), (1, 2): Link(60e-3, 3.0),
         (0, 2): Link(100e-3, 3.0)})
    search = PlanSearch(wl, het, stage_balance="tflops", probe_fn=probe)
    search.search()
    n = len([c for c in calls if c[0] == "pipeshard"])
    search.select(delta=0.1)
    assert len([c for c in calls if c[0] == "pipeshard"]) == n


def test_live_prober_reraises_programming_errors():
    """A TypeError / bad mesh shape in the probe's run_fn is a bug, not
    an OOM — it must propagate instead of becoming a None probe that
    corrupts Algorithm 1's selection."""
    from repro.core.plans import Placement
    from repro.core.selector import LiveProber

    def bad(tech, placement):
        raise TypeError("pipeline_mesh() got an unexpected keyword")

    with pytest.raises(TypeError):
        LiveProber(bad).probe("pipeshard", Placement((0, 1)))

    def bad_shape(tech, placement):
        raise ValueError("cannot split data=3 into 2 pipeline sub-stages")

    with pytest.raises(ValueError):
        LiveProber(bad_shape).probe("pipeshard", Placement((0, 1)))


def test_live_prober_maps_resource_failures_to_infeasible():
    from repro.core.plans import Placement
    from repro.core.selector import LiveProber, probe_infeasible

    XlaRuntimeError = type("XlaRuntimeError", (Exception,), {})

    def oom(tech, placement):
        raise XlaRuntimeError("RESOURCE_EXHAUSTED: Out of memory "
                              "allocating 12884901888 bytes")

    assert LiveProber(oom).probe("data", Placement((0,))) is None

    def host_oom(tech, placement):
        raise MemoryError()

    assert LiveProber(host_oom).probe("data", Placement((0,))) is None
    assert not probe_infeasible(TypeError("x"))
    assert not probe_infeasible(XlaRuntimeError("INVALID_ARGUMENT: ..."))


def test_search_best_feasibility_and_ranking():
    search = PlanSearch(WL_M, edge3(), max_sites=1)
    ranked = search.search()
    perfs = [s.tflops or 0.0 for s in ranked]
    assert perfs == sorted(perfs, reverse=True)
    assert all(len(s.candidate.sites) == 1 for s in ranked)


# ------------------------------------------------------------------ #
# the schedule dimension (docs/schedules.md)
# ------------------------------------------------------------------ #

def test_placements_carry_schedule():
    """Every searched pipeline candidate realizes as a Placement that
    pins its schedule; interleaved ones always carry an explicit
    per-chunk split (their chunks are non-contiguous on a stage)."""
    search = PlanSearch(WL_M, edge3())
    pipe = [c for c in search.candidates() if c.technique == "pipeshard"]
    assert {c.schedule for c in pipe} == {"gpipe", "1f1b", "interleaved"}
    for c in pipe:
        p = search.placement(c)
        assert p.schedule == c.schedule
        if c.schedule == "interleaved":
            assert p.stage_layers is not None
            assert len(p.stage_layers) == 2 * len(c.sites)
            assert sum(p.stage_layers) == WL_M.cfg.n_layers
        else:
            assert p.stage_layers is None      # even balance, v == 1
    assert "#1f1b" in Candidate("pipeshard", (0, 1), (0, 1), "1f1b").key


def test_schedule_search_flips_gpipe_to_1f1b_on_memory():
    """The acceptance scenario (ISSUE 4): small m (the paper's 4),
    3 stages, gpt2L at batch 48-per-site-pair scale — GPipe's m
    in-flight microbatches blow the 24 GB RTX budget while 1F1B's
    min(S, m) = 3 fit, so the schedule-aware search flips the winner
    from a 2-site Data fallback to Pipeshard-on-everything under 1F1B.
    Reproduced by `benchmarks/pipeline_ablation.py --schedules` and
    explained in docs/schedules.md."""
    wl = paper_workload(get_config("gpt2L"), global_batch=52)
    assert wl.microbatches == 4                     # small m
    topo = line("rtx3", _sites(3, gpu="RTX"),
                [Link(57.4e-3, 3.0)] * 2)
    from repro.core.costmodel import technique_step_cost
    gpipe = technique_step_cost("pipeshard", wl, topo, schedule="gpipe")
    f1b = technique_step_cost("pipeshard", wl, topo, schedule="1f1b")
    assert not gpipe.fits and f1b.fits              # the memory rescue
    assert f1b.total_s == gpipe.total_s             # same bubble => time
    legacy = PlanSearch(wl, topo, schedules=("gpipe",)).best()
    assert legacy.candidate.technique != "pipeshard"
    best = PlanSearch(wl, topo).best()
    assert best.candidate.technique == "pipeshard"
    assert best.candidate.schedule == "1f1b"
    assert len(best.candidate.sites) == 3
    assert best.tflops > legacy.tflops


def test_costmodel_prober_prices_the_placement_schedule():
    """A CostModelProber wired in as probe_fn must price each
    candidate's own schedule: interleaved placements carry 2S-entry
    chunk splits (which the gpipe pricing would reject outright), and
    1F1B's memory rescue must survive the prober path."""
    from repro.core.selector import CostModelProber
    topo = line("a30l3", _sites(3), [Link(0.1e-3, 3.0)] * 2)
    search = PlanSearch(WL_M, topo,
                        probe_fn=CostModelProber(WL_M, topo).probe)
    ranked = search.search()          # raises without schedule threading
    direct = PlanSearch(WL_M, topo)
    for s in ranked:
        if s.candidate.technique == "pipeshard":
            assert s.tflops == direct.evaluate(s.candidate), \
                s.candidate.key


def test_interleaved_shrinks_bubble_but_pays_p2p():
    """At small m on cheap links the interleaved schedule is the
    fastest pipeline (bubble / v); on dear links its v-fold boundary
    crossings invert the ordering."""
    import dataclasses
    from repro.core.costmodel import technique_step_cost
    wl = dataclasses.replace(WL_M, microbatches=2)

    def pipe_s(lat_ms, sched):
        topo = line("l3", _sites(3), [Link(lat_ms * 1e-3, 3.0)] * 2)
        return technique_step_cost("pipeshard", wl, topo,
                                   schedule=sched).total_s

    assert pipe_s(0.1, "interleaved") < pipe_s(0.1, "gpipe")
    assert pipe_s(20.0, "interleaved") > pipe_s(20.0, "gpipe")


# ------------------------------------------------------------------ #
# the extended technique pool (docs/cost-model.md): shard_zero / fsdp
# winners the paper's four-technique space cannot express
# ------------------------------------------------------------------ #

def test_extended_pool_widens_enumeration_only():
    t = make_topology("f", _sites(3), {
        (i, j): Link(1e-3, 3.0)
        for i, j in itertools.combinations(range(3), 2)})
    paper = list(PlanSearch(WL_M, t).candidates())
    full = list(PlanSearch(WL_M, t,
                           techniques=ALL_TECHNIQUES).candidates())
    # +2 collective techniques on each of the 7 non-empty subsets
    assert len(full) == len(paper) + 2 * 7
    assert {c.technique for c in full} == set(ALL_TECHNIQUES)
    # the default pool is still the paper's four
    assert {c.technique for c in paper} == set(TECHNIQUES)


def test_fsdp_wins_memory_tight_lan_selection():
    """The acceptance scenario (ISSUE 5): gpt2L on the paper's
    TACC-TACC slice — Data's replicated state (25 GB) exceeds every
    site's memory, so the paper pool falls back to zero2; the extended
    pool instead finds fsdp on the RTX site alone (state/n sharding
    fits in 24 GB, and its 3x-param-bytes gather volume beats zero2's
    2.2x-grad all-reduce on the metro link)."""
    wl = paper_workload(get_config("gpt2L"))
    c = PAPER_CLUSTERS["TACC-TACC"]
    from repro.core.costmodel import technique_step_cost
    assert not technique_step_cost("data", wl, c, [0]).fits
    assert not technique_step_cost("data", wl, c).fits
    paper = PlanSearch.for_cluster(wl, c).best()
    assert paper.candidate.technique == "zero2"
    full = PlanSearch.for_cluster(wl, c,
                                  techniques=ALL_TECHNIQUES).best()
    assert full.candidate.technique == "fsdp"
    assert full.candidate.sites == (0,)
    assert full.tflops > paper.tflops


def test_shard_zero_wins_metro_lan3():
    """The 3-site demo (examples/select_technique.py --topology lan3
    --techniques all): three 16GB T4 sites a campus apart, gpt2L — the
    hybrid shard_zero (TP inside each site, ZeRO-2 across) beats every
    paper-pool plan by keeping the per-layer all-reduces off the WAN
    while still partitioning the optimizer state."""
    wl = paper_workload(get_config("gpt2L"))
    topo = line("lan3", _sites(3, gpu="T4"), [Link(0.1e-3, 3.0)] * 2)
    paper = PlanSearch(wl, topo).best()
    full = PlanSearch(wl, topo, techniques=ALL_TECHNIQUES).best()
    assert full.candidate.technique == "shard_zero"
    assert full.candidate.sites == (0, 1, 2)
    assert full.tflops > paper.tflops


def test_extended_algorithm1_probes_and_picks_fsdp():
    """Algorithm 1's opt-in extended pool: the fsdp single-site probes
    join the paper's probe set and rescue the memory-tight TACC-TACC
    gpt2L selection; the default probe set stays bit-for-bit the
    paper's."""
    wl = paper_workload(get_config("gpt2L"))
    c = PAPER_CLUSTERS["TACC-TACC"]
    prober = CostModelProber(wl, c)
    legacy = select_technique(prober, delta=0.1)
    default = select_technique(prober, delta=0.1, extended=False)
    assert default.probes == legacy.probes
    assert default.technique == "zero2"
    ext = select_technique(prober, delta=0.1, extended=True)
    assert (ext.technique, ext.vms) == ("fsdp", [0])
    for key in ("fsdp@V1", "fsdp@V2", "fsdp@both", "shard_zero@both"):
        assert key in ext.probes
    # a widened PlanSearch derives extended probing automatically
    searched = PlanSearch.for_cluster(
        wl, c, techniques=ALL_TECHNIQUES).select(delta=0.1)
    assert (searched.technique, searched.vms) == ("fsdp", [0])


def test_extended_algorithm1_keeps_paper_picks_when_paper_tech_wins():
    """On every paper (cluster × model) where the paper pool's winner
    stands, the extended probe set must not flip the selection away
    from it arbitrarily — it only changes picks when an extended probe
    strictly wins its tier."""
    for cname in sorted(PAPER_CLUSTERS):
        for wl in (WL_M,):
            prober = CostModelProber(wl, PAPER_CLUSTERS[cname])
            base = select_technique(prober, delta=0.1)
            ext = select_technique(prober, delta=0.1, extended=True)
            if ext.technique in TECHNIQUES:
                assert (ext.technique, ext.vms) == (base.technique,
                                                    base.vms), cname


def test_bf16_carrier_flips_pipeshard_schedule():
    """The acceptance scenario (ISSUE 5): a 3-site A30 metro line whose
    3 GB/s WAN edges make the interleaved schedule's v-fold boundary
    crossings just too dear at fp32 carriers — GPipe wins.  Halving the
    wire bytes (carrier_dtype='bf16') flips the same cell's winning
    schedule to interleaved: the bubble saving now outruns the p2p
    bill."""
    topo = line("a30line3", _sites(3), [Link(1e-3, 3.0)] * 2)

    def best_all_site(carrier):
        s = PlanSearch(WL_M, topo, techniques=("pipeshard",),
                       carrier_dtype=carrier)
        return max((c for c in s.search()
                    if c.feasible and len(c.candidate.sites) == 3),
                   key=lambda c: c.tflops)

    fp32 = best_all_site("fp32")
    bf16 = best_all_site("bf16")
    assert fp32.candidate.schedule == "gpipe"
    assert bf16.candidate.schedule == "interleaved"
    assert bf16.tflops > fp32.tflops        # cheaper wire, faster plan
    # and the fp32 pricing is untouched by the knob's existence
    legacy = PlanSearch(WL_M, topo, techniques=("pipeshard",))
    assert legacy.evaluate(fp32.candidate) == fp32.tflops


def test_carrier_dtype_threads_through_probe_path():
    """The analytic Algorithm-1 probe path prices the search's carrier
    dtype too (same number as evaluate())."""
    topo = line("a30line3", _sites(3), [Link(1e-3, 3.0)] * 2)
    s = PlanSearch(WL_M, topo, carrier_dtype="bf16")
    for cand in s.candidates():
        if cand.technique == "pipeshard":
            assert s._probe("pipeshard",
                            s.placement(cand)) == s.evaluate(cand)
            break


# ------------------------------------------------------------------ #
# pruning: dominated-subset elimination + stage-order beam must be
# lossless for the best plan (the --exact escape hatch is the oracle)
# ------------------------------------------------------------------ #

def _best_by_technique(scored):
    out = {}
    for s in scored:
        if s.feasible:
            out.setdefault(s.candidate.technique, s.tflops)
    return out


def _assert_prune_lossless(search):
    exact = search.search(prune=False)
    pruned = search.search(prune=True)
    assert len(pruned) <= len(exact)
    ex_best = _best_by_technique(exact)
    pr_best = _best_by_technique(pruned)
    assert set(pr_best) == set(ex_best)
    for tech, tf in ex_best.items():
        assert pr_best[tech] == pytest.approx(tf, rel=1e-12), tech


def test_pruned_equals_exhaustive_on_example_topologies():
    topos = [edge3(),
             ring("r3", _sites(3),
                  [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)]),
             make_topology("het4", [Site(("A30", "A30")), Site(("T4", "T4")),
                                    Site(("RTX", "RTX")),
                                    Site(("A30", "A30"))],
                           {(0, 1): Link(1e-3, 3.0), (1, 2): Link(30e-3, 3.0),
                            (2, 3): Link(1e-3, 3.0),
                            (0, 3): Link(90e-3, 3.0)})]
    for topo in topos:
        for wl in (WL_M, WL_L):
            _assert_prune_lossless(PlanSearch(wl, topo))
            _assert_prune_lossless(
                PlanSearch(wl, topo, stage_balance="tflops"))


def test_pruned_equals_exhaustive_with_extended_pool():
    """The widened dominance test (fsdp's n-dependent memory and
    shard_zero's intra-site corners) keeps pruning lossless over the
    six-technique pool — incl. ragged per-site GPU counts, which only
    shard_zero's tp/intra terms can distinguish."""
    topos = [edge3(),
             ring("r3", _sites(3),
                  [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)]),
             line("lan3", _sites(3, gpu="T4"), [Link(0.1e-3, 3.0)] * 2),
             make_topology(
                 "rag4",
                 [Site(("A30", "A30", "A30", "A30")), Site(("T4", "T4")),
                  Site(("RTX", "RTX")), Site(("A30", "A30"))],
                 {(0, 1): Link(1e-3, 3.0), (1, 2): Link(30e-3, 3.0),
                  (2, 3): Link(1e-3, 3.0), (0, 3): Link(90e-3, 3.0)})]
    for topo in topos:
        for wl in (WL_M, WL_L):
            _assert_prune_lossless(
                PlanSearch(wl, topo, techniques=ALL_TECHNIQUES))
            _assert_prune_lossless(
                PlanSearch(wl, topo, techniques=ALL_TECHNIQUES,
                           carrier_dtype="bf16"))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 4),
       gpus=st.lists(st.sampled_from(["RTX", "T4", "A30"]),
                     min_size=4, max_size=4),
       lats=st.lists(st.floats(0.05, 150.0), min_size=6, max_size=6),
       shape=st.sampled_from(["full", "ring", "line"]))
def test_pruned_equals_exhaustive_property(n, gpus, lats, shape):
    """Pruned search == exhaustive search on random topologies, N <= 4
    (the acceptance gate: dominance is provably lossless and the default
    beam is exhaustive below 5 sites)."""
    sites = [Site((gpus[i], gpus[i]), name=f"S{i}") for i in range(n)]
    links = [Link(l * 1e-3, 3.0) for l in lats]
    if shape == "ring" and n >= 3:
        topo = ring("t", sites, links[:n])
    elif shape == "line":
        topo = line("t", sites, links[:n - 1])
    else:
        topo = make_topology("t", sites, {
            (i, j): links[(i * n + j) % len(links)]
            for i, j in itertools.combinations(range(n), 2)})
    for wl in (WL_M, WL_L):
        _assert_prune_lossless(PlanSearch(wl, topo))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 4),
       gpus=st.lists(st.sampled_from(["RTX", "T4", "A30"]),
                     min_size=4, max_size=4),
       per_site=st.lists(st.sampled_from([1, 2, 4]),
                         min_size=4, max_size=4),
       lats=st.lists(st.floats(0.05, 150.0), min_size=6, max_size=6),
       shape=st.sampled_from(["full", "ring", "line"]))
def test_pruned_equals_exhaustive_extended_property(n, gpus, per_site,
                                                    lats, shape):
    """Pruned == exhaustive over the six-technique pool on random
    topologies with ragged per-site GPU counts (the widened acceptance
    gate of ISSUE 5)."""
    sites = [Site((gpus[i],) * per_site[i], name=f"S{i}")
             for i in range(n)]
    links = [Link(l * 1e-3, 3.0) for l in lats]
    if shape == "ring" and n >= 3:
        topo = ring("t", sites, links[:n])
    elif shape == "line":
        topo = line("t", sites, links[:n - 1])
    else:
        topo = make_topology("t", sites, {
            (i, j): links[(i * n + j) % len(links)]
            for i, j in itertools.combinations(range(n), 2)})
    for wl in (WL_M, WL_L):
        _assert_prune_lossless(
            PlanSearch(wl, topo, techniques=ALL_TECHNIQUES))


def test_beam_stage_orders_exhaustive_below_five_sites():
    topo = make_topology("f4", _sites(4), {
        (i, j): Link((1 + i + j) * 1e-3, 3.0)
        for i, j in itertools.combinations(range(4), 2)})
    search = PlanSearch(WL_M, topo)
    for subset in [(0, 1), (0, 1, 2), (0, 1, 2, 3), (1, 2, 3)]:
        beam = search.beam_stage_orders(subset)
        assert set(beam) == set(stage_orders(subset))
    # beyond the beam: truncated to the cheapest, still canonical orders
    topo5 = make_topology("f5", _sites(5), {
        (i, j): Link((1 + i + j) * 1e-3, 3.0)
        for i, j in itertools.combinations(range(5), 2)})
    beam5 = PlanSearch(WL_M, topo5, beam_width=6).beam_stage_orders(
        tuple(range(5)))
    assert len(beam5) <= 6
    assert all(p[0] < p[-1] for p in beam5)


def test_beam_orders_ranked_by_boundary_cost():
    # asymmetric ring: the cheapest order crosses the two 5ms links
    topo = ring("r3", _sites(3),
                [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)])
    beam = PlanSearch(WL_L, topo).beam_stage_orders((0, 1, 2))
    assert beam[0] == (0, 1, 2)


def test_exact_escape_hatch_restores_full_enumeration():
    search = PlanSearch(WL_M, edge3())
    assert len(search.search(prune=False)) == 39
    assert len(PlanSearch(WL_M, edge3(), prune=False).search()) == 39
    assert len(PlanSearch(WL_M, edge3(), prune=False,
                          schedules=("gpipe",)).search()) == 27


# ------------------------------------------------------------------ #
# the wire_dtype axis (docs/quantization.md): quantized collective
# carriers as a search dimension
# ------------------------------------------------------------------ #

WIRE_POOL = ("fp32", "bf16", "int8")


def test_candidate_key_wire_suffix():
    assert Candidate("data", (0,), wire_dtype="fp32").key == "data@V1"
    assert Candidate("data", (0,), wire_dtype="int8").key == "data@V1~int8"
    c = Candidate("pipeshard", (0, 2), (2, 0), "1f1b", "int8")
    assert c.key == "pipeshard@V1+V3|V3>V1#1f1b~int8"


def test_wire_pool_scales_enumeration_uniformly():
    t = make_topology("f", _sites(3), {
        (i, j): Link(1e-3, 3.0)
        for i, j in itertools.combinations(range(3), 2)})
    base = list(PlanSearch(WL_M, t).candidates())
    wired = list(PlanSearch(WL_M, t, wire_dtypes=WIRE_POOL).candidates())
    # the wire pool multiplies the space; the fp32 slice is exactly the
    # legacy space (same order, so exact-tie stable sorts keep winners)
    assert len(wired) == 3 * len(base)
    assert [c.key for c in wired if c.wire_dtype == "fp32"] \
        == [c.key for c in base]
    with pytest.raises(ValueError):
        list(PlanSearch(WL_M, t, wire_dtypes=("fp32", "fp16")).candidates())


def test_int8_wire_flips_regional_a30_cell_to_pipeshard():
    """The acceptance gate (ISSUE 6): the paper's two-site A30 shape at
    the Table-I regional RTT (UTAH-GPN, 20.2 ms) picks single-site Data
    at fp32 wire — the 20 ms link makes every cross-WAN collective too
    dear.  Pricing int8 wire bytes (0.258x) shrinks Pipeshard's p2p +
    DP-stream bill enough that the two-site pipeline overtakes: the
    winner flips from ``data`` to ``pipeshard`` purely by widening the
    wire pool.  Reproduced by `benchmarks/topology_sweep.py --wire`."""
    topo = two_site("a30x2", ("A30", "A30"), ("A30", "A30"), 20.2)
    base = PlanSearch(WL_M, topo).best()
    assert base.candidate.key == "data@V1"
    wired = PlanSearch(WL_M, topo, wire_dtypes=WIRE_POOL).best()
    assert wired.candidate.key == "pipeshard@V1+V2~int8"
    assert wired.tflops > base.tflops
    # fp32 candidates inside the widened pool price bit-for-bit legacy
    s = PlanSearch(WL_M, topo, wire_dtypes=WIRE_POOL)
    assert s.evaluate(base.candidate) == base.tflops


def test_wire_dtype_prices_strictly_cheaper_on_wan():
    """For any WAN-crossing candidate, int8 wire must price <= bf16 <=
    fp32 (byte volume scales down monotonically; latency floors keep it
    from being strictly proportional)."""
    topo = two_site("a30x2", ("A30", "A30"), ("A30", "A30"), 20.2)
    s = PlanSearch(WL_M, topo, wire_dtypes=WIRE_POOL)
    for tech in ("data", "zero2", "pipeshard"):
        perf = {wd: s.evaluate(Candidate(
            tech, (0, 1), (0, 1) if tech == "pipeshard" else None,
            wire_dtype=wd)) for wd in WIRE_POOL}
        assert perf["int8"] > perf["bf16"] > perf["fp32"], tech


def test_pruned_equals_exhaustive_with_wire_pool():
    """Dominance pruning stays lossless when the wire pool widens the
    space: a wire dtype rescales every subset's byte terms uniformly and
    never touches latency or compute, so subset dominance is preserved
    per dtype."""
    topos = [edge3(),
             ring("r3", _sites(3),
                  [Link(5e-3, 3.0), Link(5e-3, 3.0), Link(120e-3, 3.0)]),
             line("lan3", _sites(3, gpu="T4"), [Link(0.1e-3, 3.0)] * 2)]
    for topo in topos:
        for wl in (WL_M, WL_L):
            _assert_prune_lossless(
                PlanSearch(wl, topo, wire_dtypes=WIRE_POOL))
            _assert_prune_lossless(
                PlanSearch(wl, topo, techniques=ALL_TECHNIQUES,
                           wire_dtypes=WIRE_POOL))
