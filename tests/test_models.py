"""Per-architecture smoke tests (REDUCED variants per the assignment: 2
layers, d_model<=512, <=4 experts): one forward/train step on CPU asserting
output shapes + no NaNs, plus forward/prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models import Model
from repro.models.registry import input_specs

SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _setup(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = input_specs(cfg, SHAPE, abstract=False,
                        rng=np.random.default_rng(0))
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params, batch = _setup(arch)
    logits, aux = model.forward(params, batch, remat=False)
    B = SHAPE.global_batch
    s_text = batch["tokens"].shape[1]
    exp_len = s_text + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step_no_nan(arch):
    cfg, model, params, batch = _setup(arch)

    def loss_fn(p):
        return model.loss(p, batch, remat=True)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """serve_step(prefill(x[:-1])) must reproduce forward(x) logits."""
    cfg, model, params, batch = _setup(arch)
    full_logits, _ = model.forward(params, batch, remat=False)
    cache = model.init_cache(SHAPE.global_batch, 64)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :-1]
    lg_pre, cache = model.prefill(params, pre_batch, cache)
    lg_dec, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, -1:])
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(full_logits[:, -2]),
                               atol=0.08, rtol=0.05)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(full_logits[:, -1]),
                               atol=0.08, rtol=0.05)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode must agree with full-cache decode once both see the
    same (recent) context, while using a bounded cache."""
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              sliding_window=16)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, prompt_len, n_gen = 2, 24, 4
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt_len)),
                       jnp.int32)
    # windowed path
    cache_w = model.init_cache(B, 1024, window=cfg.sliding_window)
    assert cache_w.k.shape[2] == cfg.sliding_window
    lg_w, cache_w = model.prefill(params, {"tokens": toks}, cache_w,
                                  window=cfg.sliding_window)
    # ring buffer holds exactly the last `window` tokens
    assert int(cache_w.index[0]) == prompt_len
    for _ in range(n_gen):
        nxt = jnp.argmax(lg_w, -1)[:, None].astype(jnp.int32)
        lg_w, cache_w = model.decode_step(params, cache_w, nxt,
                                          window=cfg.sliding_window)
    assert bool(jnp.isfinite(lg_w).all())


def test_mla_latent_cache_is_compressed():
    """MLA decode cache stores the latent (kv_lora + rope), not full K/V."""
    cfg = get_config("minicpm3-4b").reduced()
    model = Model(cfg)
    cache = model.init_cache(2, 64)
    # stacked [L, B, S, R]; R = kv_lora_rank << n_heads * head_dim
    assert cache.c_kv.shape[-1] == cfg.mla.kv_lora_rank
    assert cache.k_rope.shape[-1] == cfg.mla.rope_head_dim
    full_kv = 2 * cfg.n_kv_heads * cfg.head_dim
    assert cache.c_kv.shape[-1] + cache.k_rope.shape[-1] < full_kv


def test_moe_aux_loss_range():
    """Load-balance aux: E * sum f_e p_e in [1, E] => aux in
    [coef, E*coef] per layer (near-uniform routing at init)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = input_specs(cfg, SHAPE, abstract=False,
                        rng=np.random.default_rng(2))
    _, metrics = model.loss(params, batch, remat=False)
    coef = cfg.moe.router_aux_coef
    aux = float(metrics["aux"])
    assert 2 * coef * 0.9 <= aux <= 2 * coef * cfg.moe.n_experts
