"""Docs integrity: internal links resolve and every `repro.*` symbol
referenced in README/DESIGN/docs exists in the package (the same checks
CI's docs-and-benchmarks job runs via tools/check_docs.py)."""
import importlib.util
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    for f in ("README.md", "docs/index.md", "docs/architecture.md",
              "docs/topology-and-search.md", "docs/benchmarks.md",
              "docs/schedules.md"):
        assert os.path.isfile(os.path.join(ROOT, f)), f


def test_schedule_page_is_symbol_checked():
    """docs/schedules.md is covered by the checker's file walk, so a
    symbol typo there fails tests the same as any other page."""
    checker = _load_checker()
    files = checker.doc_files(ROOT)
    assert os.path.join(ROOT, "docs", "schedules.md") in files
    # and the figures it embeds exist (the link check enforces this)
    for fig in ("schedule_steptime_full.svg", "schedule_memory_full.svg"):
        assert os.path.isfile(os.path.join(ROOT, "docs", "figs", fig))


def test_docs_links_and_symbols_resolve():
    checker = _load_checker()
    errors = checker.check_all(ROOT)
    assert errors == []


def test_checker_catches_breakage(tmp_path):
    """The checker itself must actually detect problems."""
    checker = _load_checker()
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[dead](docs/nope.md) and `repro.core.search.NoSuchThing` "
        "and `benchmarks/nope.py`\n")
    errors = checker.check_all(str(tmp_path))
    assert len(errors) == 3
