"""Int8 quantization: kernels/quantized.py vs the fp32 oracles in
kernels/ref.py (interpret mode on CPU), the absmax round-trip error
contract, and the quantized KV-cache serving path (docs/quantization.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prophelpers import given, settings, st

from repro.kernels import ops, ref


def _mk(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32), dtype)


# ------------------------------------------------------------------ #
# absmax quantize / dequantize round trip
# ------------------------------------------------------------------ #

def test_quantize_shapes_and_blocking():
    rng = np.random.default_rng(0)
    x = _mk(rng, (6, 70))
    q, s = ops.quantize(x, block=32, axis=-1)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.dtype == jnp.float32 and s.shape == (6, 3)   # ceil(70/32)
    back = ops.dequantize(q, s, block=32, axis=-1)
    # per-element error <= its block's scale / 2 (round-to-nearest)
    scale_full = np.asarray(ops.dequantize(
        jnp.ones_like(q), s, block=32, axis=-1))
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= scale_full * 0.5 + 1e-7)


def test_quantize_zero_block_is_exact():
    x = jnp.zeros((4, 64))
    q, s = ops.quantize(x, block=32)
    assert np.all(np.asarray(s) == 1.0)          # zero blocks: scale 1.0
    assert np.all(np.asarray(ops.dequantize(q, s, block=32)) == 0.0)


def test_quantize_non_last_axis():
    rng = np.random.default_rng(1)
    x = _mk(rng, (40, 3, 5))
    q, s = ops.quantize(x, block=16, axis=0)
    assert q.shape == x.shape and s.shape == (3, 3, 5)
    back = ops.dequantize(q, s, block=16, axis=0)
    bound = float(jnp.max(jnp.abs(x))) / 254.0 + 1e-7
    assert float(jnp.max(jnp.abs(back - x))) <= bound


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 40), cols=st.integers(1, 90),
       block=st.sampled_from([8, 16, 32, 128]),
       scale_pow=st.integers(-3, 3),
       seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_property(rows, cols, block, scale_pow, seed):
    """Property: |x - deq(quant(x))| <= absmax / 254 globally, at any
    magnitude (the per-block bound is tighter; this one always holds
    because block absmax <= global absmax)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)).astype(np.float32)
                    * (10.0 ** scale_pow))
    q, s = ops.quantize(x, block=block)
    back = ops.dequantize(q, s, block=block)
    bound = float(jnp.max(jnp.abs(x))) / 254.0 * (1 + 1e-6) + 1e-12
    assert float(jnp.max(jnp.abs(back - x))) <= bound


# ------------------------------------------------------------------ #
# int8 blocked matmul vs the fp32 oracle
# ------------------------------------------------------------------ #

MM_CASES = [
    # (M, K, N, block)
    (64, 64, 64, 32),
    (128, 128, 128, 128),      # single tile per grid cell
    (100, 70, 52, 32),         # every dim pads
    (30, 20, 10, 16),          # tiny, all-pad path
]


@pytest.mark.parametrize("M,K,N,blk", MM_CASES)
def test_int8_matmul_error_bound(M, K, N, blk):
    rng = np.random.default_rng(2)
    x = _mk(rng, (M, K))
    w = _mk(rng, (K, N))
    out = np.asarray(ops.int8_matmul(x, w, block_m=blk, block_k=blk,
                                     block_n=blk, interpret=True))
    want = np.asarray(ref.matmul_ref(x, w))
    rel = np.linalg.norm(out - want) / np.linalg.norm(want)
    assert rel < 0.02, rel


def test_int8_matmul_matches_explicit_dequant():
    """The kernel must equal the same quantized operands multiplied in
    fp32 after dequantization — the scales are applied per K block, not
    once at the end."""
    from repro.kernels.quantized import quantize_blocks
    rng = np.random.default_rng(3)
    x = _mk(rng, (64, 96))
    w = _mk(rng, (96, 64))
    out = np.asarray(ops.int8_matmul(x, w, block_m=32, block_k=32,
                                     block_n=32, interpret=True))
    xq, xs = quantize_blocks(x, 32, 32)
    wq, ws = quantize_blocks(w, 32, 32)
    xd = np.asarray(xq, np.float32).reshape(2, 32, 3, 32) \
        * np.asarray(xs)[:, None, :, None]
    wd = np.asarray(wq, np.float32).reshape(3, 32, 2, 32) \
        * np.asarray(ws)[:, None, :, None]
    want = xd.reshape(64, 96) @ wd.reshape(96, 64)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-5)


# ------------------------------------------------------------------ #
# int8-KV flash attention
# ------------------------------------------------------------------ #

def _quant_tokens(x):
    """[B, S, KV, D] -> (int8, scales [B, S, KV]) per-token over head dim."""
    q, s = ops.quantize(x, block=x.shape[-1], axis=-1)
    return q, s


KV_CASES = [
    # (B, S, H, KV, D, causal, window)
    (2, 64, 4, 2, 32, True, 0),
    (1, 40, 2, 2, 16, True, 0),       # Sk % block_k != 0 => pad path
    (2, 96, 8, 2, 48, True, 32),      # GQA + window
    (1, 40, 2, 1, 32, False, 0),      # non-causal + pad: the mask matters
]


@pytest.mark.parametrize("B,S,H,KV,D,causal,window", KV_CASES)
def test_int8kv_attention_vs_dequant_ref(B, S, H, KV, D, causal, window):
    """Near-exact vs attention_ref over the dequantized k/v — isolates
    the kernel from the quantization error."""
    rng = np.random.default_rng(4)
    q = _mk(rng, (B, S, H, D))
    k = _mk(rng, (B, S, KV, D))
    v = _mk(rng, (B, S, KV, D))
    kq, ks = _quant_tokens(k)
    vq, vs = _quant_tokens(v)
    out = ops.flash_attention_int8kv(
        q, kq, ks[..., 0], vq, vs[..., 0], causal=causal, window=window,
        block_q=32, block_k=32, interpret=True)
    kd = ops.dequantize(kq, ks, block=D, axis=-1)
    vd = ops.dequantize(vq, vs, block=D, axis=-1)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), kd.transpose(0, 2, 1, 3),
        vd.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_int8kv_attention_cosine_gate():
    """End-to-end quantization error: outputs stay within cosine 0.999
    of the pure-fp32 attention."""
    rng = np.random.default_rng(5)
    B, S, H, D = 2, 64, 4, 32
    q = _mk(rng, (B, S, H, D))
    k = _mk(rng, (B, S, H, D))
    v = _mk(rng, (B, S, H, D))
    kq, ks = _quant_tokens(k)
    vq, vs = _quant_tokens(v)
    out = np.asarray(ops.flash_attention_int8kv(
        q, kq, ks[..., 0], vq, vs[..., 0], causal=True,
        block_q=32, block_k=32, interpret=True)).reshape(-1)
    pure = np.asarray(ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True).transpose(0, 2, 1, 3)).reshape(-1)
    cos = np.dot(out, pure) / (np.linalg.norm(out) * np.linalg.norm(pure))
    assert cos > 0.999, cos


def test_int8kv_valid_mask_truncates_keys():
    """The dynamic validity input must reproduce attention over the
    truncated key set — the decode ring-cache contract (non-causal, a
    traced number of live slots)."""
    rng = np.random.default_rng(6)
    B, S, H, D, live = 1, 48, 2, 16, 33
    q = _mk(rng, (B, S, H, D))
    k = _mk(rng, (B, S, H, D))
    v = _mk(rng, (B, S, H, D))
    kq, ks = _quant_tokens(k)
    vq, vs = _quant_tokens(v)
    valid = jnp.asarray(
        (np.arange(S) < live)[None].astype(np.float32))
    out = ops.flash_attention_int8kv(
        q, kq, ks[..., 0], vq, vs[..., 0], valid=valid, causal=False,
        block_q=16, block_k=16, interpret=True)
    kd = ops.dequantize(kq, ks, block=D, axis=-1)
    vd = ops.dequantize(vq, vs, block=D, axis=-1)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), kd[:, :live].transpose(0, 2, 1, 3),
        vd[:, :live].transpose(0, 2, 1, 3),
        causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal_pad_regression():
    """Regression (ISSUE 6 satellite): ops.flash_attention with
    causal=False and Sk % block_k != 0 must mask the padded keys — the
    causal mask no longer hides them."""
    rng = np.random.default_rng(7)
    B, S, H, D = 1, 40, 2, 16           # 40 % 32 != 0
    q = _mk(rng, (B, S, H, D))
    k = _mk(rng, (B, S, H, D))
    v = _mk(rng, (B, S, H, D))
    out = ops.flash_attention(q, k, v, causal=False, block_q=32,
                              block_k=32, interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ #
# the quantized KV-cache serving path (models/attention.py + serve)
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.models import Model
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              vocab_size=512)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def test_quant_cache_ring_append():
    from repro.models.attention import (init_quant_kv_cache,
                                        quant_cache_append)
    cache = init_quant_kv_cache(1, 4, 1, 8, 8)
    assert cache.capacity == 4
    for t in range(6):
        k = jnp.full((1, 1, 1, 8), float(t + 1))
        cache = quant_cache_append(cache, k, k)
    assert int(cache.index) == 6
    # ring layout: slot s holds the latest token with pos % 4 == s
    deq = np.asarray(ops.dequantize(
        cache.k_q, cache.k_scale[..., None], block=8, axis=-1))
    np.testing.assert_allclose(deq[0, :, 0, 0], [5.0, 6.0, 3.0, 4.0],
                               rtol=1e-6)
    assert bool(np.all(np.asarray(cache.valid(1))))


def test_quant_cache_decode_matches_fp(tiny_model):
    """The int8-KV decode guard: greedy tokens must match the fp cache
    path exactly and per-step logits stay within a small delta (the
    serving-quality gate; docs/quantization.md)."""
    model, params = tiny_model
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(4, 400, (2, 12)), jnp.int32)
    c_fp = model.init_cache(2, 48)
    c_q = model.init_cache(2, 48, kv_dtype="int8")
    lg_fp, c_fp = model.prefill(params, {"tokens": toks}, c_fp)
    lg_q, c_q = model.prefill(params, {"tokens": toks}, c_q)
    # prefill logits come from full attention, identical by construction
    np.testing.assert_array_equal(np.asarray(lg_fp), np.asarray(lg_q))
    tok = jnp.argmax(lg_fp, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        lf, c_fp = model.decode_step(params, c_fp, tok)
        lq, c_q = model.decode_step(params, c_q, tok)
        assert float(jnp.max(jnp.abs(lf - lq))) < 0.25
        nf = jnp.argmax(lf, -1)
        nq = jnp.argmax(lq, -1)
        np.testing.assert_array_equal(np.asarray(nf), np.asarray(nq))
        tok = nf[:, None].astype(jnp.int32)


def test_init_cache_kv_dtype_gates(tiny_model):
    from repro.configs import get_config
    from repro.models import Model
    model, _ = tiny_model
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        model.init_cache(1, 8, kv_dtype="int4")
    for arch in ("falcon-mamba-7b", "minicpm3-4b"):
        m = Model(get_config(arch).reduced())
        with pytest.raises(ValueError, match="plain-GQA"):
            m.init_cache(1, 8, kv_dtype="int8")


def test_engine_int8_kv(tiny_model):
    """End-to-end: the Engine carries the quantized cache through the
    compiled prefill/serve steps and generates the same greedy tokens."""
    from repro.core.plans import get_plan
    from repro.launch.mesh import make_host_mesh
    from repro.serve import Engine
    model, params = tiny_model
    mesh = make_host_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    prompts = np.asarray(rng.integers(4, 400, (2, 12)), np.int32)
    out_fp = Engine(model, get_plan("data"), mesh, batch_size=2,
                    max_len=48).generate(
                        params, {"tokens": prompts}, n_tokens=5)
    out_q = Engine(model, get_plan("data"), mesh, batch_size=2,
                   max_len=48, kv_dtype="int8").generate(
                       params, {"tokens": prompts}, n_tokens=5)
    np.testing.assert_array_equal(out_fp["tokens"], out_q["tokens"])
