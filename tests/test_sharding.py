"""Sharding rule-engine tests: spec resolution per architecture and the
divisibility invariant (hypothesis)."""
import jax
import numpy as np
import pytest
from prophelpers import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import sharding as shardlib
from repro.core.plans import get_plan
from repro.models import Model

AXIS_SIZES = {"data": 16, "model": 16}


def _shapes(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    return cfg, jax.eval_shape(lambda: model.init(jax.random.key(0)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_specs_divide_for_every_arch(arch):
    """Every emitted PartitionSpec must divide its dim by the axis size —
    the invariant jit in_shardings enforce (minicpm3's 40 heads et al.)."""
    cfg, shapes = _shapes(arch)
    plan = get_plan("shard")
    amap = plan.axis_map(mesh=_FakeMesh())
    specs = shardlib.param_specs(shapes, amap, cfg.family, AXIS_SIZES)

    def check(leaf, spec):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([AXIS_SIZES[a] for a in axes]))
            assert leaf.shape[i] % size == 0, (leaf.shape, spec)

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # sanity: at least one leaf is actually sharded for each arch
    n_sharded = sum(
        1 for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        if isinstance(s, P) and any(e is not None for e in s))
    assert n_sharded > 0, arch


class _FakeMesh:
    axis_names = ("data", "model")
    shape = AXIS_SIZES


def test_expert_weights_sharded_on_expert_axis():
    cfg, shapes = _shapes("phi3.5-moe-42b-a6.6b")
    # full config: 16 experts over 16-way model axis
    cfg_full = get_config("phi3.5-moe-42b-a6.6b")
    model = Model(cfg_full)
    shapes_full = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = shardlib.param_specs(shapes_full,
                                 get_plan("shard").axis_map(_FakeMesh()),
                                 "moe", AXIS_SIZES)
    spec = specs["layers"]["moe"]["w_gate"]
    assert spec[1] == "model", spec   # [L, E, d, ff] -> expert dim sharded


def test_nondivisible_heads_fall_back_to_replication():
    """minicpm3: 40 heads on a 16-way axis must NOT shard the head dim
    (contraction-dim sharding all-reduces every score block)."""
    cfg_full = get_config("minicpm3-4b")
    model = Model(cfg_full)
    shapes_full = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = shardlib.param_specs(shapes_full,
                                 get_plan("shard").axis_map(_FakeMesh()),
                                 "dense", AXIS_SIZES)
    spec = specs["layers"]["mla"]["w_uq"]    # [L, q_in, 40, dims]
    assert all(e is None for e in spec), spec


@settings(max_examples=50, deadline=None)
@given(
    dim0=st.integers(1, 300),
    dim1=st.integers(1, 300),
    axes_size=st.sampled_from([2, 4, 8, 16]),
)
def test_zero_spec_divisibility_property(dim0, dim1, axes_size):
    """zero_specs never emits a spec whose dim doesn't divide."""
    leaf = jax.ShapeDtypeStruct((dim0, dim1), np.float32)
    spec = shardlib.largest_dim_spec(leaf, ("data",), axes_size)
    for i, entry in enumerate(spec):
        if entry is not None:
            assert leaf.shape[i] % axes_size == 0


@settings(max_examples=30, deadline=None)
@given(batch=st.sampled_from([1, 2, 8, 32, 128, 256, 100, 7]))
def test_batch_axes_always_divide(batch):
    """plan.batch_axes product always divides the global batch."""
    mesh = _FakeMesh()
    for plan_name in ("data", "zero2", "shard"):
        plan = get_plan(plan_name)
        axes = plan.batch_axes(mesh, batch)
        prod = int(np.prod([AXIS_SIZES[a] for a in axes])) if axes else 1
        assert batch % prod == 0
