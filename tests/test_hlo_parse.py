"""HLO collective-parse tests: trip-count handling on synthetic HLO and on
a real compiled module."""
import textwrap

from repro.launch.hlo_parse import (collective_bytes_with_trips,
                                    parse_computations)

SYNTH = textwrap.dedent("""\
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(%a, %b)
    }

    %body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
      %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[128])) -> pred[] {
      %c = s32[] constant(12)
      ROOT %cmp = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[256]) -> f32[256] {
      %ag = f32[256]{0} all-gather(%a), replica_groups={}
      %w = (s32[], f32[128]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[256]{0} copy(%ag)
    }
    """)


def test_synthetic_trip_counts():
    res = collective_bytes_with_trips(SYNTH)
    # all-gather outside the loop: 256*4 bytes, once
    assert res["all-gather"] == 256 * 4
    # all-reduce inside the 12-trip while: 128*4*12
    assert res["all-reduce"] == 128 * 4 * 12


def test_parse_computations_structure():
    comps, entry = parse_computations(SYNTH)
    assert entry == "%main"
    assert comps["%cond.1"].max_const == 12
    assert comps["%main"].whiles == [("%cond.1", "%body.1")]


def test_real_module_scaling_with_depth():
    """Collective bytes must scale ~linearly with scan length."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("model",))

    def make(n):
        def f(w, x):
            def body(h, wl):
                y = jnp.tanh(h @ wl)
                y = jax.lax.with_sharding_constraint(y, P())
                return y, None
            return jnp.sum(jax.lax.scan(body, x, w)[0])
        return f

    sizes = {}
    with jax.set_mesh(mesh):
        for n in (4, 8):
            c = jax.jit(make(n)).lower(
                jax.ShapeDtypeStruct((n, 64, 64), jnp.float32),
                jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
            res = collective_bytes_with_trips(c.as_text())
            sizes[n] = sum(v for k, v in res.items()
                           if not k.startswith("_"))
    # single-device: no collectives — but the parser must not crash and
    # totals must be consistent (0 == 0)
    assert sizes[4] == sizes[8] == 0
