"""repro.analysis (ISSUE 8 tentpole): each pass must stay clean on the
real tree AND fire on seeded violations — a detector that never fires
is indistinguishable from one that is broken, so every rule gets a
negative test.  The donation fixtures under tests/analysis_fixtures/
reproduce the PR-7 ``reshard_check`` bug (control run reading buffers
the resharded run donated) and its ``host_copy`` fix.

The pure cores (``schedlint.check_tables``, ``planlint.check_registry``
/ ``check_specs``, ``conventions.check_units`` / ``check_excepts``)
take data in and return problems out, so corruption is a dict edit,
not a monkeypatch.  CLI / baseline round-trips run ``__main__.main``
in-process against a temp root.
"""
import ast
import copy
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import (Baseline, Finding, PASSES, RULES, repo_root,
                            run_passes)
from repro.analysis import conventions, donatecheck, planlint, schedlint
from repro.analysis.__main__ import main as cli_main
from repro.core.pipeline import banked_slot, schedule_tables
from repro.core.plans import PLANS, MeshSpec
from repro.core.costmodel import TECHNIQUE_SPECS
from repro.models.registry import abstractify

ROOT = repo_root()
FIXTURES = os.path.join("tests", "analysis_fixtures")


def rules_of(problems):
    """{rule, ...} from (rule, msg) pairs or Finding lists."""
    return {p[0] if isinstance(p, tuple) else p.rule for p in problems}


# ---------------------------------------------------------------- schedlint

def test_schedlint_full_acceptance_grid_is_sound():
    """The ISSUE 8 guarantee: every schedule over S in 1..4, m in 1..8
    (v in 1..3 via the interleaved variants) verifies clean."""
    checked = 0
    for sched in schedlint.GRID_SCHEDULES:
        for S in schedlint.GRID_S:
            for m in schedlint.GRID_M:
                tables = schedule_tables(sched, S, m)
                assert schedlint.check_tables(tables, sched, S, m) == [], \
                    f"{sched} S={S} m={m}"
                checked += 1
    assert checked == 128


def test_schedlint_run_on_tree_is_clean():
    res = schedlint.run(ROOT)
    assert res.findings == []
    assert res.stats["cells_checked"] == 128


def _corrupt(sched, S, m, mutate):
    tables = {k: v.copy() for k, v in schedule_tables(sched, S, m).items()}
    mutate(tables)
    return schedlint.check_tables(tables, sched, S, m)


def test_schedlint_dropped_arrival_fires():
    def drop(t):
        live = np.argwhere(t["arr_valid"])
        s, tick = live[len(live) // 2]
        t["arr_valid"][s, tick] = False
    probs = _corrupt("gpipe", 3, 4, drop)
    assert "SCHED003" in rules_of(probs) or "SCHED004" in rules_of(probs)


def test_schedlint_mislabeled_chunk_fires():
    def mislabel(t):
        live = np.argwhere(t["arr_valid"])
        s, tick = live[0]
        t["arr_chunk"][s, tick] += 1
    probs = _corrupt("interleaved2", 2, 3, mislabel)
    assert "SCHED004" in rules_of(probs)


def test_schedlint_dropped_run_slot_fires():
    def drop(t):
        assert t["active"][2, 2]
        t["active"][2, 2] = False
    probs = _corrupt("1f1b", 4, 4, drop)
    assert "SCHED001" in rules_of(probs)


def test_schedlint_out_of_range_slot_fires():
    def blow(t):
        assert t["active"][0, 0]
        t["mb"][0, 0] = 9
    probs = _corrupt("gpipe", 2, 2, blow)
    assert "SCHED002" in rules_of(probs)


def test_schedlint_double_run_fires():
    def dup(t):
        # stage 0's second tick re-runs microbatch 0
        assert t["active"][0, 1]
        t["mb"][0, 1] = 0
    probs = _corrupt("gpipe", 2, 3, dup)
    assert "SCHED001" in rules_of(probs)


def test_schedlint_tick_formula_fires():
    def pad(t):
        for k in t:
            pad_col = np.zeros((t[k].shape[0], 1), t[k].dtype)
            t[k] = np.concatenate([t[k], pad_col], axis=1)
    probs = _corrupt("gpipe", 2, 4, pad)
    assert "SCHED005" in rules_of(probs)


def test_banked_slot_is_last_stage_last_chunk():
    assert banked_slot(3, 0, 4)                 # v=1: last stage banks
    assert not banked_slot(2, 0, 4)
    assert banked_slot(3, 1, 4, virt=2)         # v=2: only the last chunk
    assert not banked_slot(3, 0, 4, virt=2)
    assert banked_slot(0, 0, 1)                 # S=1: everything banks


# ----------------------------------------------------------------- planlint

def test_plan_registry_drift_fires_both_ways():
    assert planlint.check_registry(["dp", "pp"], ["dp", "pp"]) == []
    priced_only = planlint.check_registry(["dp", "pp"], ["dp"])
    assert len(priced_only) == 1 and priced_only[0][1] == "priced-only"
    exec_only = planlint.check_registry(["dp"], ["dp", "pp"])
    assert len(exec_only) == 1 and exec_only[0][1] == "executable-only"
    assert planlint.check_registry(sorted(TECHNIQUE_SPECS),
                                   sorted(PLANS)) == []


def _spec_case(spec, shape=(8, 16), mesh=None):
    mesh = mesh or MeshSpec.of((2, 2), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct(shape, jnp.float32)}
    return planlint.check_specs(shapes, {"w": spec}, mesh, "t")


def test_check_specs_clean_and_negatives():
    assert _spec_case(P("data", "model")) == []
    assert _spec_case(P(None, ("data", "model"))) == []
    assert any("names axis" in p for p in _spec_case(P("tensor")))
    assert any("reuses" in p for p in _spec_case(P("data", "data")))
    assert any("not divisible" in p
               for p in _spec_case(P("data"), shape=(7, 16)))
    assert any("more entries" in p
               for p in _spec_case(P("data", None, "model"), shape=(8,)))
    mesh = MeshSpec.of((2, 2), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct((8,), jnp.float32),
              "b": jax.ShapeDtypeStruct((2,), jnp.float32)}
    bad = planlint.check_specs(shapes, {"w": P()}, mesh, "t")
    assert any("leaves but" in p for p in bad)


def test_mesh_spec_duck_types_like_a_mesh():
    ms = MeshSpec.of((2, 4), ("stage", "model"))
    assert ms.axis_names == ("stage", "model")
    assert ms.shape == {"stage": 2, "model": 4}
    assert ms.size == 8
    with pytest.raises(ValueError):
        MeshSpec.of((2,), ("a", "b"))


# -------------------------------------------------------------- donatecheck

@pytest.fixture(scope="module")
def fixture_findings():
    findings, stats = donatecheck.analyze(ROOT, rel_dirs=(FIXTURES,))
    assert stats["donating_factories"] >= 1
    assert stats["donating_wrappers"] >= 1
    return findings


def test_donatecheck_reproduces_pr7_reshard_bug(fixture_findings):
    """donate_bad.run_place is the PR-7 reshard_check bug: the control
    run reads params/opt the resharded run's train() call donated."""
    hits = [f for f in fixture_findings
            if f.rule == "DON001" and "donate_bad" in f.file
            and "train()" in f.message]
    assert len(hits) == 2, [f.render() for f in fixture_findings]
    assert {f.line for f in hits} == {28}


def test_donatecheck_loop_without_rebind_fires(fixture_findings):
    hits = [f for f in fixture_findings
            if f.rule == "DON001" and f.line == 36]
    assert len(hits) == 2
    assert all("loop" in f.message for f in hits)


def test_donatecheck_double_slot_fires(fixture_findings):
    assert any(f.rule == "DON002" and f.line == 43
               for f in fixture_findings)


def test_donatecheck_non_literal_argnums_fires(fixture_findings):
    assert any(f.rule == "DON003" for f in fixture_findings)


def test_donatecheck_fixed_code_passes(fixture_findings):
    """The host_copy twin of the bug is clean — the fix pattern that
    landed in launch/reshard_check.py really is what the rule accepts."""
    assert [f for f in fixture_findings if "donate_good" in f.file] == []


def test_donatecheck_tree_is_clean():
    res = donatecheck.run(ROOT)
    assert res.findings == [], [f.render() for f in res.findings]
    # the real donation surfaces must be in the model, or the pass
    # proves nothing about the tree
    assert res.stats["donating_factories"] >= 2
    assert res.stats["donating_wrappers"] >= 2


# -------------------------------------------------------------- conventions

@pytest.fixture(scope="module")
def conv_tree():
    path = os.path.join(ROOT, FIXTURES, "conv_bad.py")
    with open(path) as f:
        return ast.parse(f.read())


def test_check_units_flags_only_cross_unit_arithmetic(conv_tree):
    lines = {line for line, _ in conventions.check_units(conv_tree)}
    assert lines == {10, 12}                    # s+bytes, ms-gbps


def test_check_excepts_flags_only_swallowers(conv_tree):
    lines = {line for line, _ in conventions.check_excepts(conv_tree)}
    assert lines == {22, 29}                    # return None / pass


def test_conventions_tree_is_clean():
    res = conventions.run(ROOT)
    assert res.findings == [], [f.render() for f in res.findings]
    assert res.stats["techniques_checked"] == len(TECHNIQUE_SPECS)


# ----------------------------------------------------- baseline + CLI

def _f(rule="DON001", file="src/x.py", msg="buffer 'p' reused"):
    return Finding(rule, "error", file, 1, msg)


def test_baseline_split_new_accepted_stale():
    b = Baseline([
        {"rule": "DON001", "file": "src/x.py", "match": "reused",
         "justification": "known"},
        {"rule": "CONV001", "file": "src/y.py", "match": "never",
         "justification": "stale"},
    ], path="tools/analysis_baseline.json")
    new, accepted, stale = b.split([_f(), _f(file="src/z.py")])
    assert [f.file for f in new] == ["src/z.py"]
    assert [f.file for f in accepted] == ["src/x.py"]
    assert [f.rule for f in stale] == ["BASE001"]
    assert "CONV001" in stale[0].message


def test_baseline_load_rejects_incomplete_entries(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(
        {"accepted": [{"rule": "DON001", "file": "src/x.py"}]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(str(p))
    p.write_text(json.dumps({"accepted": []}))
    assert Baseline.load(str(p)).entries == []
    assert Baseline.load(str(tmp_path / "missing.json")).entries == []


def test_checked_in_baseline_parses():
    b = Baseline.load(os.path.join(ROOT, "tools",
                                   "analysis_baseline.json"))
    for e in b.entries:
        assert e["rule"] in RULES


SEEDED_BUG = '''\
import jax

def run(model, params, opt, batch):
    step = jax.jit(model.step, donate_argnums=(0, 1))
    out = step(params, opt, batch)
    return params
'''


@pytest.fixture()
def seeded_root(tmp_path):
    """A minimal repo root whose src/ holds one donation bug."""
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "buggy.py").write_text(SEEDED_BUG)
    (tmp_path / "tools").mkdir()
    return tmp_path


def test_cli_fails_on_seeded_violation(seeded_root, capsys):
    out = seeded_root / "report.json"
    rc = cli_main(["--root", str(seeded_root), "--passes", "donatecheck",
                   "--format", "json", "--out", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["summary"]["new"] == 1
    assert report["findings"][0]["rule"] == "DON001"
    assert not report["findings"][0]["baselined"]
    assert json.loads(capsys.readouterr().out)["exit_code"] == 1


def test_cli_baselined_violation_passes(seeded_root, capsys):
    base = seeded_root / "tools" / "analysis_baseline.json"
    base.write_text(json.dumps({"accepted": [
        {"rule": "DON001", "file": "src/buggy.py", "match": "donated",
         "justification": "seeded fixture for the CLI test"}]}))
    rc = cli_main(["--root", str(seeded_root), "--passes", "donatecheck"])
    assert rc == 0
    assert "baselined: seeded fixture" in capsys.readouterr().out


def test_cli_stale_baseline_entry_fails(seeded_root, capsys):
    (seeded_root / "src" / "buggy.py").write_text("x = 1\n")
    base = seeded_root / "tools" / "analysis_baseline.json"
    base.write_text(json.dumps({"accepted": [
        {"rule": "DON001", "file": "src/buggy.py", "match": "donated",
         "justification": "now stale"}]}))
    rc = cli_main(["--root", str(seeded_root), "--passes", "donatecheck"])
    assert rc == 1
    assert "BASE001" in capsys.readouterr().out


def test_cli_baseline_none_ignores_checked_in_file(seeded_root):
    base = seeded_root / "tools" / "analysis_baseline.json"
    base.write_text(json.dumps({"accepted": [
        {"rule": "DON001", "file": "src/buggy.py", "match": "donated",
         "justification": "would mask it"}]}))
    rc = cli_main(["--root", str(seeded_root), "--passes", "donatecheck",
                   "--baseline", "none", "--format", "json"])
    assert rc == 1


def test_rules_catalog_covers_every_emitted_rule():
    prefixes = ("PLAN", "SCHED", "DON", "CONV", "BASE")
    assert all(r.startswith(prefixes) for r in RULES)
    assert set(PASSES) == {"planlint", "schedlint", "donatecheck",
                           "conventions"}


def test_full_cli_is_clean_on_tree(capsys):
    """The acceptance gate CI runs: all four passes, checked-in
    baseline, exit 0.  planlint abstract-traces every candidate of both
    scenarios — device-free, so this stays a few seconds."""
    rc = cli_main(["--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0, report["findings"]
    assert report["summary"]["new"] == 0
    assert set(report["passes"]) == set(PASSES)
    assert report["passes"]["planlint"]["stats"]["candidates"] > 100


# ------------------------------------------------- abstractify (satellite 2)

def test_abstractify_matches_eval_shape_closure():
    tree = {"w": jnp.ones((4, 8), jnp.bfloat16),
            "layers": [np.zeros((2,), np.int32), 3.0]}
    got = abstractify(tree)
    want = jax.eval_shape(lambda: tree)
    assert jax.tree.map(
        lambda a, b: (a.shape, a.dtype) == (b.shape, b.dtype), got, want)
    flat = jax.tree.leaves(got)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in flat)


def test_abstractify_is_idempotent_and_traceable():
    tree = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    again = abstractify(tree)
    assert again["w"].shape == (4,) and again["w"].dtype == jnp.float32
    out = jax.eval_shape(lambda t: jax.tree.map(lambda x: x * 2, t), again)
    assert out["w"].shape == (4,)
