"""The kernel-bench numerics gate (ISSUE 9 satellite): a drift failure
must be actionable from the message alone — it names the kernel, the
measured-vs-threshold comparison, and the pinned operand PRNG seed, so
a red CI line reproduces without digging through the harness."""
import math

import pytest

from benchmarks.kernel_bench import SEED, drift_fail_message


def test_drift_message_names_kernel_measured_threshold_and_seed():
    msg = drift_fail_message("int8_matmul", "rel error vs fp32",
                             0.137, ">", 0.02)
    assert "CLAIM-FAIL[int8_matmul]" in msg
    assert "0.137" in msg and "> threshold 0.02" in msg
    assert f"(seed={SEED})" in msg
    assert "broken kernel" in msg

    msg = drift_fail_message("flash_attention_int8kv",
                             "cosine vs fp32 flash", 0.51234, "<", 0.999)
    assert "CLAIM-FAIL[flash_attention_int8kv]" in msg
    assert "0.51234" in msg and "< threshold 0.999" in msg
    assert f"(seed={SEED})" in msg


def test_broken_int8_matmul_fails_with_named_message(monkeypatch,
                                                     tmp_path):
    """End-to-end regression: a kernel whose numerics drift (here: an
    int8_matmul stubbed to return zeros) must fail the run (non-zero
    return) and print the standardized message carrying its name, the
    measured error, the 0.02 threshold, and the seed."""
    jnp = pytest.importorskip("jax.numpy")
    from benchmarks import kernel_bench
    from repro.kernels import ops

    real = ops.int8_matmul

    def zeroed(x, w, **kw):
        return jnp.zeros_like(real(x, w, **kw))

    monkeypatch.setattr(ops, "int8_matmul", zeroed)
    lines = []
    n_fail = kernel_bench.run(print_fn=lines.append, out=str(tmp_path))
    assert n_fail == 1
    fails = [l for l in lines if l.startswith("CLAIM-FAIL")]
    assert len(fails) == 1
    (msg,) = fails
    assert "CLAIM-FAIL[int8_matmul]" in msg
    assert "> threshold 0.02" in msg
    assert f"(seed={SEED})" in msg
    # zeroed output => rel error is exactly 1, and the message carries it
    assert " 1 > " in msg
