"""Measured-rate calibration (repro.calib): overlay semantics, the
design-row linearity the fitter depends on, and the synthetic-ground-
truth recovery guarantees — exact at zero noise, bounded under bounded
multiplicative noise (docs/calibration.md)."""
import json
import math

import numpy as np
import pytest

from prophelpers import given, settings, st

from repro.calib.fit import (collective_sample, compute_sample,
                             fit_calibration, row_dot, step_design_row,
                             step_sample)
from repro.calib.microbench import (RecordingProber,
                                    synthetic_measurements)
from repro.calib.overlay import Calibration, LinkRate, MeasuredLink
from repro.configs import get_config
from repro.core.costmodel import (ALL_TECHNIQUES, PAPER_CLUSTERS,
                                  paper_workload, technique_step_cost)
from repro.core.plans import Placement
from repro.core.selector import CostModelProber
from repro.core.topology import (Link, Site, hub, line, ring, two_site)

WL_M = paper_workload(get_config("gpt2m"))
A30X2 = two_site("a30x2", ("A30", "A30"), ("A30", "A30"), 20.2)


def _sites(n, gpu="A30"):
    return [Site((gpu, gpu), name=f"S{i}") for i in range(n)]


def _topo(shape: str, n: int):
    """The topology-zoo shapes the property tests sweep (N in 2..4)."""
    if n == 2 or shape == "two":
        return two_site("t2", ("A30", "A30"), ("T4", "T4"), 20.2)
    wan = [Link(10e-3 * (k + 1), 2.0 + k) for k in range(n)]
    if shape == "ring":
        return ring("r", _sites(n), wan[:n])
    if shape == "hub":
        return hub("h", _sites(1)[0], _sites(n - 1, gpu="T4"), wan[0])
    return line("l", _sites(n), wan[:n - 1])


# ------------------------------------------------------------------ #
# overlay semantics
# ------------------------------------------------------------------ #

def test_measured_link_skips_window_clamp():
    """A fitted rate was measured *through* the TCP window, so
    ``MeasuredLink`` must not re-apply the analytic clamp that
    ``Link.effective_gbps`` applies to datasheet bandwidths."""
    lat, bw = 57.4e-3, 10.0
    assert Link(lat, bw).effective_gbps < bw          # clamp engages
    assert MeasuredLink(lat, bw).effective_gbps == bw  # measured: no clamp
    assert LinkRate(lat, bw).link() == MeasuredLink(lat, bw)


def test_calibration_json_round_trip():
    cal = Calibration(site_tflops={1: 15.0, 0: 23.5},
                      links={(0, 1): LinkRate(22e-3, 2.4),
                             (1, 1): LinkRate(4e-6, 11.0)},
                      note="bench host, 2026-08")
    back = Calibration.loads(cal.dumps())
    assert back == cal
    assert json.loads(cal.dumps()) == cal.to_json()   # stable text form
    assert not cal.is_identity and Calibration.identity().is_identity


def test_calibration_pair_keys_canonicalize():
    cal = Calibration(links={(1, 0): LinkRate(1e-3, 5.0)})
    topo = _topo("line", 3)
    assert cal.link(topo, 0, 1) == cal.link(topo, 1, 0) \
        == MeasuredLink(1e-3, 5.0)
    # unmeasured pairs fall through to the very same analytic objects
    assert cal.link(topo, 1, 2) is topo.link(1, 2)
    assert cal.link(topo, 2, 2) is topo.sites[2].intra


def test_identity_overlay_is_bit_for_bit_none():
    """Every technique on every paper cluster: ``Calibration.identity()``
    must price bit-for-bit (``==``, not isclose) what ``calibration=
    None`` prices — the overlay only ever falls through."""
    ident = Calibration.identity()
    wl = WL_M
    for cname, cluster in sorted(PAPER_CLUSTERS.items()):
        for tech in ALL_TECHNIQUES:
            for sel in ([0], [0, 1]):
                kw = {"stage_order": tuple(sel)} \
                    if tech == "pipeshard" else {}
                if tech == "pipeshard" and len(sel) == 1:
                    continue
                a = technique_step_cost(tech, wl, cluster, sel, **kw)
                b = technique_step_cost(tech, wl, cluster, sel,
                                        calibration=ident, **kw)
                assert (a.compute_s, a.comm_s, a.mem_required_gb) == \
                    (b.compute_s, b.comm_s, b.mem_required_gb), \
                    (cname, tech, sel)


# ------------------------------------------------------------------ #
# design-row linearity
# ------------------------------------------------------------------ #

TRUTH = Calibration(site_tflops={0: 17.0, 1: 9.0},
                    links={(0, 0): LinkRate(6e-6, 9.0),
                           (0, 1): LinkRate(25e-3, 1.7)},
                    note="truth")


@pytest.mark.parametrize("tech", sorted(ALL_TECHNIQUES))
@pytest.mark.parametrize("sel", [(0,), (0, 1)])
def test_step_design_row_reproduces_step_cost(tech, sel):
    """``row_dot(step_design_row(...), cal)`` must reproduce
    ``technique_step_cost(..., calibration=cal).total_s`` — the
    linearity (at fixed max/argmax structure) the whole fitter rests
    on."""
    if tech == "pipeshard" and len(sel) == 1:
        pytest.skip("1-stage pipeline degenerates")
    kw = {"stage_order": sel} if tech == "pipeshard" else {}
    want = technique_step_cost(tech, WL_M, A30X2, sel,
                               calibration=TRUTH, **kw).total_s
    row = step_design_row(tech, WL_M, A30X2, sel, calibration=TRUTH,
                          **kw)
    got = row_dot(row, TRUTH, A30X2)
    assert math.isclose(got, want, rel_tol=1e-9), (tech, sel)


def test_recording_prober_pools_step_samples():
    """RecordingProber converts each successful probe's TFLOP/s figure
    back to the step seconds it came from, so ε-epoch probes become
    fitter rows instead of being thrown away."""
    inner = CostModelProber(WL_M, A30X2)
    rec = RecordingProber(inner, WL_M)
    t = rec.probe("data", Placement((0,)))
    assert t == inner.probe("data", Placement((0,)))
    assert rec.probe("data", None) == inner.probe("data", None)
    assert len(rec.samples) == 1                 # placement=None skipped
    (s,) = rec.samples
    assert s.kind == "step" and s.technique == "data" and s.sites == (0,)
    assert math.isclose(s.time_s, WL_M.flops_per_step / (t * 1e12))


# ------------------------------------------------------------------ #
# synthetic-ground-truth recovery
# ------------------------------------------------------------------ #

def _max_rel_err(fitted: Calibration, truth: Calibration, topo) -> float:
    err = 0.0
    for i in truth.site_tflops:
        err = max(err, abs(fitted.gpu_tflops(topo, i)
                           / truth.gpu_tflops(topo, i) - 1.0))
    for (i, j) in truth.links:
        f, t = fitted.link(topo, i, j), truth.link(topo, i, j)
        err = max(err, abs(f.latency_s / t.latency_s - 1.0),
                  abs(f.effective_gbps / t.effective_gbps - 1.0))
    return err


def _full_truth(topo, rng) -> Calibration:
    """A random full-coverage ground truth: every site's achieved rate
    and every (intra + end-to-end inter) pair overridden."""
    n = topo.n_sites
    sites = {i: float(rng.uniform(5.0, 30.0)) for i in range(n)}
    links = {}
    for i in range(n):
        links[(i, i)] = LinkRate(float(rng.uniform(1e-6, 1e-4)),
                                 float(rng.uniform(5.0, 20.0)))
        for j in range(i + 1, n):
            links[(i, j)] = LinkRate(float(rng.uniform(1e-3, 60e-3)),
                                     float(rng.uniform(0.5, 4.0)))
    return Calibration(sites, links, note="synthetic truth")


def test_fit_recovers_truth_exactly_at_zero_noise():
    topo = A30X2
    rng = np.random.default_rng(11)
    truth = _full_truth(topo, rng)
    samples = synthetic_measurements(
        topo, truth, rng=rng, noise=0.0, wl=WL_M,
        step_placements=[("data", (0,), {}), ("zero2", (0, 1), {}),
                         ("pipeshard", (0, 1),
                          {"stage_order": (0, 1)})])
    fr = fit_calibration(topo, samples)
    assert fr.residual < 1e-9
    assert _max_rel_err(fr.calibration, truth, topo) < 1e-9


def test_fit_recovery_error_is_noise_bounded():
    """2% multiplicative noise must not blow recovery past a few
    percent (the least-squares average beats the worst sample)."""
    topo = A30X2
    rng = np.random.default_rng(3)
    truth = _full_truth(topo, rng)
    samples = synthetic_measurements(topo, truth, rng=rng, noise=0.02)
    fr = fit_calibration(topo, samples)
    assert _max_rel_err(fr.calibration, truth, topo) < 0.05


def test_fit_rejects_empty_measurement_set():
    with pytest.raises(ValueError):
        fit_calibration(A30X2, [])


def test_fit_keeps_base_for_unmeasured_coefficients():
    """Half-measured sets must not invent rates: coefficients with no
    sample keep the base overlay's (here: analytic) values."""
    topo = A30X2
    samples = [compute_sample(0, 1e12, 1e12 / (15.0 * 1e12))]
    fr = fit_calibration(topo, samples)
    cal = fr.calibration
    assert math.isclose(cal.gpu_tflops(topo, 0), 15.0, rel_tol=1e-9)
    assert math.isclose(cal.gpu_tflops(topo, 1), 25.0)   # datasheet
    assert cal.link(topo, 0, 1) == topo.link(0, 1)       # untouched


@settings(max_examples=12, deadline=None)
@given(shape=st.sampled_from(["ring", "hub", "line"]),
       n=st.integers(min_value=2, max_value=4),
       noise=st.sampled_from([0.0, 0.01, 0.03]),
       seed=st.integers(min_value=0, max_value=2**16))
def test_fit_recovery_property(shape, n, noise, seed):
    """The acceptance property (ISSUE 9): on random workloads over the
    topology zoo (ring / hub / line, N in 2..4) with a random
    full-coverage ground truth, the fitter recovers the truth exactly
    at zero noise and within a noise-proportional band under bounded
    multiplicative noise."""
    topo = _topo(shape, n)
    rng = np.random.default_rng(seed)
    truth = _full_truth(topo, rng)
    steps = [("data", tuple(range(topo.n_sites)), {})]
    if topo.n_sites >= 2:
        steps.append(("pipeshard", (0, 1), {"stage_order": (0, 1)}))
    samples = synthetic_measurements(topo, truth, rng=rng, noise=noise,
                                     wl=WL_M, step_placements=steps)
    fr = fit_calibration(topo, samples)
    err = _max_rel_err(fr.calibration, truth, topo)
    if noise == 0.0:
        assert err < 1e-9
    else:
        assert err < max(10.0 * noise, 0.05)
