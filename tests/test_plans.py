"""Plan-equivalence tests: every registered plan (the paper's four plus
shard_zero and fsdp — ``repro.core.plans.PLANS``) must compute the same
optimizer trajectory.  Runs in a subprocess with 8 forced host devices
(device count locks at first jax init)."""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.compat import NATIVE_SHARD_MAP

needs_partial_auto = pytest.mark.skipif(
    not NATIVE_SHARD_MAP,
    reason="pipeshard needs partial-auto shard_map; the jax-0.4.x SPMD "
           "partitioner rejects it (repro.compat.NATIVE_SHARD_MAP)")


def _run_plan_check(env, extra_args=()):
    cmd = [sys.executable, "-m", "repro.launch.plan_check",
           "--devices", "8", *extra_args]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    return json.loads(line)


@pytest.mark.slow
@needs_partial_auto
def test_all_plans_equivalent_dense(subproc_env):
    from repro.core.plans import PLANS
    res = _run_plan_check(subproc_env)
    # the default plan list derives from the registry (incl. fsdp)
    assert set(res) == set(PLANS)
    base = res["data"]
    for name, r in res.items():
        np.testing.assert_allclose(r["losses"], base["losses"], rtol=2e-3,
                                   err_msg=name)
        np.testing.assert_allclose(r["param_norm"], base["param_norm"],
                                   rtol=1e-3, err_msg=name)


@pytest.mark.slow
def test_plans_equivalent_moe(subproc_env):
    # rtol 6e-3: the shard plan's per-data-shard MoE dispatch casts its
    # shard_map boundary to fp32 (XLA CPU bug workaround), so rounding
    # differs slightly from the data plan's global dispatch; no-drop
    # capacity in the reduced config keeps the math otherwise identical.
    res = _run_plan_check(subproc_env, ["--arch", "phi3.5-moe-42b-a6.6b",
                           "--plans", "data,shard", "--layers", "2"])
    np.testing.assert_allclose(res["shard"]["losses"], res["data"]["losses"],
                               rtol=6e-3)


@pytest.mark.slow
def test_plans_equivalent_ssm(subproc_env):
    res = _run_plan_check(subproc_env, ["--arch", "falcon-mamba-7b",
                           "--plans", "data,zero2,shard", "--layers", "2"])
    for name in ("zero2", "shard"):
        np.testing.assert_allclose(res[name]["losses"],
                                   res["data"]["losses"], rtol=2e-3)


@pytest.mark.slow
@needs_partial_auto
def test_pipeshard_four_stages(subproc_env):
    """4-stage pipeline (stage absorbs the whole 'pod'+'data' axes)."""
    res = _run_plan_check(subproc_env, ["--plans", "data,pipeshard", "--layers", "8"])
    np.testing.assert_allclose(res["pipeshard"]["losses"],
                               res["data"]["losses"], rtol=2e-3)
