"""Continuous-batching serving tests (ISSUE 10): the ServeStats unit
fix, SlotScheduler/OutputQueue invariants under random traces, the
replica-placement pass, the trace simulator, and the slow end-to-end
bit-exactness gate (continuous vs fixed-batch greedy tokens)."""
import dataclasses

import numpy as np
import pytest

from repro.serve import (ContinuousStats, OutputQueue, Request,
                         ServeStats, SlotScheduler)


# ------------------------------------------------------------------ #
# ServeStats units (the satellite regression)
def test_tokens_per_s_units():
    """One decode step emits one token per live slot: tokens/s must be
    steps/s * n_slots, not the bare step rate (the pre-PR-10 bug)."""
    st = ServeStats(decode_s=[0.1, 0.1, 0.1], n_slots=4)
    assert st.steps_per_s == pytest.approx(10.0)
    assert st.tokens_per_s == pytest.approx(40.0)
    # single-slot serving is unchanged by the fix
    assert ServeStats(decode_s=[0.1], n_slots=1).tokens_per_s == \
        pytest.approx(10.0)


def test_serve_stats_wall_clock_fallback():
    """timing=False records no per-step times; the loop wall clock and
    step count must still yield a rate."""
    st = ServeStats(decode_s=[], n_slots=2, total_decode_s=2.0, n_steps=10)
    assert st.steps_per_s == pytest.approx(5.0)
    assert st.tokens_per_s == pytest.approx(10.0)
    assert ServeStats().tokens_per_s == 0.0


def test_continuous_stats_goodput():
    st = ContinuousStats(n_slots=3, n_tokens=30, total_s=2.0,
                         occupancy=[3, 3, 2, 2])
    assert st.tokens_per_s == pytest.approx(15.0)
    assert st.mean_occupancy == pytest.approx(2.5)
    assert ContinuousStats().tokens_per_s == 0.0


# ------------------------------------------------------------------ #
# SlotScheduler invariants
def test_slot_scheduler_basics():
    s = SlotScheduler(2)
    a = s.admit(10, max_new=2)
    b = s.admit(11, max_new=1)
    assert not s.has_free() and s.occupancy == 2
    with pytest.raises(RuntimeError):
        s.admit(12, max_new=1)
    assert s.record_token(b) is True        # hit its budget of 1
    assert s.evict(b) == 11
    assert s.record_token(a) is False
    with pytest.raises(KeyError):
        s.record_token(b)                   # freed slot is unreadable
    with pytest.raises(KeyError):
        s.evict(b)
    c = s.admit(12, max_new=1)
    assert c == b                           # freed slot recycled
    s.check()


def test_slot_scheduler_rejects_bad_args():
    with pytest.raises(ValueError):
        SlotScheduler(0)
    with pytest.raises(ValueError):
        SlotScheduler(1).admit(0, max_new=0)


def test_slot_scheduler_random_trace(rng):
    """Property test: across random admit/generate/evict traces the slot
    invariants hold at every step — no slot is both free and live, no
    live slot is overwritten by backfill, occupancy is conserved."""
    for trial in range(20):
        n_slots = int(rng.integers(1, 6))
        s = SlotScheduler(n_slots)
        uid = 0
        live = {}                           # slot -> uid, shadow copy
        for _ in range(200):
            if s.has_free() and rng.random() < 0.5:
                slot = s.admit(uid, max_new=int(rng.integers(1, 5)))
                assert slot not in live     # backfill never clobbers
                live[slot] = uid
                uid += 1
            elif live:
                slot = int(rng.choice(sorted(live)))
                if s.record_token(slot):
                    assert s.evict(slot) == live.pop(slot)
            s.check()
            assert s.occupancy == len(live)
            assert sorted(live) == s.live_slots()
            for slot, u in live.items():
                assert s.uid_of(slot) == u


def test_output_queue_detokenizes_on_drain():
    calls = []

    def detok(ids):
        calls.append(ids)
        return "".join(chr(65 + i) for i in ids)

    q = OutputQueue(detok)
    q.put(7, [0, 1])
    q.put(3, [2])
    assert len(q) == 2 and not calls        # put never detokenizes
    assert q.drain() == [(7, "AB"), (3, "C")]
    assert len(calls) == 2 and len(q) == 0
    assert q.drain() == []
    # without a detokenizer, raw ids pass through
    q2 = OutputQueue()
    q2.put(1, [5])
    assert q2.drain() == [(1, [5])]


# ------------------------------------------------------------------ #
# replica placement (pure cost model, no jax compute)
def test_partitions_bell_numbers():
    from repro.serve.placement import partitions
    for n, bell in ((0, 1), (1, 1), (2, 2), (3, 5), (4, 15)):
        parts = list(partitions(range(n)))
        assert len(parts) == bell
        for p in parts:                     # each is an exact cover
            got = sorted(x for g in p for x in g)
            assert got == list(range(n))


def test_place_replicas_rejects_rate_mismatch():
    from repro.configs import get_config
    from repro.core.search import PlanSearch
    from repro.core.topology import two_site
    from repro.serve.placement import decode_workload, place_replicas

    topo = two_site("pair", ("A30",), ("A30",), 0.2)
    search = PlanSearch(decode_workload(get_config("gpt2m"), slots=4),
                        topo)
    with pytest.raises(ValueError, match="rates"):
        place_replicas(search, [1.0], slots=4)


def test_disconnected_group_is_infeasible():
    """Cutting the middle site out of a line leaves {0,2} with no link:
    that group must price as None, not crash or get a free lunch."""
    from repro.configs import get_config
    from repro.core.search import PlanSearch
    from repro.core.topology import Link, Site, line
    from repro.serve.placement import _price_group, decode_workload

    topo = line("l3", [Site(("A30",)) for _ in range(3)],
                [Link(1e-3, 10.0), Link(1e-3, 10.0)])
    search = PlanSearch(decode_workload(get_config("gpt2m"), slots=4),
                        topo)
    assert _price_group(search, topo, [0, 2], [1.0, 0.0, 1.0],
                        slots=4, prompt_len=64, gen_len=8) is None
    priced = _price_group(search, topo, [0, 1], [1.0, 1.0, 0.0],
                          slots=4, prompt_len=64, gen_len=8)
    assert priced is not None


def test_placement_winner_map_gate():
    """The pinned BENCH_10 scenario: at 50% single-site load the far
    (80 ms) site must keep its own local replica while the 0.2 ms LAN
    pair shares one — the ISSUE's acceptance winner map."""
    from benchmarks.serving_bench import PROMPT_LEN, SLOTS, pinned_scenario
    from repro.serve.placement import _price_group, place_replicas

    search = pinned_scenario()
    single, _ = _price_group(search, search.topology, [0],
                             [0.0, 0.0, 0.0], slots=SLOTS,
                             prompt_len=PROMPT_LEN, gen_len=64)
    capacity_rps = SLOTS / (single.prefill_s + 64 * single.decode_step_s)
    plan = place_replicas(search, [0.5 * capacity_rps] * 3, slots=SLOTS,
                          prompt_len=PROMPT_LEN, gen_len=64)
    assert (2,) in plan.groups, plan.groups
    assert any(0 in g and 1 in g for g in plan.groups), plan.groups
    # saturating one site must still be feasible pooled: rates at 90%
    # of one site's capacity only fit when the LAN pair shares
    hot = place_replicas(search, [0.9 * capacity_rps] * 3, slots=SLOTS,
                         prompt_len=PROMPT_LEN, gen_len=64)
    assert hot is not None
    for r in hot.replicas:
        assert r.rho < 0.95


# ------------------------------------------------------------------ #
# the trace simulator behind BENCH_10
def test_trace_is_deterministic():
    from benchmarks.serving_bench import make_trace
    a1, g1 = make_trace(1000, 5.0)
    a2, g2 = make_trace(1000, 5.0)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(g1, g2)
    assert np.all(np.diff(a1) > 0) and a1.shape == (1000,)


def test_continuous_beats_fixed_on_mixed_trace():
    """The goodput mechanism itself: with a long-tail generation mix and
    saturating arrivals, per-slot freeing must beat hold-for-longest."""
    from benchmarks.serving_bench import (make_trace, sim_continuous,
                                          sim_fixed)
    step_s, prefill_s = 2e-3, 60e-3
    arrivals_s, gen_len = make_trace(4000, 60.0)
    cont = sim_continuous(arrivals_s, gen_len, step_s=step_s,
                          prefill_s=prefill_s, slots=8)
    fixed = sim_fixed(arrivals_s, gen_len, step_s=step_s,
                      prefill_s=prefill_s, batch=8)
    assert cont["goodput_tok_s"] > 2.0 * fixed["goodput_tok_s"]
    assert np.all(cont["ttft_s"] >= 0) and np.all(fixed["ttft_s"] >= 0)
    assert 0.0 < cont["occupancy"] <= 1.0


def test_uniform_trace_no_continuous_advantage():
    """Control: when every request generates the same length, fixed
    batching wastes nothing and the two engines converge (<10% apart) —
    the 2x gate really is about the length mix."""
    from benchmarks.serving_bench import sim_continuous, sim_fixed
    rng = np.random.default_rng(0)
    arrivals_s = np.cumsum(rng.exponential(1 / 50.0, 4000))
    gen_len = np.full(4000, 64, dtype=np.int64)
    cont = sim_continuous(arrivals_s, gen_len, step_s=2e-3,
                          prefill_s=60e-3, slots=8)
    fixed = sim_fixed(arrivals_s, gen_len, step_s=2e-3,
                      prefill_s=60e-3, batch=8)
    ratio = cont["goodput_tok_s"] / fixed["goodput_tok_s"]
    assert ratio < 1.1


# ------------------------------------------------------------------ #
# slot-cache plumbing
def test_init_slot_cache_widens_index_leaves():
    """Per-slot caches carry one ring index per batch row: every index
    leaf gains a trailing [B] axis, data leaves keep their train shape."""
    import jax
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("llama3.2-3b").reduced()
    model = Model(cfg)
    base = model.init_cache(3, 32)
    slot = model.init_slot_cache(3, 32)

    def leaves_by_path(tree):
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        return {jax.tree_util.keystr(p): v for p, v in flat}

    b, s = leaves_by_path(base), leaves_by_path(slot)
    assert b.keys() == s.keys()
    n_index = 0
    for k in b:
        if "index" in k:
            n_index += 1
            assert s[k].shape == b[k].shape + (3,)
            assert s[k].dtype == b[k].dtype
        else:
            assert s[k].shape == b[k].shape
    assert n_index >= 1


def test_ring_valid_per_slot_masks():
    import jax.numpy as jnp
    from repro.models.attention import _ring_valid

    scalar = _ring_valid(jnp.asarray(2, jnp.int32), 3, 4)
    assert scalar.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(scalar[0]),
                                  [True, True, False, False])
    per_slot = _ring_valid(jnp.asarray([0, 2, 4], jnp.int32), 3, 4)
    np.testing.assert_array_equal(
        np.asarray(per_slot),
        [[False] * 4, [True, True, False, False], [True] * 4])


# ------------------------------------------------------------------ #
# end-to-end: continuous == fixed, bit for bit
@pytest.fixture(scope="module")
def serve_setup():
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model

    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              vocab_size=512)
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(0))
    return model, mesh, params


@pytest.mark.slow
def test_continuous_bit_exact_vs_fixed(serve_setup):
    """The ISSUE's pinned gate: per-request greedy tokens from the
    continuous engine are bit-identical to the fixed-batch Engine's,
    across mixed prompt lengths, slot churn, and bucketed prefill."""
    from repro.core.plans import get_plan
    from repro.serve import ContinuousEngine, Engine, Request

    model, mesh, params = serve_setup
    rng = np.random.default_rng(3)
    lens = [5, 9, 9, 13, 5, 7]
    prompts = [np.asarray(rng.integers(4, 400, (n,)), np.int32)
               for n in lens]
    plan, max_new = get_plan("data"), 6

    ref, bylen = {}, {}
    for i, p in enumerate(prompts):
        bylen.setdefault(len(p), []).append(i)
    for n, idxs in bylen.items():
        eng = Engine(model, plan, mesh, batch_size=len(idxs), max_len=64)
        out = eng.generate(
            params, {"tokens": np.stack([prompts[i] for i in idxs])},
            n_tokens=max_new)
        for row, i in enumerate(idxs):
            ref[i] = out["tokens"][row]

    ce = ContinuousEngine(model, plan, mesh, slots=3, max_len=64,
                          buckets=(8, 16, 32))
    res = ce.run(params, [Request(i, p) for i, p in enumerate(prompts)],
                 max_new=max_new)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(res["outputs"][i], ref[i],
                                      err_msg=f"request {i} diverged")
    st = res["stats"]
    assert st.n_tokens == max_new * len(prompts)
    assert 0 < st.mean_occupancy <= 3
    assert len(st.ttft_s) == len(prompts)
    assert all(t >= 0 for t in st.ttft_s.values())


@pytest.mark.slow
def test_continuous_ssm_exact_prefill_bit_exact():
    """SSM families integrate pad tokens into their recurrent state, so
    the engine must route them through exact-length prefill — and still
    match the fixed-batch engine bit for bit."""
    import jax
    from repro.configs import get_config
    from repro.core.plans import get_plan
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.serve import ContinuousEngine, Engine, Request

    cfg = get_config("falcon-mamba-7b").reduced()
    model = Model(cfg)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        params = model.init(jax.random.key(1))
    rng = np.random.default_rng(11)
    prompts = [np.asarray(
        rng.integers(4, min(cfg.vocab_size, 400), (n,)), np.int32)
        for n in (4, 6, 4)]
    plan, max_new = get_plan("data"), 4
    eng = Engine(model, plan, mesh, batch_size=1, max_len=32)
    ref = [eng.generate(params, {"tokens": p[None]},
                        n_tokens=max_new)["tokens"][0] for p in prompts]
    ce = ContinuousEngine(model, plan, mesh, slots=2, max_len=32)
    assert ce.exact_prefill      # the ssm family must take this path
    res = ce.run(params,
                 [Request(i, p) for i, p in enumerate(prompts)],
                 max_new=max_new)
    for i, want in enumerate(ref):
        np.testing.assert_array_equal(res["outputs"][i], want,
                                      err_msg=f"request {i} diverged")


@pytest.mark.slow
def test_continuous_int8_kv_bit_exact(serve_setup):
    """--kv-dtype int8 keeps working continuously: the quantized ring
    cache scatters through insert and stays bit-identical to the
    fixed-batch int8 engine."""
    from repro.core.plans import get_plan
    from repro.serve import ContinuousEngine, Engine, Request

    model, mesh, params = serve_setup
    rng = np.random.default_rng(7)
    prompts = [np.asarray(rng.integers(4, 400, (n,)), np.int32)
               for n in (5, 9, 7, 9)]
    plan, max_new = get_plan("data"), 5
    eng = Engine(model, plan, mesh, batch_size=1, max_len=64,
                 kv_dtype="int8")
    ref = [eng.generate(params, {"tokens": p[None]},
                        n_tokens=max_new)["tokens"][0] for p in prompts]
    ce = ContinuousEngine(model, plan, mesh, slots=2, max_len=64,
                          buckets=(8, 16), kv_dtype="int8")
    res = ce.run(params,
                 [Request(i, p) for i, p in enumerate(prompts)],
                 max_new=max_new)
    for i, want in enumerate(ref):
        np.testing.assert_array_equal(res["outputs"][i], want,
                                      err_msg=f"request {i} diverged")


@pytest.mark.slow
def test_engine_timing_flag(serve_setup):
    """timing=False must skip per-step device syncs but return the same
    tokens and still produce a wall-clock rate."""
    from repro.core.plans import get_plan
    from repro.serve import Engine

    model, mesh, params = serve_setup
    eng = Engine(model, get_plan("data"), mesh, batch_size=2, max_len=64)
    batch = {"tokens": np.asarray(
        np.random.default_rng(5).integers(4, 400, (2, 8)), np.int32)}
    timed = eng.generate(params, batch, n_tokens=4, timing=True)
    fast = eng.generate(params, batch, n_tokens=4, timing=False)
    np.testing.assert_array_equal(timed["tokens"], fast["tokens"])
    # the first token comes out of prefill; decode runs n_tokens-1 steps
    assert len(timed["stats"].decode_s) == 3
    assert fast["stats"].decode_s == []
    assert fast["stats"].n_steps == 3
    assert fast["stats"].total_decode_s > 0
    assert fast["stats"].tokens_per_s > 0
