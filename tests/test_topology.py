"""N-site topology subsystem: link graph routing, spanning-set bottleneck,
the legacy two-VM Cluster as the exact N=2 special case, and site→mesh
mapping (DESIGN.md §5)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import (PAPER_CLUSTERS, fabric_cluster,
                                  paper_workload, technique_step_cost)
from repro.core.plans import Placement
from repro.core.topology import (GPUS, Link, Site, Topology, fully_connected,
                                 hub, line, make_topology, ring, two_site)
from repro.launch.mesh import topology_mesh_spec

WL = paper_workload(get_config("gpt2m"))


def _sites(n, gpu="A30"):
    return [Site((gpu, gpu), name=f"S{i}") for i in range(n)]


# ------------------------------------------------------------------ #
# graph mechanics
# ------------------------------------------------------------------ #

def test_link_effective_throughput_tcp_window_rule():
    assert Link(0.0, 3.0).effective_gbps == 3.0
    # 100ms RTT, 8MB window: 0.08 GB/s regardless of raw bandwidth
    assert Link(0.1, 3.0).effective_gbps == pytest.approx(0.08)


def test_intra_link_for_same_site():
    t = two_site("t", ("RTX", "RTX"), ("T4", "T4"), 10.0)
    assert t.link(0, 0) is t.sites[0].intra
    assert t.link(0, 1).latency_s == pytest.approx(10e-3)


def test_hub_routes_leaf_to_leaf_through_hub():
    t = hub("h", Site(("A30", "A30")), _sites(2), Link(20e-3, 3.0))
    direct = t.link(0, 1)                  # hub -> leaf: one spoke
    relayed = t.link(1, 2)                 # leaf -> leaf: two spokes
    assert direct.latency_s == pytest.approx(20e-3)
    assert relayed.latency_s == pytest.approx(40e-3)
    assert relayed.bandwidth_gbps == 3.0   # min along the path


def test_route_prefers_lower_latency_path():
    # 0-1-2 cheap relay vs 0-2 expensive direct: link() must return the
    # direct edge when present, routing only fills missing pairs
    t = make_topology("m", _sites(3), {
        (0, 1): Link(1e-3, 3.0), (1, 2): Link(1e-3, 3.0)})
    routed = t.link(0, 2)
    assert routed.latency_s == pytest.approx(2e-3)


def test_disconnected_sites_raise():
    t = make_topology("d", _sites(3), {(0, 1): Link(1e-3, 3.0)})
    with pytest.raises(ValueError, match="not connected"):
        t.link(0, 2)


def test_worst_link_is_spanning_bottleneck():
    t = make_topology("w", _sites(3), {
        (0, 1): Link(1e-3, 3.0), (1, 2): Link(1e-3, 3.0),
        (0, 2): Link(90e-3, 3.0)})
    # subset {0,1}: only the cheap link
    assert t.worst_link([0, 1]).latency_s == pytest.approx(1e-3)
    # all three: the 90ms edge caps the collective
    assert t.worst_link(None).latency_s == pytest.approx(90e-3)
    # single site: its intra link
    assert t.worst_link([1]) is t.sites[1].intra


def test_select_validates():
    t = fully_connected("f", _sites(2), Link(1e-3, 3.0))
    with pytest.raises(IndexError):
        t.select([2])
    with pytest.raises(ValueError):
        t.select([0, 0])


def test_ring_builder_validates_link_count():
    with pytest.raises(ValueError):
        ring("r", _sites(3), [Link(1e-3, 3.0)] * 2)
    with pytest.raises(ValueError):
        line("l", _sites(3), [Link(1e-3, 3.0)] * 3)
    # a 2-site "ring" would silently merge its two parallel edges
    with pytest.raises(ValueError, match=">= 3 sites"):
        ring("r2", _sites(2), [Link(1e-3, 3.0), Link(200e-3, 3.0)])


def test_conflicting_duplicate_links_rejected():
    with pytest.raises(ValueError, match="conflicting links"):
        make_topology("dup", _sites(2), {
            (0, 1): Link(1e-3, 3.0), (1, 0): Link(200e-3, 3.0)})


# ------------------------------------------------------------------ #
# degradation helpers (elastic re-planning, docs/elasticity.md)
# ------------------------------------------------------------------ #

def test_without_sites_reindexes_and_maps_back():
    t = ring("r4", _sites(4), [Link(1e-3, 3.0)] * 4)
    survivor, kept = t.without_sites((1,))
    assert survivor.n_sites == 3
    assert kept == (0, 2, 3)                     # new index -> old index
    assert "S1" in survivor.name                 # provenance in the name
    # surviving links follow the reindexing: old (2,3) -> new (1,2)
    assert (1, 2) in survivor.links
    # old edges through the dead site are gone: new 0 (old 0) and new 1
    # (old 2) had no direct edge on the ring
    assert (0, 1) not in survivor.links
    with pytest.raises(ValueError, match="died"):
        t.without_sites((0, 1, 2, 3))
    with pytest.raises(IndexError):
        t.without_sites((9,))


def test_without_link_removes_edge_and_routes_around():
    t = ring("r3", _sites(3), [Link(1e-3, 3.0)] * 3)
    cut = t.without_link(0, 1)
    assert t.link(0, 1).latency_s == pytest.approx(1e-3)
    # the pair now routes the long way around the ring
    assert cut.link(0, 1).latency_s == pytest.approx(2e-3)
    with pytest.raises(ValueError, match="no direct link"):
        cut.without_link(0, 1)


def test_components_split_and_ordering():
    t = line("l5", _sites(5), [Link(1e-3, 3.0)] * 4)
    assert t.components() == [(0, 1, 2, 3, 4)]
    survivor, _ = t.without_sites((2,))          # sever the middle
    assert survivor.components() == [(0, 1), (2, 3)]
    lone = make_topology("iso", _sites(3), {(0, 1): Link(1e-3, 3.0)})
    assert lone.components() == [(0, 1), (2,)]   # largest first


# ------------------------------------------------------------------ #
# the N=2 special case is the legacy Cluster, bit for bit
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("cname", sorted(PAPER_CLUSTERS))
@pytest.mark.parametrize("tech", ["data", "zero2", "shard", "pipeshard"])
def test_cluster_topology_embedding_preserves_costs(cname, tech):
    cluster = PAPER_CLUSTERS[cname]
    topo = cluster.topology()
    for vms in (None, [0], [1]):
        a = technique_step_cost(tech, WL, cluster, vms)
        b = technique_step_cost(tech, WL, topo, vms)
        assert a.compute_s == pytest.approx(b.compute_s)
        assert a.comm_s == pytest.approx(b.comm_s)
        assert a.mem_required_gb == pytest.approx(b.mem_required_gb)
        assert a.mem_available_gb == pytest.approx(b.mem_available_gb)


def test_two_site_builder_matches_fabric_cluster():
    c = fabric_cluster("x", ("A30", "A30"), ("T4", "T4"), 20.0)
    t = two_site("x", ("A30", "A30"), ("T4", "T4"), 20.0)
    for tech in ("data", "zero2", "shard", "pipeshard"):
        assert technique_step_cost(tech, WL, c).total_s == pytest.approx(
            technique_step_cost(tech, WL, t).total_s)


def test_pipeshard_stage_order_prices_crossed_links():
    # line A--B--C with one dear edge: order (0,1,2) crosses two cheap
    # links; order (0,2,1) must route 0->2 through B and pay double
    t = line("ln", _sites(3), [Link(2e-3, 3.0), Link(2e-3, 3.0)])
    natural = technique_step_cost("pipeshard", WL, t, stage_order=[0, 1, 2])
    crossed = technique_step_cost("pipeshard", WL, t, stage_order=[0, 2, 1])
    assert crossed.comm_s > natural.comm_s


def test_stage_order_must_be_permutation():
    t = fully_connected("f", _sites(3), Link(1e-3, 3.0))
    with pytest.raises(ValueError, match="permutation"):
        technique_step_cost("pipeshard", WL, t, vms=[0, 1],
                            stage_order=[0, 2])


# ------------------------------------------------------------------ #
# site -> mesh mapping
# ------------------------------------------------------------------ #

def test_topology_mesh_spec_shapes():
    t = fully_connected("f", _sites(3), Link(1e-3, 3.0))
    shape, axes = topology_mesh_spec(t)
    assert shape == (3, 2, 1)
    assert axes == ("pod", "data", "model")
    shape, _ = topology_mesh_spec(t, [0, 2], model=2)
    assert shape == (2, 1, 2)


def test_topology_mesh_spec_rejects_ragged_sites():
    t = make_topology("rag", [Site(("A30", "A30")), Site(("T4",))],
                      {(0, 1): Link(1e-3, 3.0)})
    with pytest.raises(ValueError, match="unequal GPU counts"):
        topology_mesh_spec(t)


def test_placement_mesh_wires_flat_plans():
    """The extended pool's winners (fsdp / shard_zero — flat, not
    pipelined) realize through ``launch.mesh.placement_mesh`` as plain
    topology meshes over the placement's site subset."""
    import jax
    from repro.core.plans import get_plan
    from repro.launch.mesh import placement_mesh
    topo = make_topology("one", [Site(("A30",), name="A")], {})
    mesh = placement_mesh(topo, get_plan("fsdp"), Placement((0,)),
                          devices=jax.devices()[:1])
    assert mesh.axis_names == ("pod", "data", "model")
    assert mesh.devices.size == 1


def test_placement_pod_permutation():
    p = Placement(sites=(1, 3, 4), stage_order=(4, 1, 3))
    assert p.pod_permutation() == (2, 0, 1)
    assert p.n_stages == 3
    assert Placement(sites=(0, 1)).pod_permutation() == (0, 1)
    with pytest.raises(ValueError, match="permutation"):
        Placement(sites=(0, 1), stage_order=(0, 2))
