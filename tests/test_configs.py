"""Config-system tests: every assigned architecture is present with the
exact published hyperparameters and a spec-conforming reduced() variant."""
import pytest

from repro.configs import (ARCH_CONFIGS, ASSIGNED_ARCHS, INPUT_SHAPES,
                           get_config, get_shape)

# published parameter counts (billions), ±12% tolerance for structural
# simplifications documented in DESIGN.md
PUBLISHED_PARAMS = {
    "minicpm3-4b": 4.0,
    "phi-3-vision-4.2b": 4.2,
    "phi3.5-moe-42b-a6.6b": 41.9,
    "falcon-mamba-7b": 7.3,
    "zamba2-2.7b": 2.7,
    "llama3-405b": 405.0,
    "phi4-mini-3.8b": 3.8,
    "whisper-small": 0.244,
    "deepseek-v2-236b": 236.0,
    "llama3.2-3b": 3.2,
}

ACTIVE_PARAMS = {
    "phi3.5-moe-42b-a6.6b": 6.6,
    "deepseek-v2-236b": 21.0,
}


def test_all_assigned_archs_present():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ASSIGNED_ARCHS:
        assert arch in ARCH_CONFIGS


def test_six_family_span():
    fams = {get_config(a).family for a in ASSIGNED_ARCHS}
    assert fams == {"dense", "vlm", "moe", "ssm", "hybrid", "encdec"}


@pytest.mark.parametrize("arch,target", sorted(PUBLISHED_PARAMS.items()))
def test_param_counts_match_published(arch, target):
    got = get_config(arch).param_count() / 1e9
    assert abs(got - target) / target < 0.15, (arch, got, target)


@pytest.mark.parametrize("arch,target", sorted(ACTIVE_PARAMS.items()))
def test_active_param_counts(arch, target):
    got = get_config(arch).active_param_count() / 1e9
    assert abs(got - target) / target < 0.15, (arch, got, target)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_variant_conforms(arch):
    """Spec: smoke variant has 2 layers, d_model<=512, <=4 experts."""
    r = get_config(arch).reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.n_experts <= 4
    assert r.family == get_config(arch).family


def test_exact_assignment_hyperparams():
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k,
            c.moe.n_shared_experts, c.mla.kv_lora_rank) == \
        (60, 5120, 160, 6, 2, 512)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state, c.d_ff) == (64, 4096, 16, 0)
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state, c.ssm.version) == \
        (54, 2560, 64, 2)
    c = get_config("whisper-small")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.vocab_size) == \
        (12, 12, 768, 51865)


def test_input_shapes_exact():
    assert len(INPUT_SHAPES) == 4
    s = get_shape("train_4k")
    assert (s.seq_len, s.global_batch, s.kind) == (4096, 256, "train")
    s = get_shape("prefill_32k")
    assert (s.seq_len, s.global_batch, s.kind) == (32768, 32, "prefill")
    s = get_shape("decode_32k")
    assert (s.seq_len, s.global_batch, s.kind) == (32768, 128, "decode")
    s = get_shape("long_500k")
    assert (s.seq_len, s.global_batch, s.kind) == (524288, 1, "decode")


def test_long_context_policy():
    """ssm/hybrid native; dense via sliding window; whisper has none."""
    assert get_config("falcon-mamba-7b").supports_long_context
    assert get_config("zamba2-2.7b").supports_long_context
    assert get_config("llama3-405b").supports_long_context  # window variant
    assert not get_config("whisper-small").supports_long_context
