"""Evaluation substrate tests: perplexity + embedding extraction."""
import dataclasses
import math

import jax
import numpy as np

from repro.configs import get_config
from repro.data import Loader, Tokenizer, build_dataset, synthetic_wikipedia
from repro.models import Model
from repro.train.evaluate import embed_texts, evaluate_perplexity


def _setup():
    texts = list(synthetic_wikipedia(120, seed=3))
    tok = Tokenizer.train(texts, 512)
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              vocab_size=tok.vocab_size)
    ds = build_dataset(texts, tok, seq_len=48)
    return cfg, tok, ds


def test_perplexity_near_uniform_at_init():
    cfg, tok, ds = _setup()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loader = Loader(ds, global_batch=4, seed=0)
    res = evaluate_perplexity(model, params, loader, max_batches=3)
    # untrained model ~ uniform over vocab
    assert abs(res["nll"] - math.log(cfg.vocab_size)) < 0.5
    assert res["tokens"] > 0


def test_embeddings_shape_and_finiteness():
    cfg, tok, ds = _setup()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    embs = embed_texts(model, params, [ds.examples[:4, :16],
                                       ds.examples[4:6, :16]])
    assert embs.shape == (6, cfg.d_model)
    assert np.isfinite(embs).all()
    # different inputs -> different embeddings
    assert np.abs(embs[0] - embs[1]).max() > 1e-4
