"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; multi-device tests spawn
subprocesses with their own --xla_force_host_platform_device_count."""
import os
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def subproc_env():
    """Environment for subprocess-based multi-device tests (PYTHONPATH
    pointing at src/).  Fixture (pytest conftest auto-discovery) rather
    than `import conftest`, which breaks under importlib import mode."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env
